"""Fault injection (core/faults) + degraded-mode NoI routing: rerouting,
explicit disconnection results, degenerate topologies, derating, seeded
scenario sampling, and the fault-tolerance-aware MOO objective."""
import math

import numpy as np
import pytest

from repro.config import get_config
from repro.core.cosim import (Episode, EpisodeMix, degradation_under_faults,
                              fabric_time, resilience_objective)
from repro.core.faults import (NOMINAL, DisconnectedFabric, FaultModel,
                               FaultScenario, all_link_scenarios,
                               endurance_link_weights)
from repro.core.noi import evaluate_noi, mesh_baseline_eval, noi_phase_time
from repro.core.placement import Placement, initial_placement, mesh_links
from repro.core.simulator import simulate_generation
from repro.core.traffic import Workload, transformer_phases


@pytest.fixture(scope="module")
def phases():
    w = Workload.from_config(get_config("bert-base"), seq_len=16)
    return transformer_phases(w)


@pytest.fixture(scope="module")
def mesh36():
    return initial_placement(36)


def _finite_eval(ev):
    for x in (ev.mu, ev.sigma, ev.max_util, ev.total_byte_hops):
        assert math.isfinite(x) and not math.isnan(x)


# ---------------------------------------------------------------------------
# scenario semantics
# ---------------------------------------------------------------------------

def test_nominal_scenario_bit_identical(mesh36, phases):
    """scenario=None, the NOMINAL constant, and an empty FaultScenario all
    evaluate to exactly the same numbers (the fault plumbing is free when
    unused — the calibration pins rely on it)."""
    base = evaluate_noi(mesh36, phases)
    for sc in (NOMINAL, FaultScenario(), FaultScenario.make()):
        ev = evaluate_noi(mesh36, phases, scenario=sc)
        assert (ev.mu, ev.sigma, ev.max_util, ev.total_byte_hops) == \
            (base.mu, base.sigma, base.max_util, base.total_byte_hops)


def test_link_failure_reroutes(mesh36, phases):
    """Failing one mesh link leaves the fabric routable: the evaluation
    stays finite and the dead link carries zero bytes."""
    links = sorted(mesh36.links)
    sc = FaultScenario.make([links[0]])
    ev = evaluate_noi(mesh36, phases, scenario=sc)
    assert not ev.disconnected
    _finite_eval(ev)
    for u in ev.per_phase_link_bytes:
        assert u[0] == 0.0                       # nothing routed on it
    base = evaluate_noi(mesh36, phases)
    assert ev.total_byte_hops != base.total_byte_hops or ev.mu != base.mu


def test_all_links_failed_is_explicit_disconnection(mesh36, phases):
    sc = FaultScenario.make(sorted(mesh36.links))
    ev = evaluate_noi(mesh36, phases, scenario=sc)
    assert ev.disconnected
    assert ev.mu == float("inf") and ev.sigma == float("inf")
    assert not math.isnan(ev.mu)


def test_disconnected_placement_without_scenario(phases):
    """A linkless multi-chiplet placement is disconnected even fault-free —
    explicit inf result, no NaN/zero-division."""
    p = Placement(2, 2, ["SM", "MC", "DRAM", "ReRAM"], set(), [3])
    ev = evaluate_noi(p, phases)
    assert ev.disconnected and ev.mu == float("inf")


def test_single_chiplet_system_is_zero_not_nan(phases):
    """One chiplet, zero links: no inter-chiplet traffic → exactly-zero
    link statistics (the empty-array mean used to NaN here)."""
    p = Placement(1, 1, ["SM"], set(), [])
    ev = evaluate_noi(p, phases)
    assert not ev.disconnected
    assert ev.mu == 0.0 and ev.sigma == 0.0 and ev.max_util == 0.0


def test_chiplet_down_redistributes_and_role_wipeout_disconnects(mesh36,
                                                                 phases):
    roles = mesh36.roles()
    drams = roles["DRAM"]
    assert len(drams) > 1
    ev = evaluate_noi(mesh36, phases,
                      scenario=FaultScenario.make(failed_chiplets=[drams[0]]))
    assert not ev.disconnected
    _finite_eval(ev)
    # traffic a dead chiplet would have sourced moves to its role peers
    base = evaluate_noi(mesh36, phases)
    assert ev.total_byte_hops != base.total_byte_hops
    # killing EVERY chiplet of a role leaves its traffic unroutable
    ev2 = evaluate_noi(mesh36, phases,
                       scenario=FaultScenario.make(failed_chiplets=drams))
    assert ev2.disconnected


def test_derated_link_slows_phase_time(mesh36, phases):
    base = evaluate_noi(mesh36, phases)
    # derate the busiest link of the heaviest phase to 10% bandwidth
    u = max(base.per_phase_link_bytes, key=lambda u: u.max())
    busiest = sorted(mesh36.links)[int(np.argmax(u))]
    sc = FaultScenario.make(derated_links={busiest: 0.1})
    ev = evaluate_noi(mesh36, phases, scenario=sc)
    assert ev.mu == base.mu                      # routing unchanged
    assert ev.link_bw_scale is not None
    t0 = noi_phase_time(u)
    t1 = noi_phase_time(u, ev.link_bw_scale)
    assert t1 == pytest.approx(t0 * 10.0)


def test_derate_factor_validated():
    with pytest.raises(ValueError, match="derate"):
        FaultScenario.make(derated_links={(0, 1): 0.0})
    with pytest.raises(ValueError, match="derate"):
        FaultScenario.make(derated_links={(0, 1): 1.5})


def test_mesh_baseline_eval_degenerate_is_explicit(phases):
    """A scenario that wipes a whole role disconnects every sampled mesh
    draw: the baseline reports disconnection explicitly (no NaN from
    averaging infs)."""
    sc = FaultScenario.make(failed_chiplets=range(36))
    ev = mesh_baseline_eval(36, phases, n_samples=2, scenario=sc)
    assert ev.disconnected and not math.isnan(ev.mu)
    ok = mesh_baseline_eval(36, phases, n_samples=2)
    assert not ok.disconnected
    _finite_eval(ok)


# ---------------------------------------------------------------------------
# scenario sampling
# ---------------------------------------------------------------------------

def test_sampling_deterministic_per_design(mesh36):
    fm = FaultModel(k_links=2, seed=5)
    a = fm.sample_scenarios(mesh36, 6)
    b = fm.sample_scenarios(mesh36, 6)
    assert a == b
    assert all(len(s.failed_links) == 2 for s in a)
    c = FaultModel(k_links=2, seed=6).sample_scenarios(mesh36, 6)
    assert a != c


def test_sampling_weights_bias_draws(mesh36):
    links = sorted(mesh36.links)
    w = [0.0] * len(links)
    w[7] = 1.0
    fm = FaultModel(k_links=1, seed=0)
    for sc in fm.sample_scenarios(mesh36, 5, link_weights=w):
        assert sc.failed_links == frozenset({links[7]})
    with pytest.raises(ValueError, match="link_weights"):
        fm.sample_scenarios(mesh36, 1, link_weights=[1.0])


def test_sampling_chiplets_and_derates(mesh36):
    fm = FaultModel(k_links=1, k_chiplets=1, k_derated=2, bw_derate=0.5,
                    seed=1)
    for sc in fm.sample_scenarios(mesh36, 4):
        assert len(sc.failed_chiplets) == 1
        assert len(sc.derated_links) == 2
        assert all(f == 0.5 for _, f in sc.derated_links)
        assert not (set(l for l, _ in sc.derated_links) & sc.failed_links)


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(k_links=-1)
    with pytest.raises(ValueError):
        FaultModel(bw_derate=0.0)


def test_all_link_scenarios_exhaustive_and_capped(mesh36):
    scs = all_link_scenarios(mesh36, k=1)
    assert len(scs) == len(mesh36.links)
    assert len({s.failed_links for s in scs}) == len(scs)
    capped = all_link_scenarios(mesh36, k=2, max_scenarios=10)
    assert len(capped) == 10
    assert all(len(s.failed_links) == 2 for s in capped)


def test_endurance_weights_upweight_reram_links(mesh36, phases):
    w = endurance_link_weights(mesh36, phases, reram_wear_factor=4.0)
    links = sorted(mesh36.links)
    assert len(w) == len(links)
    assert all(x > 0 for x in w)
    rerams = set(mesh36.roles()["ReRAM"])
    rw = [x for l, x in zip(links, w) if l[0] in rerams or l[1] in rerams]
    other = [x for l, x in zip(links, w)
             if l[0] not in rerams and l[1] not in rerams]
    assert np.mean(rw) > np.mean(other)


# ---------------------------------------------------------------------------
# simulator threading
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["2.5D-HI", "HAIMA_chiplet",
                                  "TransPIM_chiplet"])
def test_generation_per_scenario_and_disconnection(arch):
    w = Workload.from_config(get_config("bert-base"), seq_len=16)
    p = initial_placement(36)
    sc = FaultScenario.make([sorted(p.links)[0]])
    g = simulate_generation(w, 36, 16, 4, arch=arch, scenario=sc)
    assert math.isfinite(g.ttft_s) and math.isfinite(g.decode_step_s)
    assert math.isfinite(g.energy_j)
    base = simulate_generation(w, 36, 16, 4, arch=arch)
    nsc = simulate_generation(w, 36, 16, 4, arch=arch, scenario=NOMINAL)
    assert (nsc.ttft_s, nsc.decode_step_s, nsc.energy_j) == \
        (base.ttft_s, base.decode_step_s, base.energy_j)
    wipe = FaultScenario.make(failed_chiplets=range(36))
    with pytest.raises(DisconnectedFabric):
        simulate_generation(w, 36, 16, 4, arch=arch, scenario=wipe)


# ---------------------------------------------------------------------------
# fault-tolerance-aware objective
# ---------------------------------------------------------------------------

def _mix():
    return EpisodeMix([Episode(16, 8, 2)], prefill_chunk=16, max_batch=2,
                      active_hist={2: 1}, max_stall_tokens=16)


def test_resilience_objective_orders_fragile_below_robust():
    """On a mesh (1-failure-robust) the objective is finite with
    worst >= the seed-normalised nominal (= 1.0 for the seed placement
    itself, always scenario 0); on a spanning tree (any link failure
    disconnects) it is inf — the MOO archive drops such designs."""
    obj, seed_time, phs = resilience_objective(
        get_config("bert-base"), _mix(), 36,
        fault_model=FaultModel(k_links=1, seed=0), n_scenarios=4)
    mesh = initial_placement(36)
    assert seed_time == pytest.approx(fabric_time(mesh, phs))
    e, wc = obj(mesh)
    assert math.isfinite(e) and math.isfinite(wc)
    assert wc >= 1.0 and wc >= e > 0    # nominal (==1.0) is scenario 0

    # spanning tree: drop mesh links until exactly n-1 remain, connected
    tree = mesh.copy()
    for l in sorted(mesh.links):
        if len(tree.links) == tree.n - 1:
            break
        tree.links.discard(l)
        if not tree.connected():
            tree.links.add(l)
    assert tree.connected() and len(tree.links) == tree.n - 1
    assert obj(tree) == (float("inf"), float("inf"))

    from repro.core.moo import Archive
    a = Archive()
    assert a.add(mesh, obj(mesh))
    assert not a.add(tree, obj(tree))


def test_degradation_under_faults_reports():
    p = initial_placement(36)
    obj, _, phs = resilience_objective(
        get_config("bert-base"), _mix(), 36, n_scenarios=2)
    scs = all_link_scenarios(p, k=1, max_scenarios=8)
    rep = degradation_under_faults(p, phs, scs)
    assert rep["n_scenarios"] == 8 and rep["n_disconnected"] == 0
    assert math.isfinite(rep["worst_t"])
    assert rep["worst_t"] >= rep["expected_t"] > 0
    assert rep["nominal_t"] > 0 and rep["worst_label"]
    # all-links-down scenario disconnects and is counted, never NaN
    rep2 = degradation_under_faults(
        p, phs, [FaultScenario.make(sorted(p.links))])
    assert rep2["n_disconnected"] == 1
    assert rep2["worst_t"] == float("inf")


def test_endurance_weighted_objective_runs():
    obj, _, _ = resilience_objective(
        get_config("bert-base"), _mix(), 36, n_scenarios=2,
        endurance_weighted=True)
    e, wc = obj(initial_placement(36))
    assert math.isfinite(e) and math.isfinite(wc)
