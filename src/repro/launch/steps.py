"""Step builders + ShapeDtypeStruct input specs for every (arch × shape) cell.

``input_specs`` follows the assignment: weak-type-correct, shardable
stand-ins for every model input — token batches for training, request
batches + KV caches for serving — with **no device allocation**.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeSpec
from repro.models import transformer as T
from repro.parallel.api import Plan, activate_plan
from repro.parallel import sharding as SH
from repro.training.optimizer import OptConfig, adamw_init, adamw_update

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((B,), jnp.int32), "pos": SDS((B,), jnp.int32)}
    b = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        b["encoder_tokens"] = SDS((B, S), jnp.int32)
    if cfg.family == "vlm":
        b["image_embeds"] = SDS((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return b


def params_specs(cfg: ModelConfig, param_dtype) -> Any:
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, param_dtype=param_dtype),
        SDS((2,), jnp.uint32))


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                             dtype=jnp.bfloat16))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, plan: Optional[Plan] = None, *,
                    opt_cfg: OptConfig = OptConfig(), accum: int = 1,
                    impl: str = "ref", remat: bool = True,
                    remat_policy: Optional[str] = None,
                    grad_shardings=None):
    def loss_f(params, batch):
        with activate_plan(plan):
            return T.loss_fn(params, cfg, batch, impl=impl, remat=remat,
                             remat_policy=remat_policy)

    def pin(grads):
        # keep gradients on the parameter sharding — without this the
        # grad-accumulation carry (and the embedding-gradient dot feeding
        # it) materialises unsharded inside the scan body
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_f, has_aux=True)(params, batch)
            grads = pin(grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_f, has_aux=True)(params, mb)
                g_acc = pin(jax.tree_util.tree_map(jnp.add, g_acc, pin(g)))
                return (g_acc, l_acc + l), None

            g0 = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)),
                                            micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, plan: Optional[Plan] = None, *,
                      impl: str = "ref", kv_cap: int = 0):
    def prefill_step(params, batch):
        with activate_plan(plan):
            return T.prefill(params, cfg, batch, impl=impl, kv_cap=kv_cap)
    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[Plan] = None, *,
                     impl: str = "ref"):
    def decode(params, cache, tokens, pos):
        with activate_plan(plan):
            return T.decode_step(params, cfg, cache, tokens, pos, impl=impl)
    return decode


# ---------------------------------------------------------------------------
# full AOT cell assembly (used by dryrun + roofline + perf loop)
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               accum: int = 1, impl: str = "ref",
               donate: bool = True):
    """Returns (jitted_fn, example_args_SDS) for one (arch × shape × mesh)."""
    mode = shape.kind
    plan, ctx = SH.build_plan(cfg, shape, mesh, mode=mode)
    bspecs = batch_specs(cfg, shape)
    bshard = SH.batch_shardings(bspecs, ctx)

    if mode == "train":
        pspecs = params_specs(cfg, jnp.float32)
        pshard = SH.params_shardings(pspecs, ctx)
        ospecs = jax.eval_shape(adamw_init, pspecs)
        oshard = {  # moments shard exactly like their parameters (ZeRO)
            "m": SH.params_shardings(ospecs["m"], ctx),
            "v": SH.params_shardings(ospecs["v"], ctx),
            "count": NamedSharding(mesh, P()),
        }
        fn = make_train_step(cfg, plan, accum=accum, impl=impl,
                             grad_shardings=pshard)
        rep = NamedSharding(mesh, P())
        metrics_shard = {"loss": rep, "gnorm": rep, "lr": rep}
        jfn = jax.jit(
            fn,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, metrics_shard),
            donate_argnums=(0, 1) if donate else (),
        )
        return jfn, (pspecs, ospecs, bspecs), plan

    pspecs = params_specs(cfg, jnp.bfloat16)
    pshard = SH.params_shardings(pspecs, ctx)

    if mode == "prefill":
        fn = make_prefill_step(cfg, plan, impl=impl, kv_cap=shape.seq_len)
        out_spec = jax.eval_shape(fn, pspecs, bspecs)
        vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        logits_shard = NamedSharding(mesh, P(ctx.dp if ctx.dp else None, vocab_ax))
        cshard = SH.cache_shardings(out_spec[1], ctx)
        jfn = jax.jit(fn, in_shardings=(pshard, bshard),
                      out_shardings=(logits_shard, cshard))
        return jfn, (pspecs, bspecs), plan

    # decode
    cspecs = cache_specs(cfg, shape)
    cshard = SH.cache_shardings(cspecs, ctx)
    tok_shard = NamedSharding(mesh, P(ctx.dp if ctx.dp else None))
    fn = make_decode_step(cfg, plan, impl=impl)
    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_shard = NamedSharding(mesh, P(ctx.dp if ctx.dp else None, vocab_ax))
    jfn = jax.jit(
        fn,
        in_shardings=(pshard, cshard, tok_shard, tok_shard),
        out_shardings=(logits_shard, cshard),
        donate_argnums=(1,) if donate else (),
    )
    args = (pspecs, cspecs, batch_specs(cfg, shape)["tokens"],
            batch_specs(cfg, shape)["pos"])
    return jfn, args, plan
