"""Capacity benchmark: tail latency (TTFT/TPOT percentiles) vs offered load.

The request-level view the paper's serving claims live in: an open-loop
arrival process (``serving/workload.py``) is offered to the engine
through the streaming front end (``serving/frontend.py``) at multiples
of the measured closed-loop capacity, and the drain's per-request
timestamps yield p50/p95/p99 TTFT and TPOT per priority class — the
load-latency curve that saturates at capacity and diverges under
overload.

Each zoo model runs the sweep under both schedulers:

- ``fifo`` — strict arrival order (the pre-layering engine's policy);
- ``slo``  — ``SloScheduler`` with a high-priority interactive class
  (tight TTFT/TPOT targets) over a best-effort batch class: priority
  admission + slack-gated chunked-prefill preemption of decode.

The headline check: at overload (highest load multiple) the SLO policy
improves the high-priority class's p99 TTFT vs FIFO — tail isolation
paid for by the batch class, visible in the same table.  The overload
run's measured mix then flows through ``cosim_from_engine`` so Plane-B
NoI architecture comparison is driven by the tail-latency regime, not a
synthetic mix.

Results go to ``experiments/BENCH_capacity.json`` (schema-checked;
``--smoke`` writes ``BENCH_capacity_smoke.json`` for CI) and are
rendered by ``benchmarks/report.py``.

    PYTHONPATH=src python -m benchmarks.perf_capacity [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

_CLASS_KEYS = {"n", "ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
               "tpot_p50_s", "tpot_p95_s", "tpot_p99_s",
               "mean_queue_wait_s"}
_POINT_KEYS = {"offered_rps", "load_x", "n", "finished", "failed",
               "span_s", "classes"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_capacity.json record shape (CI bit-rot gate)."""
    for key in ("bench", "backend", "smoke", "hi_fraction", "loads",
                "schedulers", "models"):
        assert key in rec, f"missing top-level key {key!r}"
    assert rec["models"], "no models in record"
    for arch, m in rec["models"].items():
        for key in ("capacity_rps", "curves", "slo_wins_hi_p99_ttft",
                    "cosim"):
            assert key in m, f"model {arch!r} missing {key!r}"
        for sched in rec["schedulers"]:
            curve = m["curves"][sched]
            assert len(curve) == len(rec["loads"]), \
                f"{arch}/{sched}: {len(curve)} points != {len(rec['loads'])}"
            for pt in curve:
                missing = _POINT_KEYS - set(pt)
                assert not missing, f"{arch}/{sched} point missing {missing}"
                for cls in ("hi", "lo"):
                    missing = _CLASS_KEYS - set(pt["classes"][cls])
                    assert not missing, \
                        f"{arch}/{sched}/{cls} missing {missing}"
        for key in ("mix", "archs"):
            assert key in m["cosim"], f"{arch} cosim missing {key!r}"


def _pcts(xs) -> tuple:
    """(p50, p95, p99), or ``(None, None, None)`` for an empty sample
    class — the record stores JSON null, never a fake 0 s latency."""
    if not xs:
        return (None, None, None)
    p = np.percentile(np.asarray(xs, np.float64), (50.0, 95.0, 99.0))
    return (float(p[0]), float(p[1]), float(p[2]))


def _class_stats(reqs) -> dict:
    ttft = [r.t_first_token - r.t_enqueue for r in reqs]
    # gen_len <= 1 requests have no per-token cadence sample (TPOT is a
    # difference over len(output) - 1 intervals) — they are excluded, and
    # a class with none left reports null
    tpot = [(r.t_done - r.t_first_token) / (len(r.output) - 1)
            for r in reqs if len(r.output) > 1]
    qwait = [r.t_admit - r.t_enqueue for r in reqs if r.t_admit > 0.0]
    t50, t95, t99 = _pcts(ttft)
    d50, d95, d99 = _pcts(tpot)
    return {"n": len(reqs),
            "ttft_p50_s": t50, "ttft_p95_s": t95, "ttft_p99_s": t99,
            "tpot_p50_s": d50, "tpot_p95_s": d95, "tpot_p99_s": d99,
            "mean_queue_wait_s": float(np.mean(qwait)) if qwait else None}


def _warm_drain(engine, cfg, *, n: int, min_len: int, max_len: int,
                max_new_tokens: int, seed: int = 0) -> list:
    """Closed-loop drain of ``n`` requests; returns the finished slice."""
    from repro.serving.workload import synthetic_prompts

    rng = np.random.default_rng(seed)
    n0 = len(engine.finished)
    for p in synthetic_prompts(n, rng, min_len=min_len, max_len=max_len,
                               vocab=cfg.vocab_size):
        engine.submit(p, max_new_tokens)
    engine.run_until_drained()
    return engine.finished[n0:]


def measure_capacity(cfg, params, ecfg_kw: dict, *, n: int,
                     min_len: int, max_len: int,
                     max_new_tokens: int) -> float:
    """Closed-loop capacity (finished req/s): submit everything at once,
    drain flat out — the saturation throughput the load multiples are
    anchored to.  A first (untimed) drain absorbs every compile; the
    measured drain times only the serving loop."""
    from repro.serving.engine import EngineConfig, ServingEngine

    eng = ServingEngine(cfg, params, EngineConfig(**ecfg_kw))
    shape = dict(n=n, min_len=min_len, max_len=max_len,
                 max_new_tokens=max_new_tokens)
    _warm_drain(eng, cfg, **shape)            # compiles happen here
    done = _warm_drain(eng, cfg, **shape, seed=1)
    span = max(r.t_done for r in done) - min(r.t_enqueue for r in done)
    return len(done) / max(span, 1e-9)


def run_point(engine, frontend, *, n: int, rate_rps: float, load_x: float,
              hi_fraction: float, min_len: int, max_len: int,
              max_new_tokens: int, seed: int) -> dict:
    """Offer one open-loop workload and summarise the drain per class."""
    from repro.serving.workload import make_workload

    n0, f0 = len(engine.finished), len(engine.failed)
    wl = make_workload(n, rate_rps, seed=seed, hi_fraction=hi_fraction,
                       min_len=min_len, max_len=max_len,
                       vocab=engine.cfg.vocab_size,
                       max_new_tokens=max_new_tokens)
    t0 = time.perf_counter()
    frontend.play(wl)
    span = time.perf_counter() - t0
    done = engine.finished[n0:]
    hi = [r for r in done if r.priority > 0]
    lo = [r for r in done if r.priority == 0]
    return {"offered_rps": rate_rps,
            "load_x": load_x,
            "n": n,
            "finished": len(done),
            "failed": len(engine.failed) - f0,
            "span_s": span,
            "classes": {"hi": _class_stats(hi), "lo": _class_stats(lo)}}


def run_model(arch: str, *, loads, n: int, hi_fraction: float,
              ecfg_kw: dict, min_len: int, max_len: int,
              max_new_tokens: int, hi_ttft_ms: float, hi_tpot_ms: float,
              lo_ttft_ms: float, n_chiplets: int) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.config import get_config, reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.frontend import ServingFrontend
    from repro.serving.scheduler import SloClass, SloScheduler

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)
    shape = dict(min_len=min_len, max_len=max_len,
                 max_new_tokens=max_new_tokens)
    capacity = measure_capacity(cfg, params, ecfg_kw, n=n, **shape)

    def make_sched(name):
        if name == "fifo":
            return None                       # engine default
        return SloScheduler(classes={1: SloClass(ttft_ms=hi_ttft_ms,
                                                 tpot_ms=hi_tpot_ms),
                                     0: SloClass(ttft_ms=lo_ttft_ms)},
                            aging_s=30.0)

    curves: dict[str, list] = {}
    overload_engine = None
    for sched_name in ("fifo", "slo"):
        # one engine per scheduler, warmed with an untimed closed-loop
        # drain (fresh jit closures per engine → compiles land there, not
        # in the first load point); per-point metrics slice
        # engine.finished, so accumulation across load points never
        # mixes samples
        engine = ServingEngine(cfg, params, EngineConfig(**ecfg_kw),
                               scheduler=make_sched(sched_name))
        _warm_drain(engine, cfg, n=2 * ecfg_kw["max_batch"], **shape)
        frontend = ServingFrontend(engine)
        curve = []
        for j, load_x in enumerate(loads):
            curve.append(run_point(
                engine, frontend, n=n, rate_rps=load_x * capacity,
                load_x=load_x, hi_fraction=hi_fraction, seed=100 + j,
                **shape))
        curves[sched_name] = curve
        if sched_name == "slo":
            overload_engine = engine

    hi_fifo = curves["fifo"][-1]["classes"]["hi"]["ttft_p99_s"]
    hi_slo = curves["slo"][-1]["classes"]["hi"]["ttft_p99_s"]
    # the overload SLO run's measured mix drives Plane-B NoI comparison
    cosim = cosim_from_engine(overload_engine, n_chiplets=n_chiplets)
    return {"capacity_rps": capacity,
            "curves": curves,
            "hi_p99_ttft_s": {"fifo": hi_fifo, "slo": hi_slo},
            # an empty hi class at the overload point (null percentile)
            # cannot claim a win in either direction
            "slo_wins_hi_p99_ttft": bool(
                hi_fifo is not None and hi_slo is not None
                and hi_slo < hi_fifo),
            "cosim": cosim}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["qwen2.5-3b", "gemma2-9b"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, still writes JSON)")
    ap.add_argument("--requests", type=int, default=40,
                    help="requests offered per (scheduler, load) point")
    ap.add_argument("--loads", nargs="+", type=float,
                    default=[0.5, 1.0, 2.0],
                    help="offered load as multiples of measured capacity")
    ap.add_argument("--hi-fraction", type=float, default=0.25,
                    help="fraction of requests in the high-priority class")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--min-len", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=20)
    ap.add_argument("--hi-ttft-ms", type=float, default=200.0)
    ap.add_argument("--hi-tpot-ms", type=float, default=100.0)
    ap.add_argument("--lo-ttft-ms", type=float, default=5000.0)
    ap.add_argument("--n-chiplets", type=int, default=64)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: experiments/BENCH_capacity"
                         ".json, or BENCH_capacity_smoke.json with --smoke "
                         "so CI never clobbers the recorded full run)")
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS, "BENCH_capacity_smoke.json" if args.smoke
            else "BENCH_capacity.json")
    if args.smoke:
        args.archs = ["qwen2.5-3b"]
        args.requests = 8
        args.loads = [0.8, 2.5]
        args.max_batch, args.kv_len = 2, 48
        args.max_new_tokens = 4
        args.min_len, args.max_len = 4, 8
        args.n_chiplets = 36          # smallest paper system size (§4.1.1)

    import jax
    from benchmarks.common import emit

    ecfg_kw = dict(max_batch=args.max_batch, kv_len=args.kv_len,
                   max_new_tokens=args.max_new_tokens, impl="ref")
    models = {}
    for arch in args.archs:
        models[arch] = run_model(
            arch, loads=args.loads, n=args.requests,
            hi_fraction=args.hi_fraction, ecfg_kw=ecfg_kw,
            min_len=args.min_len, max_len=args.max_len,
            max_new_tokens=args.max_new_tokens,
            hi_ttft_ms=args.hi_ttft_ms, hi_tpot_ms=args.hi_tpot_ms,
            lo_ttft_ms=args.lo_ttft_ms, n_chiplets=args.n_chiplets)

    rec = {
        "bench": "capacity",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "requests": args.requests,
        "hi_fraction": args.hi_fraction,
        "loads": args.loads,
        "schedulers": ["fifo", "slo"],
        "engine": ecfg_kw,
        "slo": {"hi_ttft_ms": args.hi_ttft_ms,
                "hi_tpot_ms": args.hi_tpot_ms,
                "lo_ttft_ms": args.lo_ttft_ms},
        "models": models,
    }
    check_schema(rec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    def ms(v):
        return None if v is None else v * 1e3

    def ms_s(v):
        return "—" if v is None else f"{v * 1e3:.0f}"

    rows = []
    for arch, m in models.items():
        for sched in ("fifo", "slo"):
            for pt in m["curves"][sched]:
                rows.append({
                    "arch": arch, "sched": sched,
                    "load_x": pt["load_x"],
                    "offered_rps": round(pt["offered_rps"], 2),
                    "hi_ttft_p99_ms":
                        ms(pt["classes"]["hi"]["ttft_p99_s"]),
                    "lo_ttft_p99_ms":
                        ms(pt["classes"]["lo"]["ttft_p99_s"]),
                    "hi_tpot_p99_ms":
                        ms(pt["classes"]["hi"]["tpot_p99_s"]),
                })
    emit(rows, "capacity")
    for arch, m in models.items():
        hp = m["hi_p99_ttft_s"]
        print(f"{arch}: capacity {m['capacity_rps']:.2f} req/s · overload "
              f"hi-class p99 TTFT {ms_s(hp['fifo'])} ms (fifo) -> "
              f"{ms_s(hp['slo'])} ms (slo) · "
              f"{'SLO wins' if m['slo_wins_hi_p99_ttft'] else 'NO WIN'}")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
