from repro.parallel.api import activate_plan, constrain, current_plan  # noqa: F401
