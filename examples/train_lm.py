"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on CPU with the full production stack — sharded data pipeline,
AdamW, checkpointing (resume works mid-run), preemption handling, and the
straggler watchdog.  Loss must visibly descend on the structured synthetic
corpus.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      (~100M params; use --tiny for a fast smoke run)
"""
import argparse

import jax

from repro.config import ShapeSpec, get_config, reduce_config
from repro.launch.mesh import small_mesh
from repro.training.optimizer import OptConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (fast CPU smoke)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.tiny:
        cfg = reduce_config(get_config("qwen2.5-3b"))
    else:
        # mamba2-130m: the one assigned architecture that genuinely is
        # ~100M params — train it for real
        cfg = get_config("mamba2-130m")
    shape = ShapeSpec("train_lm", "train", args.seq, args.batch)
    mesh = small_mesh(1, 1)

    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")

    trainer = Trainer(
        cfg, shape, mesh,
        opt_cfg=OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
    )
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")

    first = None
    for m in trainer.run(args.steps - trainer.step):
        if first is None:
            first = m["loss"]
        if m["step"] % 10 == 0:
            print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['gnorm']:.2f}  lr {m['lr']:.2e}  "
                  f"{m['dt']*1e3:6.0f} ms/step", flush=True)
    trainer.save()
    last = trainer.metrics_log[-1]["loss"]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({trainer.slow_steps} slow steps, checkpoint at {args.ckpt_dir})")


if __name__ == "__main__":
    main()
