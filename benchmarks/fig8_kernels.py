"""Fig. 8: per-kernel latency, 36-chiplet system, BERT-Base, N ∈ {64, 256}.

Validates: 2.5D-HI < both baselines on every kernel; FF gain largest;
HAIMA beats TransPIM on score but loses end-to-end at this size.
"""
from repro.config import get_config
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.simulator import simulate_2p5d_hi
from repro.core.traffic import Workload

from benchmarks.common import emit

KERNELS = ("embed", "kqv", "score", "ff", "lm_head")


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for n in (64, 256):
        w = Workload.from_config(get_config("bert-base"), seq_len=n)
        sims = {
            "2.5D-HI": simulate_2p5d_hi(w, 36),
            "HAIMA_chiplet": simulate_haima_chiplet(w, 36),
            "TransPIM_chiplet": simulate_transpim_chiplet(w, 36),
        }
        for kern in KERNELS:
            row = {"seq_len": n, "kernel": kern}
            for name, sim in sims.items():
                row[name + "_ms"] = sim.per_kernel_s[kern] * 1e3
            row["gain_x"] = min(row["HAIMA_chiplet_ms"],
                                row["TransPIM_chiplet_ms"]) / row["2.5D-HI_ms"]
            rows.append(row)
    if verbose:
        emit(rows, "fig8: per-kernel latency (BERT-Base, 36 chiplets)")
    # assertions (the paper's Fig-8 claims)
    for n in (64, 256):
        sub = {r["kernel"]: r for r in rows if r["seq_len"] == n}
        for kern in ("kqv", "score", "ff"):
            assert sub[kern]["gain_x"] >= 1.0, (n, kern)
        assert sub["ff"]["gain_x"] == max(
            sub[k]["gain_x"] for k in ("embed", "kqv", "score", "ff"))
    return rows


if __name__ == "__main__":
    run()
