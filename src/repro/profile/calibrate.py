"""Map fitted kernel rates onto Plane-B rate constants — behind an
explicit opt-in.

``measured_calib(table)`` returns a ``simulator.Calib`` whose
``sm_efficiency`` / ``reram_fill`` come from *measured* effective rates
instead of the Table-4 anchor fit.  Nothing uses it unless you pass it:
``simulate_generation(..., calib=measured_calib(table))`` /
``cosim_mix(..., calib=)`` — the default ``CALIB`` path stays
bit-identical (the anchor-calibration contract in ``core/README.md`` is
untouched; this module only *constructs* an alternative ``Calib``).

What is mapped, what stays analytical
-------------------------------------
- ``sm_efficiency``  <- measured attention FLOP rate (segmented-prefill
  fit, falling back to decode attention) over the allocated SM peak.
- ``reram_fill``     <- measured fused dequant-matmul FLOP rate (the
  weight-stationary regime ReRAM models) over the allocated ReRAM peak.
- Everything else — NoI wire/hop model, DRAM bandwidth, link energies,
  the HAIMA/TransPIM baseline constants — stays analytical.  The
  profiler measures this host's kernels; it has nothing to say about
  the paper's fabric.

``phase_error_report`` quantifies the gap per phase: the analytical
charge for the fitted phase's median grid point vs the measured cost
model's prediction, next to the fit's own held-out residual (the error
bar).  On CPU the absolute gap is enormous by construction — the
interpreter is not a 27-TFLOP SM plane — which is exactly what the
report is for: the co-sim headline carries the measured residual as its
error bar, and the analytical-vs-measured column says how far the
hand-set constants sit from *this* backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import chiplets as C
from repro.core import simulator
from repro.core.simulator import CALIB, Calib
from repro.profile.costmodel import CalibrationTable, PhaseFit

__all__ = ["PLANE_MAP", "measured_calib", "phase_error_report",
           "error_bar_rel"]

# which Plane-B compute/transfer plane each fitted phase class maps onto
PLANE_MAP = {
    "prefill_attn": "sm",
    "decode_attn": "sm",
    "decode_attn_kv8": "sm",
    "decode_attn_kv4": "sm",
    "dequant_matmul": "reram",
    "executor_step": "dram",
}

# preference order for the rate that calibrates each efficiency scalar
_SM_KINDS = ("prefill_attn", "decode_attn")
_RERAM_KINDS = ("dequant_matmul",)


def _geomean(vals: list[float]) -> float:
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def _plane_flops_rate(fits: dict[str, PhaseFit], kinds) -> Optional[float]:
    rates = [fits[k].flops_rate for k in kinds
             if k in fits and fits[k].flops_rate > 0]
    return _geomean(rates) if rates else None


def measured_calib(table: CalibrationTable, *, n_chiplets: int = 64,
                   base: Calib = CALIB) -> Calib:
    """A ``Calib`` whose efficiency scalars are measured, not anchored.

    Missing phase classes keep ``base``'s value for their scalar (a table
    with only attention fits still calibrates ``sm_efficiency``).  The
    result is clamped to (0, 1]: an efficiency is achieved/peak by
    definition.  Opt-in only — callers must pass it as ``calib=``.
    """
    alloc = simulator._alloc(n_chiplets)
    kw = {}
    sm = _plane_flops_rate(table.fits, _SM_KINDS)
    if sm is not None:
        peak = alloc["SM"] * C.SM.peak_flops
        kw["sm_efficiency"] = min(max(sm / peak, 1e-12), 1.0)
    rer = _plane_flops_rate(table.fits, _RERAM_KINDS)
    if rer is not None:
        peak = alloc["ReRAM"] * C.RERAM.peak_flops
        kw["reram_fill"] = min(max(rer / peak, 1e-12), 1.0)
    return dataclasses.replace(base, **kw)


def _analytical_seconds(fit: PhaseFit, *, alloc: dict, calib: Calib,
                        d_model: int) -> float:
    """Plane B's charge for the fit's median grid point, on the plane
    the phase class maps to (compute planes charge FLOPs, the executor
    step charges its fabric bytes against DRAM bandwidth)."""
    plane = PLANE_MAP.get(fit.kind, "sm")
    if plane == "dram":
        bytes_term = (fit.ref_term if fit.term == "bytes"
                      else fit.ref_term * fit.flops_per_unit)
        return bytes_term / (alloc["DRAM"] * C.DRAM.bw)
    flops = fit.ref_term * fit.flops_per_unit
    if plane == "reram":
        return flops / (alloc["ReRAM"] * C.RERAM.peak_flops
                        * calib.reram_fill)
    rate = (alloc["SM"] * C.SM.peak_flops * calib.sm_efficiency
            * min(1.0, d_model / C.SM_SAT_DIM))
    return flops / rate


def phase_error_report(table: CalibrationTable, *, n_chiplets: int = 64,
                       d_model: int = 64, calib: Calib = CALIB) -> dict:
    """Per-phase analytical-vs-measured comparison.

    For every fitted phase class: the measured model's prediction at its
    median grid point, the analytical charge for the same byte/FLOP
    terms, their log10 ratio (measured/analytical), and the fit's
    held-out residual — the error bar a calibrated claim carries.
    """
    alloc = simulator._alloc(n_chiplets)
    report = {}
    for kind, fit in sorted(table.fits.items()):
        measured = fit.predict(fit.ref_term)
        analytical = _analytical_seconds(fit, alloc=alloc, calib=calib,
                                         d_model=d_model)
        report[kind] = {
            "plane": PLANE_MAP.get(kind, "sm"),
            "term": fit.term,
            "ref_term": fit.ref_term,
            "measured_s": measured,
            "fit_rel_err_at_ref": (abs(measured - fit.ref_seconds)
                                   / max(fit.ref_seconds, 1e-30)),
            "analytical_s": analytical,
            "log10_measured_over_analytical": (
                math.log10(measured / analytical)
                if measured > 0 and analytical > 0 else None),
            "intercept_s": fit.intercept_s,
            "rate": fit.rate,
            "rate_ci95_rel": fit.rate_ci95_rel,
            "heldout_max_rel_err": fit.heldout_max_rel_err,
            "heldout_mean_rel_err": fit.heldout_mean_rel_err,
            "n_train": fit.n_train,
            "n_heldout": fit.n_heldout,
        }
    return report


def error_bar_rel(table: CalibrationTable) -> float:
    """Worst held-out relative residual across the table's fits — the ±
    on every co-sim headline replayed through this calibration."""
    return table.error_bar_rel
