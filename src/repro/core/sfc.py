"""Space-filling curves over 2-D chiplet grids (paper §3.2).

The paper places sequentially-communicating chiplets (input-embedding and
feed-forward pipelines on the ReRAM macro) along a space-filling curve so that
consecutive pipeline stages are physically adjacent on the interposer. This
module provides the classical curves it cites — Hilbert, Morton/Z, row-major
boustrophedon ("snake"), and the onion curve — as bijections

    order: {0..n-1} -> grid coordinates (x, y)

plus locality metrics used by the NoI optimizer and by ``core.hetero`` to
order TPU mesh devices.

All curves return an ``(n, 2)`` int array of (x, y) positions such that curve
step ``i`` maps to position ``pos[i]``; every grid cell appears exactly once
(bijectivity is property-tested in ``tests/test_sfc.py``).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "hilbert_curve",
    "morton_curve",
    "boustrophedon_curve",
    "onion_curve",
    "curve_positions",
    "locality_score",
    "mean_hop_stretch",
    "CURVES",
]


# ---------------------------------------------------------------------------
# Hilbert curve
# ---------------------------------------------------------------------------

def _hilbert_d2xy(order: int, d: int) -> tuple[int, int]:
    """Convert distance-along-curve ``d`` to (x, y) for a 2^order x 2^order grid."""
    t = d
    x = y = 0
    s = 1
    n = 1 << order
    while s < n:
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        # rotate quadrant
        if ry == 0:
            if rx == 1:
                x = s - 1 - x
                y = s - 1 - y
            x, y = y, x
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert_curve(width: int, height: int) -> np.ndarray:
    """Hilbert ordering of a ``width x height`` grid.

    For non-power-of-two or non-square grids we walk the Hilbert curve of the
    enclosing 2^k square and drop positions outside the grid — this preserves
    the visiting order and (approximately) the locality of the true curve,
    which is the standard "pruned Hilbert" construction.
    """
    if width <= 0 or height <= 0:
        raise ValueError("grid dims must be positive")
    side = max(width, height)
    order = max(1, int(np.ceil(np.log2(side))))
    n = 1 << order
    out = []
    for d in range(n * n):
        x, y = _hilbert_d2xy(order, d)
        if x < width and y < height:
            out.append((x, y))
    return np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------------------------
# Morton (Z-order) curve
# ---------------------------------------------------------------------------

def _deinterleave(z: int) -> tuple[int, int]:
    x = y = 0
    for bit in range(32):
        x |= ((z >> (2 * bit)) & 1) << bit
        y |= ((z >> (2 * bit + 1)) & 1) << bit
    return x, y


def morton_curve(width: int, height: int) -> np.ndarray:
    """Z-order ordering (pruned to the grid)."""
    if width <= 0 or height <= 0:
        raise ValueError("grid dims must be positive")
    side = max(width, height)
    order = max(1, int(np.ceil(np.log2(side))))
    n = 1 << order
    out = []
    for z in range(n * n):
        x, y = _deinterleave(z)
        if x < width and y < height:
            out.append((x, y))
    return np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------------------------
# Boustrophedon ("snake") curve — row-major with alternating direction.
# Every consecutive pair is Manhattan-adjacent; this is the curve used for
# the ReRAM macro in the reference implementation because it is optimal for
# purely linear pipelines.
# ---------------------------------------------------------------------------

def rowmajor_curve(width: int, height: int) -> np.ndarray:
    """Row-major raster order — the non-locality-preserving baseline the
    paper's SFC argument is made against (long jumps at row ends)."""
    if width <= 0 or height <= 0:
        raise ValueError("grid dims must be positive")
    return np.asarray([(x, y) for y in range(height) for x in range(width)],
                      dtype=np.int64)


def boustrophedon_curve(width: int, height: int) -> np.ndarray:
    if width <= 0 or height <= 0:
        raise ValueError("grid dims must be positive")
    out = []
    for y in range(height):
        xs = range(width) if y % 2 == 0 else range(width - 1, -1, -1)
        for x in xs:
            out.append((x, y))
    return np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------------------------
# Onion curve — concentric shells from the boundary inward (Xu et al., ICDE'18
# cited by the paper). Good clustering for range queries; we include it as a
# candidate ordering in the MOO search space.
# ---------------------------------------------------------------------------

def onion_curve(width: int, height: int) -> np.ndarray:
    if width <= 0 or height <= 0:
        raise ValueError("grid dims must be positive")
    visited = np.zeros((width, height), dtype=bool)
    out = []
    x0, y0, x1, y1 = 0, 0, width - 1, height - 1
    while x0 <= x1 and y0 <= y1:
        for x in range(x0, x1 + 1):
            out.append((x, y0))
        for y in range(y0 + 1, y1 + 1):
            out.append((x1, y))
        if y1 > y0:
            for x in range(x1 - 1, x0 - 1, -1):
                out.append((x, y1))
        if x1 > x0:
            for y in range(y1 - 1, y0, -1):
                out.append((x0, y))
        x0 += 1
        y0 += 1
        x1 -= 1
        y1 -= 1
    del visited
    return np.asarray(out, dtype=np.int64)


CURVES = {
    "hilbert": hilbert_curve,
    "rowmajor": rowmajor_curve,
    "morton": morton_curve,
    "boustrophedon": boustrophedon_curve,
    "onion": onion_curve,
}


def curve_positions(name: str, width: int, height: int) -> np.ndarray:
    try:
        fn = CURVES[name]
    except KeyError:  # pragma: no cover - defensive
        raise ValueError(f"unknown curve {name!r}; have {sorted(CURVES)}") from None
    return fn(width, height)


# ---------------------------------------------------------------------------
# Locality metrics
# ---------------------------------------------------------------------------

def locality_score(pos: np.ndarray) -> float:
    """Mean Manhattan distance between curve-consecutive grid cells.

    1.0 is optimal (every consecutive pair adjacent) — boustrophedon achieves
    it; Hilbert achieves it on power-of-two squares; Morton does not.
    """
    pos = np.asarray(pos)
    d = np.abs(np.diff(pos, axis=0)).sum(axis=1)
    return float(d.mean())


def mean_hop_stretch(pos: np.ndarray, window: int = 4) -> float:
    """Average Manhattan distance between cells ``<= window`` apart on the
    curve, normalised by their curve distance. Lower = better clustering.
    """
    pos = np.asarray(pos)
    n = len(pos)
    total, count = 0.0, 0
    for k in range(1, window + 1):
        d = np.abs(pos[k:] - pos[:-k]).sum(axis=1)
        total += float((d / k).sum())
        count += n - k
    return total / max(count, 1)
