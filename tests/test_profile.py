"""Calibration plane: micro-timer determinism, cost-model fits and their
pinned residual discipline, the versioned table, the explicit ``calib=``
opt-in (default path bit-identical), and the engine tracer contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.core.cosim import cosim_from_engine, mix_from_stats
from repro.core.simulator import CALIB, simulate_generation
from repro.core.traffic import Workload
from repro.models import transformer as T
from repro.profile.bench import Sample, Timing, measure
from repro.profile.calibrate import (PLANE_MAP, error_bar_rel,
                                     measured_calib, phase_error_report)
from repro.profile.costmodel import (CALIBRATION_VERSION, DEFAULT_TERMS,
                                     CalibrationTable, build_table,
                                     fit_phase, fit_samples)
from repro.serving.engine import EngineConfig, ServingEngine


# ---------------------------------------------------------------------------
# bench.measure: the micro-timer
# ---------------------------------------------------------------------------

class FakeClock:
    """Deterministic clock: each call advances by the next scripted dt."""

    def __init__(self, dts):
        self.t, self.dts = 0.0, list(dts)
        self.i = 0

    def __call__(self):
        # measure() calls the clock twice per timed call (start/stop):
        # advance only on the stop edge
        if self.i % 2 == 1:
            self.t += self.dts.pop(0)
        self.i += 1
        return self.t


def test_measure_separates_compile_from_steady_state():
    clk = FakeClock([1.0, 0.25, 0.5, 0.125])   # warmup, then 3 repeats
    calls = []
    t = measure(lambda: calls.append(1), warmup=1, repeat=3,
                clock=clk, sync=None)
    assert len(calls) == 4                     # 1 warmup + 3 timed
    assert t.compile_s == 1.0                  # first call absorbs compile
    assert t.times_s == (0.25, 0.5, 0.125)
    assert t.best_s == 0.125                   # min-of-k steady state
    assert t.median_s == 0.25


def test_measure_rejects_degenerate_loops():
    with pytest.raises(ValueError):
        measure(lambda: None, warmup=0, repeat=3, sync=None)
    with pytest.raises(ValueError):
        measure(lambda: None, warmup=1, repeat=0, sync=None)


def test_timing_best_and_median():
    t = Timing(compile_s=1.0, times_s=(0.5, 0.25))
    assert t.best_s == 0.25
    assert t.median_s == 0.5       # upper median on even-length windows


# ---------------------------------------------------------------------------
# costmodel: fits, holdout determinism, fallbacks, versioned table
# ---------------------------------------------------------------------------

def _mk(kind, xs, ys, *, flops=None):
    """Synthetic sample grid: bytes regressor = xs, seconds = ys."""
    return [Sample(kind, "synthetic", {"i": i}, x,
                   (flops[i] if flops else 2.0 * x), y, 0.0)
            for i, (x, y) in enumerate(zip(xs, ys))]


def test_fit_phase_recovers_exact_affine_model():
    xs = [1e6 * k for k in range(1, 10)]
    ys = [5e-5 + x / 2e9 for x in xs]           # 50us launch + 2 GB/s
    f = fit_phase(_mk("decode_attn", xs, ys))
    assert f.term == "bytes"
    assert f.intercept_s == pytest.approx(5e-5, rel=1e-9)
    assert f.rate == pytest.approx(2e9, rel=1e-9)
    assert f.r2 == pytest.approx(1.0)
    assert f.n_heldout == 3 and f.n_train == 6
    assert f.heldout_max_rel_err == pytest.approx(0.0, abs=1e-9)
    assert f.predict(4e6) == pytest.approx(5e-5 + 4e6 / 2e9)
    # flops_rate converts through the mean FLOPs-per-byte of the grid
    assert f.flops_rate == pytest.approx(2.0 * f.rate)


def test_fit_phase_holdout_split_is_deterministic():
    xs = [1e6 * k for k in range(1, 10)]
    ys = [5e-5 + x / 2e9 for x in xs]
    a = fit_phase(_mk("decode_attn", xs, ys))
    # shuffled input, same split: ordering is by term magnitude, not by
    # arrival order
    idx = [7, 2, 5, 0, 8, 1, 6, 3, 4]
    b = fit_phase(_mk("decode_attn", [xs[i] for i in idx],
                      [ys[i] for i in idx]))
    assert a == b
    # small grids (< 2*holdout_every) train on everything
    c = fit_phase(_mk("decode_attn", xs[:5], ys[:5]))
    assert c.n_heldout == 0 and c.n_train == 5


def test_fit_phase_negative_intercept_refits_through_origin():
    # noise tilts OLS to a negative intercept; the refit must go through
    # the origin, not clamp-and-keep the stale slope
    xs = [1.0, 2.0, 3.0]
    ys = [0.9, 2.1, 3.3]                        # OLS intercept < 0
    f = fit_phase(_mk("decode_attn", xs, ys))
    assert f.intercept_s == 0.0
    sxx = sum(x * x for x in xs)
    slope = sum(x * y for x, y in zip(xs, ys)) / sxx
    assert f.rate == pytest.approx(1.0 / slope)


def test_fit_phase_latency_floor_fallback():
    # flat times across a growing grid (vectorised-away batch): the fit
    # keeps the floor as intercept and an effectively infinite rate
    xs = [1e6, 2e6, 4e6]
    ys = [1e-3, 1e-3, 1e-3]
    f = fit_phase(_mk("executor_step", xs, ys))
    assert f.intercept_s == pytest.approx(1e-3, rel=0.35)
    assert f.predict(4e6) == pytest.approx(1e-3, rel=0.05)
    assert f.heldout_max_rel_err < 0.05


def test_fit_phase_input_validation():
    with pytest.raises(ValueError):
        fit_phase([])
    mixed = _mk("decode_attn", [1.0], [1.0]) + _mk("prefill_attn",
                                                   [1.0], [1.0])
    with pytest.raises(ValueError):
        fit_phase(mixed)
    with pytest.raises(ValueError):
        fit_phase(_mk("decode_attn", [1.0, 2.0], [1.0, 2.0]),
                  term="joules")


def test_fit_samples_groups_by_kind_and_table_roundtrips():
    xs = [1e6 * k for k in range(1, 7)]
    samples = (_mk("decode_attn", xs, [x / 1e9 for x in xs])
               + _mk("prefill_attn", xs, [1e-4 + x / 5e9 for x in xs]))
    fits = fit_samples(samples)
    assert set(fits) == {"decode_attn", "prefill_attn"}
    # prefill fits against flops (= 2*bytes in the synthetic grid)
    assert fits["prefill_attn"].term == "flops"

    table = build_table(samples, backend="cpu", interpret=True,
                        meta={"note": "synthetic"})
    again = CalibrationTable.from_json(table.to_json())
    assert again.fits == table.fits
    assert again.backend == "cpu" and again.interpret is True
    assert again.meta == {"note": "synthetic"}
    assert table.error_bar_rel == max(f.heldout_max_rel_err
                                      for f in table.fits.values())
    assert error_bar_rel(table) == table.error_bar_rel


def test_table_version_mismatch_raises():
    d = build_table(_mk("decode_attn", [1e6, 2e6], [1e-3, 2e-3]),
                    backend="cpu", interpret=True).to_json()
    d["version"] = CALIBRATION_VERSION + 1
    with pytest.raises(ValueError, match="re-run the profiler"):
        CalibrationTable.from_json(d)


def test_sample_json_roundtrip():
    s = Sample("decode_attn", "bert-base", {"batch": 2}, 1e6, 2e6,
               3.5e-4, 1.2e-2)
    assert Sample.from_json(s.to_json()) == s


# ---------------------------------------------------------------------------
# calibrate: the explicit opt-in seam
# ---------------------------------------------------------------------------

def _synthetic_table():
    xs = [1e6 * k for k in range(1, 7)]
    samples = []
    for kind in DEFAULT_TERMS:
        rate = {"prefill_attn": 5e9}.get(kind, 1e9)
        term = DEFAULT_TERMS[kind]
        ys = [1e-5 + x / rate for x in xs]
        if term == "flops":     # seconds must follow the fitted regressor
            samples += _mk(kind, [x / 2.0 for x in xs], ys,
                           flops=[x for x in xs])
        else:
            samples += _mk(kind, xs, ys)
    return build_table(samples, backend="cpu", interpret=True)


def test_measured_calib_is_opt_in_and_default_untouched():
    table = _synthetic_table()
    mcal = measured_calib(table)
    # the default constants object is never mutated
    assert CALIB.sm_efficiency == dataclasses.replace(CALIB).sm_efficiency
    assert mcal is not CALIB
    assert mcal.sm_efficiency != CALIB.sm_efficiency
    assert mcal.reram_fill != CALIB.reram_fill
    assert 0.0 < mcal.sm_efficiency <= 1.0
    assert 0.0 < mcal.reram_fill <= 1.0

    # default-path bit-identity: simulate_generation without calib= is
    # unchanged by the existence of a table
    w = Workload.from_config(get_config("gpt-j"), seq_len=128)
    base = simulate_generation(w, 64, 128, 16, arch="2.5D-HI")
    again = simulate_generation(w, 64, 128, 16, arch="2.5D-HI",
                                calib=CALIB)
    assert (base.ttft_s, base.decode_step_s, base.decode_tok_s) \
        == (again.ttft_s, again.decode_step_s, again.decode_tok_s)
    measured = simulate_generation(w, 64, 128, 16, arch="2.5D-HI",
                                   calib=mcal)
    assert measured.decode_step_s != base.decode_step_s


def test_measured_calib_partial_table_keeps_base_constants():
    # a table with only SM kinds must leave reram_fill at the base value
    xs = [1e6 * k for k in range(1, 7)]
    t = build_table(_mk("decode_attn", xs, [x / 1e9 for x in xs]),
                    backend="cpu", interpret=True)
    mcal = measured_calib(t)
    assert mcal.sm_efficiency != CALIB.sm_efficiency
    assert mcal.reram_fill == CALIB.reram_fill


def test_phase_error_report_covers_every_fit():
    table = _synthetic_table()
    rep = phase_error_report(table)
    assert set(rep) == set(table.fits)
    for kind, row in rep.items():
        assert row["plane"] == PLANE_MAP[kind]
        assert row["measured_s"] > 0 and row["analytical_s"] > 0
        # log-gap is finite and consistent with the two times
        expect = np.log10(row["measured_s"] / row["analytical_s"])
        assert row["log10_measured_over_analytical"] == pytest.approx(expect)


# ---------------------------------------------------------------------------
# engine tracer: dormant by default, measured step times when on
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_pair():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(5)]
    engines = []
    for trace in (False, True):
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=3, kv_len=48, max_new_tokens=6, impl="ref",
            trace=trace))
        for p in prompts:
            eng.submit(p)
        eng.run_until_drained()
        engines.append(eng)
    return engines


def test_tracer_keys_dormant_unless_enabled(traced_pair):
    off, on = traced_pair
    s_off, s_on = off.stats(), on.stats()
    assert not any(k.startswith("trace_") for k in s_off)
    for key in ("trace_iterations", "trace_prefill_s", "trace_decode_s",
                "trace_d2h_s", "trace_decode_step_s",
                "trace_decode_step_p50_s", "trace_decode_step_p95_s"):
        assert key in s_on, key
    assert s_on["trace_iterations"] == len(on.trace) >= 1
    assert s_on["trace_decode_step_s"] > 0


def test_tracer_does_not_perturb_outputs_or_stats(traced_pair):
    off, on = traced_pair
    outs_off = sorted((r.uid, tuple(r.output)) for r in off.finished)
    outs_on = sorted((r.uid, tuple(r.output)) for r in on.finished)
    assert outs_off == outs_on
    s_off, s_on = off.stats(), on.stats()
    # identical key surface apart from trace_* (wall-clock-derived values
    # like tokens_per_s legitimately differ between two real drains) and
    # identical deterministic counters
    assert {k for k in s_on if not k.startswith("trace_")} == set(s_off)
    for key in ("requests", "decode_steps", "prefill_tokens",
                "decode_tokens"):
        if key in s_off:
            assert s_on[key] == s_off[key], key


def test_mix_and_cosim_carry_measured_step_times(traced_pair):
    off, on = traced_pair
    mix_off = mix_from_stats(off.stats())
    mix_on = mix_from_stats(on.stats())
    assert mix_off.measured_step_s == 0.0      # tracing off -> all zero
    assert mix_on.measured_step_s > 0
    assert mix_on.measured_prefill_s > 0

    full = get_config("qwen2.5-3b")
    rec_off = cosim_from_engine(off, cfg=full, n_chiplets=64)
    rec_on = cosim_from_engine(on, cfg=full, n_chiplets=64)
    assert "measured_step_s" not in rec_off["mix"]
    assert rec_on["mix"]["measured_step_s"] == mix_on.measured_step_s
    # Plane-B replay itself is identical: measured wall-clock annotates,
    # never re-prices
    assert rec_off["archs"] == rec_on["archs"]
