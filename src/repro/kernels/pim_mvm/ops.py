"""jit'd dispatch wrapper + quantiser for the PIM-MVM kernel.

``quantize_weights`` is the "programming the crossbars" step: done once,
offline, per static weight matrix (the paper's weight-stationary claim);
``pim_mvm`` is the streaming execute step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pim_mvm import kernel as _kernel
from repro.kernels.pim_mvm.ref import pim_mvm_ref

XBAR = _kernel.XBAR


def quantize_weights(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(K, N) float -> (int8 values, (K/128, N/128) f32 per-tile scales).

    Symmetric per-crossbar-tile quantisation: each 128×128 tile gets one
    scale = max|w|/127 — the granularity a bit-sliced crossbar imposes
    (all cells in a crossbar share the DAC/ADC range).
    """
    K, N = w.shape
    if K % XBAR or N % XBAR:
        raise ValueError(f"weights {(K, N)} must tile {XBAR}x{XBAR} crossbars")
    t = w.astype(jnp.float32).reshape(K // XBAR, XBAR, N // XBAR, XBAR)
    t = t.transpose(0, 2, 1, 3)                      # (Kt, Nt, 128, 128)
    scales = jnp.max(jnp.abs(t), axis=(2, 3)) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    q = jnp.round(t / scales[:, :, None, None]).astype(jnp.int8)
    q = q.transpose(0, 2, 1, 3).reshape(K, N)
    return q, scales


def pim_mvm(x, wq, scales, *, impl: str = "auto", **blocks):
    """Quantised weight-stationary matmul.

    impl: ref | pallas | pallas_interpret | auto (pallas on TPU, else ref).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return pim_mvm_ref(x, wq, scales)
    if impl == "pallas":
        return _kernel.pim_mvm_pallas(x, wq, scales, **blocks)
    if impl == "pallas_interpret":
        return _kernel.pim_mvm_pallas(x, wq, scales, interpret=True, **blocks)
    raise ValueError(f"unknown impl {impl!r}")
