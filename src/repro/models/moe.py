"""Mixture-of-Experts FFN with static-shape capacity-sort dispatch.

The paper's extreme "static weight" kernel class: expert weights are the
weight-stationary plane (ReRAM-macro analogue → expert-parallel sharding
over the ``model`` axis), while token dispatch is the dynamic many-to-few
traffic the NoI must carry (§3.2).

Dispatch is vmapped **per batch row** so the sort never crosses the
batch sharding axis: each row's S tokens are routed with an
argsort-by-expert + per-expert capacity, giving fully static shapes
(the GShard/Switch scheme without the O(T·E·C) one-hot blow-up).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.modules import activation, dense_init, init_mlp, apply_mlp
from repro.parallel import constrain


def init_moe(key, cfg, *, dtype=jnp.float32):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "experts": {
            "w_gate": dense_init(ks[1], (E, D, Fe), dtype),
            "w_up": dense_init(ks[2], (E, D, Fe), dtype),
            "w_down": dense_init(ks[3], (E, Fe, D), dtype, fan_in=Fe),
        },
    }
    if cfg.n_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, glu=True, mlp_bias=False)
        p["shared"] = init_mlp(ks[4], shared_cfg,
                               d_ff=cfg.n_shared_experts * Fe)
    return p


def _capacity(tokens: int, cfg) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(min(int(c), tokens), 1)


def _dispatch_row(x, gates, idx, E: int, C: int, k: int):
    """x (S, D); gates/idx (S, k) -> (buf (E*C, D), slot (S*k,), tok (S*k,),
    keep (S*k,), gate_sorted (S*k,))."""
    S, D = x.shape
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok = order // k
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(S * k) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].set(x[tok], mode="drop")
    gate_sorted = gates.reshape(-1)[order]
    return buf, slot, tok, keep, gate_sorted


def apply_moe(p, x, cfg, *, mode: str = "train"):
    """x (B, S, D) -> (B, S, D).

    Two dispatch paths:
    - capacity-sort einsum (default): fully static shapes, expert axis
      shardable over ``model`` (EP) — the dry-run / training path.  Tokens
      beyond an expert's capacity are dropped (standard GShard semantics).
    - dropless grouped-matmul (``ragged_dot``): exact, no drops — used for
      single-host decode (serving engine, CPU tests) where static EP
      sharding isn't in play and decode-vs-prefill consistency matters.
    """
    from repro.parallel.api import current_plan

    B, S, D = x.shape
    E, k, Fe = cfg.n_experts, cfg.top_k, cfg.d_ff_expert
    C = _capacity(S, cfg)
    dt = x.dtype
    act = activation(cfg.act)

    logits = (x @ p["router"]).astype(jnp.float32)       # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                 # (B, S, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    gates = gates.astype(dt)

    if mode in ("prefill", "decode") and current_plan() is None:
        # single-host serving: exact dropless path, so decode continues
        # prefill bit-for-bit (capacity drops would make them diverge)
        y = _apply_dropless(p, x, gates, idx, cfg)
        if "shared" in p:
            y = y + apply_mlp(p["shared"], x, cfg)
        return y

    if current_plan() is not None and S > 1:
        # sharded execution: GShard one-hot einsum dispatch — einsums
        # partition cleanly under SPMD where the sort/scatter path
        # materialises unsharded (B, E·C, D) buffers (measured: 2.5 GiB +
        # 2 GiB per layer on qwen3-moe train_4k)
        y = _apply_gshard(p, x, gates, idx, cfg)
        if "shared" in p:
            y = y + apply_mlp(p["shared"], x, cfg)
        return y

    buf, slot, tok, keep, gate_sorted = jax.vmap(
        lambda xr, gr, ir: _dispatch_row(xr, gr, ir, E, C, k))(x, gates, idx)
    xe = buf.reshape(B, E, C, D)
    xe = constrain(xe, "expert_buf")

    we = p["experts"]
    h = act(jnp.einsum("becd,edf->becf", xe, we["w_gate"].astype(dt))) * \
        jnp.einsum("becd,edf->becf", xe, we["w_up"].astype(dt))
    h = constrain(h, "expert_hidden")
    ye = jnp.einsum("becf,efd->becd", h, we["w_down"].astype(dt))
    ye = constrain(ye, "expert_buf")
    yflat = ye.reshape(B, E * C, D)

    def _combine_row(yf, slot_r, tok_r, keep_r, gate_r):
        gathered = yf[jnp.minimum(slot_r, E * C - 1)] * keep_r[:, None]
        return jnp.zeros((S, D), yf.dtype).at[tok_r].add(gathered * gate_r[:, None])

    y = jax.vmap(_combine_row)(yflat, slot, tok, keep, gate_sorted)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y


def _apply_gshard(p, x, gates, idx, cfg):
    """GShard-style dispatch: per-sequence-group one-hot dispatch/combine
    einsums with local capacity.  Groups are aligned to the sequence
    sharding (G = mesh model-axis size when it divides S), so the
    rank-cumsum is shard-local and every op partitions.

    x (B, S, D), gates/idx (B, S, k) -> (B, S, D)
    """
    from repro.parallel.api import current_plan

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    act = activation(cfg.act)

    plan = current_plan()
    G = 1
    if plan is not None:
        g = plan.mesh.shape.get("model", 1)
        if S % g == 0:
            G = g
    Sg = S // G
    Cg = _capacity(Sg, cfg)

    xg = x.reshape(B, G, Sg, D)
    eg = idx.reshape(B, G, Sg, k)
    wg = gates.reshape(B, G, Sg, k)

    # position-in-expert ranks, k slots processed in priority order
    onehot = jax.nn.one_hot(eg, E, dtype=jnp.float32)     # (B,G,Sg,k,E)
    # tokens before s (all k slots) + earlier slots at s
    cum_tok = jnp.cumsum(onehot.sum(3), axis=2) - onehot.sum(3)  # (B,G,Sg,E)
    cum_slot = jnp.cumsum(onehot, axis=3) - onehot               # (B,G,Sg,k,E)
    rank = cum_tok[:, :, :, None, :] + cum_slot                  # (B,G,Sg,k,E)
    keep = (rank < Cg) & (onehot > 0)
    rank = jnp.sum(rank * onehot, axis=-1)                       # (B,G,Sg,k)
    keepk = jnp.any(keep, axis=-1)                               # (B,G,Sg,k)

    oh_c = jax.nn.one_hot(rank.astype(jnp.int32), Cg, dtype=jnp.float32)
    # dispatch (B,G,Sg,k,E,Cg) — contracted immediately, never fully live
    disp = (onehot[..., None] * oh_c[..., None, :]
            * keepk[..., None, None].astype(jnp.float32))
    disp_sum = disp.sum(3).astype(dt)                            # (B,G,Sg,E,Cg)
    comb = (disp * wg[..., None, None].astype(jnp.float32)
            ).sum(3).astype(dt)                                  # (B,G,Sg,E,Cg)

    xe = jnp.einsum("bgsec,bgsd->begcd", disp_sum, xg)           # (B,E,G,Cg,D)
    xe = xe.reshape(B, E, G * Cg, D)
    xe = constrain(xe, "expert_buf")

    we = p["experts"]
    h = act(jnp.einsum("becd,edf->becf", xe, we["w_gate"].astype(dt))) * \
        jnp.einsum("becd,edf->becf", xe, we["w_up"].astype(dt))
    h = constrain(h, "expert_hidden")
    ye = jnp.einsum("becf,efd->becd", h, we["w_down"].astype(dt))
    ye = constrain(ye, "expert_buf").reshape(B, E, G, Cg, D)

    y = jnp.einsum("bgsec,begcd->bgsd", comb, ye)
    return y.reshape(B, S, D)


def _apply_dropless(p, x, gates, idx, cfg):
    """Exact MoE via sorted grouped matmul (jax.lax.ragged_dot) — the
    MegaBlocks-style dropless path: every selected (token, expert) pair is
    computed, no capacity, shapes static in B·S·k."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    act = activation(cfg.act)
    we = p["experts"]

    xf = x.reshape(B * S, D)
    flat_e = idx.reshape(-1)                         # (B*S*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok = order // k                                 # source token per slot
    xs = xf[tok]                                     # (B*S*k, D) sorted by e
    group_sizes = jnp.bincount(sorted_e, length=E).astype(jnp.int32)

    h = act(jax.lax.ragged_dot(xs, we["w_gate"].astype(dt), group_sizes)) * \
        jax.lax.ragged_dot(xs, we["w_up"].astype(dt), group_sizes)
    ys = jax.lax.ragged_dot(h, we["w_down"].astype(dt), group_sizes)
    gate_sorted = gates.reshape(-1)[order]
    y = jnp.zeros((B * S, D), dt).at[tok].add(ys * gate_sorted[:, None])
    return y.reshape(B, S, D)


def router_aux_loss(p, x, cfg):
    """Switch-style load-balance loss (used by the training loop)."""
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
