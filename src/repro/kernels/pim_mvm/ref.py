"""Pure-jnp oracle for the quantised weight-stationary MVM."""
from __future__ import annotations

import jax.numpy as jnp

XBAR = 128


def dequantize_ref(wq, scales):
    """(K, N) int8 + (K/128, N/128) f32 tile scales -> (K, N) f32."""
    full = jnp.repeat(jnp.repeat(scales, XBAR, axis=0), XBAR, axis=1)
    return wq.astype(jnp.float32) * full


def pim_mvm_ref(x, wq, scales):
    w = dequantize_ref(wq, scales)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
