"""Minitron-8B — width-pruned Nemotron-4 (squared-ReLU MLP, no GLU).
[arXiv:2407.14679; hf]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=256_000,
    rope_theta=10_000.0,
    act="relu2",
    glu=False,
    source="arXiv:2407.14679",
))
