"""Slot-pool layer: the slotted (optionally quantised) KV cache, the
per-slot decode state, and every piece of slot-lifecycle bookkeeping.

One :class:`SlotPool` owns everything whose lifetime is "a slot":

- the device KV cache built by ``models.transformer.init_cache`` —
  bf16 rows, or int8 / packed-int4 code + f32 scale leaves under
  ``kv_bits`` — sharded when a ``shard_ctx`` is provided
  (``parallel.sharding.cache_shardings``);
- the fused-path device state (last token, position, budget, liveness
  per slot, plus the threaded PRNG key);
- the lazily-created host-path arrays of the ``fused=False`` baseline;
- host bookkeeping: which ``Request`` occupies each slot, chunked-
  prefill progress (``prefilling``: slot → (next_prompt_pos, budget))
  and the anomaly-quarantine counters.

The engine allocates/frees slots through this object; the executor
transforms ``(cache, state)`` and hands them back; the checkpoint plane
serialises the pool through :meth:`array_tree` / :meth:`meta` and
restores it through :meth:`load_array_tree` / :meth:`load_meta` — the
engine's private fields are no longer part of the snapshot contract.
The array-tree layout (``cache/...``, ``state/...``, ``host/...`` flat
keys) is exactly the pre-layering snapshot format, so checkpoints
written by the monolithic engine restore bit-exactly through this API
(pinned by ``tests/test_serving_checkpoint.py``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T


class SlotPool:
    def __init__(self, cfg: ModelConfig, ecfg, *, shard_ctx=None):
        B, S = ecfg.max_batch, ecfg.kv_len
        self.cfg, self.ecfg = cfg, ecfg
        self.cache = T.init_cache(cfg, B, S, dtype=jnp.bfloat16,
                                  kv_bits=ecfg.kv_bits)
        if shard_ctx is not None:
            from repro.parallel.sharding import cache_shardings
            shardings = cache_shardings(
                jax.eval_shape(lambda: self.cache), shard_ctx)
            self.cache = jax.device_put(self.cache, shardings)

        # fused-path device-resident per-slot state
        self.state = {
            "tokens": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "budget": jnp.zeros((B,), jnp.int32),
            "live": jnp.zeros((B,), bool),
            "key": jax.random.PRNGKey(ecfg.seed),
        }
        # host bookkeeping: slot occupancy, chunked-prefill progress,
        # anomaly-quarantine counters
        self.slot_req: list = [None] * B
        self.prefilling: dict[int, tuple[int, int]] = {}
        self.anomalies: list[int] = [0] * B
        # host-path (fused=False) arrays, created on first admission
        self.host: Optional[dict[str, np.ndarray]] = None
        # draft-model speculation: a second slot-pool cache with the
        # *draft* config's geometry, maintained in lockstep with the
        # target cache by the executor's speculative step (absent for
        # self-speculation, which shares the target cache)
        self.draft_cache = None

    def init_draft(self, draft_cfg: ModelConfig) -> None:
        """Allocate the draft-model KV pool (same slot count / depth as the
        target pool; always fp — the draft is cheap by construction)."""
        self.draft_cache = T.init_cache(draft_cfg, self.ecfg.max_batch,
                                        self.ecfg.kv_len, dtype=jnp.bfloat16)

    # -- slot lifecycle ----------------------------------------------------
    def free_slots(self) -> list[int]:
        """Free slot indices, ascending (allocation order is index order —
        the pre-layering engine's behaviour, kept for bit-identity)."""
        return [i for i in range(self.ecfg.max_batch)
                if self.slot_req[i] is None]

    def occupied(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def decoding(self) -> list:
        """Requests in slots that are actively decoding (occupied and not
        mid-prefill) — the set a prefill burst would preempt."""
        return [r for i, r in enumerate(self.slot_req)
                if r is not None and i not in self.prefilling]

    def ensure_host(self) -> dict[str, np.ndarray]:
        if self.host is None:
            B = self.ecfg.max_batch
            self.host = {"slot_pos": np.zeros(B, np.int32),
                         "slot_budget": np.zeros(B, np.int32),
                         "last_token": np.zeros(B, np.int32)}
        return self.host

    def release(self, slot: int) -> None:
        """Free a slot whose request finished (continuous batching)."""
        self.slot_req[slot] = None

    def truncate(self, slot: int, keep_len: int) -> None:
        """Invalidate every cache entry of ``slot`` at positions >=
        ``keep_len`` (``pos`` leaves are the single source of validity, so
        flipping them to -1 is a complete logical rollback — stale k/v or
        code/scale rows behind an invalid ``pos`` are never attendable).
        Host-side sibling of the jitted speculative step's in-program
        rollback, used to truncate rejected tokens from a slot."""
        def cut(path, leaf):
            if str(getattr(path[-1], "key", "")) != "pos":
                return leaf
            row = leaf[:, slot]                       # (repeats, cap)
            row = jnp.where(row >= keep_len, -1, row)
            return leaf.at[:, slot].set(row)

        self.cache = jax.tree_util.tree_map_with_path(cut, self.cache)
        if self.draft_cache is not None:
            self.draft_cache = jax.tree_util.tree_map_with_path(
                cut, self.draft_cache)

    def valid_len(self, slot: int) -> int:
        """1 + the highest valid cache position of ``slot`` (0 = empty) —
        the committed-prefix length a rollback truncated the slot to."""
        longest = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.cache)[0]:
            if str(getattr(path[-1], "key", "")) == "pos":
                longest = max(longest, int(jnp.max(leaf[:, slot])) + 1)
        return longest

    def kill(self, slot: int) -> None:
        """Free slot ``slot`` and silence its device row so the decode
        sweep never advances a dead request again."""
        self.slot_req[slot] = None
        self.prefilling.pop(slot, None)
        self.anomalies[slot] = 0
        if self.ecfg.fused:
            self.state["live"] = self.state["live"].at[slot].set(False)
        elif self.host is not None:
            self.host["slot_budget"][slot] = 0

    # -- serialization API (repro.serving.checkpoint) ----------------------
    def array_tree(self) -> dict:
        """Every array leaf of the pool, in the snapshot tree layout
        (``cache``/``state`` and, once created, ``host``).  Leaves are
        the live device arrays — callers copy (``np.asarray``) before
        mutating or donating."""
        tree: dict = {"cache": self.cache, "state": self.state}
        if self.draft_cache is not None:
            tree["draft"] = self.draft_cache
        if self.host is not None:
            tree["host"] = dict(self.host)
        return tree

    def array_template(self, with_host: bool) -> dict:
        """A structure-matching template for ``ckpt.unflatten_tree`` —
        fresh zero host arrays when the snapshot carries them."""
        tree: dict = {"cache": self.cache, "state": self.state}
        if self.draft_cache is not None:
            tree["draft"] = self.draft_cache
        if with_host:
            B = self.ecfg.max_batch
            tree["host"] = {"slot_pos": np.zeros(B, np.int32),
                            "slot_budget": np.zeros(B, np.int32),
                            "last_token": np.zeros(B, np.int32)}
        return tree

    def load_array_tree(self, tree: dict) -> None:
        """Adopt restored leaves: device pytrees are re-placed on device,
        host arrays stay host-side numpy."""
        self.cache = jax.device_put(tree["cache"])
        self.state = jax.device_put(tree["state"])
        if "draft" in tree:
            self.draft_cache = jax.device_put(tree["draft"])
        if "host" in tree:
            self.host = {k: np.array(v) for k, v in tree["host"].items()}

    def meta(self) -> dict:
        """JSON-safe slot bookkeeping for the snapshot meta record."""
        return {
            "prefilling": [[int(s), int(start), int(budget)]
                           for s, (start, budget) in self.prefilling.items()],
            "slot_anomalies": list(self.anomalies),
        }

    def load_meta(self, prefilling, slot_anomalies) -> None:
        self.prefilling = {int(s): (int(start), int(budget))
                           for s, start, budget in prefilling}
        self.anomalies = list(slot_anomalies)
