"""Mamba2-130M — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                 # attention-free; the mamba block is the layer
    vocab_size=50_280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,           # d_inner = 1536
    ssm_head_dim=64,        # 24 SSD heads
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=256,
    use_rope=False,
    tie_embeddings=True,
    source="arXiv:2405.21060",
    notes="long_500k runs (O(1) state per token); attention plane "
          "inapplicable — see DESIGN.md §Arch-applicability",
))
