"""§Perf: flash-kernel-adjusted roofline for attention-heavy cells.

The dry-run lowers with ``impl='ref'`` (XLA attention: chunked, but every
(q_chunk × S_kv) logits/softmax tensor round-trips HBM).  On TPU the
serving path runs the Pallas flash kernel (kernels/flash_attention) whose
entire point — the same as the paper's fused score+softmax on SM chiplets
— is that score-class tensors live in VMEM only.

This tool measures the score-class HBM traffic directly from the lowered
HLO (trip-count-weighted tensors whose trailing dims are (q-chunk, S_kv)
shaped) and reports the roofline memory term with and without it:

    PYTHONPATH=src python -m benchmarks.perf_flash_adjust <arch> <shape>
"""
import json
import os
import re
import sys
from collections import defaultdict

from repro.roofline.hlo import (_CALL_ATTR_RE, _parse_shape,
                                _split_computations, analyze_hlo_text)
from repro.roofline.analysis import V5E

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def rl_bytes(rec) -> float:
    return rec["roofline"]["hbm_bytes_per_dev"]


def _trip_multipliers(text, cost):
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}
    mult = {}

    def walk(name, m):
        if name in mult and mult[name] >= m:
            return
        mult[name] = max(mult.get(name, 0), m)
        c = by_name.get(name)
        if c is None:
            return
        for op in c.ops:
            for attr in _CALL_ATTR_RE.finditer(op.body):
                sub = attr.group(1)
                if sub == name:
                    continue
                k = m * (cost.trip_counts.get(sub, 1)
                         if op.opcode == "while" else 1)
                walk(sub, k)

    entry = [c for c in comps if c.is_entry][0]
    walk(entry.name, 1)
    return comps, mult


def score_class_bytes(text, cost, skv_set: set, *, qmin: int = 128) -> float:
    """Trip-weighted HBM bytes of score-class tensors: fusion/dot outputs
    whose trailing two dims are (q_chunk, S_kv) for an S_kv value implied
    by the cell's config (full, windowed, or axis-sharded variants) — the
    attention logits / probabilities / masks the flash kernel keeps in
    VMEM."""
    comps, mult = _trip_multipliers(text, cost)
    total = 0.0
    for c in comps:
        m = mult.get(c.name, 0)
        if m == 0:
            continue
        for op in c.ops:
            if op.opcode not in ("fusion", "dot", "broadcast", "convert"):
                continue
            b, dt, dims = _parse_shape(op.out_shape)
            if len(dims) < 2 or b <= 0:
                continue
            if dims[-1] in skv_set and dims[-2] >= qmin:
                total += b * m
    return total


def skv_values(arch: str, shape: str) -> set:
    """S_kv dims a score tensor can have in this cell: full / windowed
    sequence, divided by the possible shard factors — excluding dims that
    collide with the model's feature dims."""
    from repro.config import SHAPES, get_config

    cfg = get_config(arch)
    S = SHAPES[shape].seq_len
    base = {S}
    if cfg.window:
        base.add(cfg.window)
    out = set()
    for s in base:
        for div in (1, 2, 16, 32):
            if s % div == 0:
                out.add(s // div)
    exclude = {cfg.d_model, cfg.d_ff, cfg.d_ff_expert, cfg.vocab_size,
               cfg.head_dim, cfg.d_model // max(cfg.n_heads, 1)}
    return {s for s in out if s not in exclude and s >= 256}


def run(arch: str, shape: str, mesh: str = "single", verbose=True) -> dict:
    jpath = os.path.join(DRYRUN, f"{arch}__{shape}__{mesh}.json")
    hpath = jpath.replace(".json", ".hlo.txt")
    rec = json.load(open(jpath))
    text = open(hpath).read()
    cost = analyze_hlo_text(text, num_devices=rec["n_devices"])
    score_b = min(score_class_bytes(text, cost, skv_values(arch, shape)),
                  0.95 * rl_bytes(rec))
    rl = rec["roofline"]
    mem_flash = max(rl["hbm_bytes_per_dev"] - score_b, 0.0) / V5E.hbm_bw
    out = {
        "arch": arch, "shape": shape, "mesh": mesh,
        "compute_s": rl["compute_s"],
        "memory_s_ref": rl["memory_s"],
        "score_class_gib": score_b / 2**30,
        "memory_s_flash": mem_flash,
        "collective_s": rl["collective_s"],
        "step_s_ref": max(rl["compute_s"], rl["memory_s"], rl["collective_s"]),
        "step_s_flash": max(rl["compute_s"], mem_flash, rl["collective_s"]),
    }
    out["speedup"] = out["step_s_ref"] / out["step_s_flash"]
    bound = max(("compute", out["compute_s"]), ("memory", out["memory_s_flash"]),
                ("collective", out["collective_s"]), key=lambda t: t[1])[0]
    out["bound_after"] = bound
    if verbose:
        print(f"{arch} × {shape} × {mesh}:")
        print(f"  baseline (XLA ref attention): memory={out['memory_s_ref']:.3f}s "
              f"step={out['step_s_ref']:.3f}s")
        print(f"  score-class HBM traffic: {out['score_class_gib']:.1f} GiB/dev")
        print(f"  flash-adjusted: memory={out['memory_s_flash']:.3f}s "
              f"step={out['step_s_flash']:.3f}s "
              f"({out['speedup']:.2f}x, now {bound}-bound)")
    return out


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-27b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "prefill_32k"
    mesh = sys.argv[3] if len(sys.argv) > 3 else "single"
    run(arch, shape, mesh)
