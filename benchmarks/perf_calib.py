"""Measured-cost calibration benchmark: profile the real Pallas kernels and
the jitted executor program, fit Plane-B rate constants from the timings,
and pin the held-out residuals that become the error bar on every NoI claim.

Pipeline (all of ``repro.profile``):

1. **profile** — ``kernel_samples`` times decode attention (fp / kv8 / kv4),
   segmented prefill and the fused dequant-matmul across a zoo × batch ×
   KV-position grid; ``executor_samples`` times the engine's jitted
   ``fused_step`` end to end.  Warm-up (compile) time is separated from
   min-of-k steady state.
2. **fit** — ``build_table`` least-squares fits per-phase time as an affine
   model in the ``traffic.py`` byte/FLOP terms (intercept = launch
   overhead, slope = effective rate) with a deterministic held-out split;
   the residuals and 95% CIs ship inside the versioned
   ``CalibrationTable``.
3. **replay** — ``measured_calib`` maps the fitted rates onto the
   simulator's ``Calib`` constants (explicit ``calib=`` opt-in: the
   default analytical path stays bit-identical) and the same zoo model is
   co-simulated under both, reporting the per-phase analytical-vs-measured
   error (``phase_error_report``).
4. **trace** — a reduced ``ServingEngine`` drain with
   ``EngineConfig(trace=True)`` records per-iteration prefill/decode/d2h
   wall-clock, and ``cosim_from_engine`` carries the measured step times
   alongside the measured episode mix.

The schema pins ``heldout_max_rel_err <= tolerance_rel`` for every fitted
phase: ``tolerance_rel`` is 0.75 under interpret-mode Pallas on CPU (the
interpreter's per-block overhead leaves real scatter even after the
single-block measurement design) and 0.5 on compiled backends.  A fit
drifting past the pin is a calibration regression, not noise.

    PYTHONPATH=src python -m benchmarks.perf_calib [--smoke]

Results: ``experiments/BENCH_calib.json`` (``BENCH_calib_smoke.json`` with
``--smoke`` so CI never clobbers the recorded full run); rendered by
``benchmarks/report.py`` (per-phase error bars + co-sim headlines ±
calibration error).
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

# every kind the profiler must cover — the fits Plane-B replay draws on
KINDS = ("decode_attn", "decode_attn_kv8", "decode_attn_kv4",
         "prefill_attn", "dequant_matmul", "executor_step")

# pinned held-out relative tolerance on the fitted cost models: interpret
# mode (CPU Pallas interpreter) carries more scatter than compiled kernels
TOLERANCE_INTERPRET = 0.75
TOLERANCE_COMPILED = 0.5

_FIT_KEYS = {"kind", "term", "intercept_s", "rate", "rate_ci95_rel", "r2",
             "n_train", "n_heldout", "heldout_max_rel_err",
             "heldout_mean_rel_err", "flops_per_unit", "ref_term",
             "ref_seconds"}
_PHASE_KEYS = {"plane", "term", "ref_term", "measured_s",
               "fit_rel_err_at_ref", "analytical_s",
               "log10_measured_over_analytical", "intercept_s", "rate",
               "rate_ci95_rel", "heldout_max_rel_err",
               "heldout_mean_rel_err", "n_train", "n_heldout"}
_COSIM_KEYS = {"ttft_ms", "decode_step_ms", "decode_tok_s"}
_TRACE_KEYS = {"trace_iterations", "trace_prefill_s", "trace_decode_s",
               "trace_d2h_s", "trace_decode_step_s",
               "trace_decode_step_p50_s", "trace_decode_step_p95_s"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_calib.json record shape (CI bit-rot gate)."""
    for key in ("bench", "backend", "interpret", "smoke", "tolerance_rel",
                "n_samples", "table", "error_bar_rel", "phase_errors",
                "calib", "cosim", "engine_trace"):
        assert key in rec, f"missing top-level key {key!r}"
    tol = rec["tolerance_rel"]
    table = rec["table"]
    assert table["version"] == 1, f"stale table version {table['version']}"
    fits = table["fits"]
    missing_kinds = set(KINDS) - set(fits)
    assert not missing_kinds, f"unfitted kinds {missing_kinds}"
    for kind, fit in fits.items():
        missing = _FIT_KEYS - set(fit)
        assert not missing, f"fit {kind!r} missing {missing}"
        assert fit["rate"] > 0, f"fit {kind!r} has non-positive rate"
        # THE pin: the fitted cost model must reproduce held-out measured
        # phase times within the documented tolerance
        assert fit["heldout_max_rel_err"] <= tol, \
            f"fit {kind!r} held-out rel err {fit['heldout_max_rel_err']:.3f}" \
            f" exceeds the pinned tolerance {tol}"
    assert 0 < rec["error_bar_rel"] <= tol, \
        f"error bar {rec['error_bar_rel']} outside (0, {tol}]"
    for kind in KINDS:
        row = rec["phase_errors"][kind]
        missing = _PHASE_KEYS - set(row)
        assert not missing, f"phase_errors {kind!r} missing {missing}"
        assert row["measured_s"] > 0 and row["analytical_s"] > 0
    cal = rec["calib"]
    for key in ("sm_efficiency", "reram_fill"):
        assert cal["measured"][key] > 0
        # the opt-in must do something: measured constants differ from the
        # Table-4-anchored defaults it leaves untouched
        assert cal["measured"][key] != cal["default"][key], \
            f"measured calib {key} identical to the analytical default"
    for variant in ("default", "measured"):
        row = rec["cosim"][variant]
        missing = _COSIM_KEYS - set(row)
        assert not missing, f"cosim {variant!r} missing {missing}"
        assert row["decode_step_ms"] > 0
    tr = rec["engine_trace"]
    missing = _TRACE_KEYS - set(tr)
    assert not missing, f"engine_trace missing {missing}"
    assert tr["trace_iterations"] >= 1
    assert tr["trace_decode_step_s"] > 0
    assert tr["mix_measured_step_s"] > 0, \
        "cosim_from_engine lost the traced step time"


def collect_samples(*, smoke: bool, seed: int = 0) -> list:
    """Run the profiling grids.  Smoke keeps one arch but still gives every
    kind ≥6 points so the held-out split engages (executor stays at 3 —
    the latency-floor fit pins its residuals on the training points)."""
    from repro.profile.bench import executor_samples, kernel_samples

    # qmm shapes stay <=512 on every axis: that keeps the interpret-mode
    # invocation single-block, where time is affine in the byte term
    qmm = dict(qmm_shapes=((128, 256), (256, 256), (256, 512),
                           (512, 512), (128, 512), (512, 256)),
               qmm_m=32, qmm_bits=(8,))
    archs = ("bert-base",) if smoke else ("bert-base", "gpt-j")
    kv_lens = (256, 512, 1024) if smoke else (256, 512, 768, 1024)
    repeat = 3 if smoke else 5
    samples = kernel_samples(
        archs, batches=(1, 2), kv_lens=kv_lens, kv_bits=(0, 8, 4),
        prefill_lens=(256, 384, 512), seg_len=64,
        qmm_shapes=(), repeat=repeat, seed=seed)
    # the tiny matmuls sit closest to the timer's noise floor — always
    # take 5 steady-state repeats for them (min-of-k tightens fast)
    samples += kernel_samples(archs, batches=(), kv_lens=(), kv_bits=(),
                              prefill_lens=(), repeat=5, seed=seed, **qmm)
    # the executor program is latency-bound on the reduced config: chain
    # 8 steps per timed call (see bench.executor_samples) and always take
    # 5 repeats — each point builds its own engine, so min-of-k is the
    # only defence against build-to-build scheduler noise
    samples += executor_samples(("bert-base",), batches=(1, 2, 4),
                                kv_len=128, prompt_len=16,
                                repeat=5, seed=seed)
    return samples


def cosim_delta(table, *, arch: str, chiplets: int, prompt_len: int,
                gen_len: int, batch: int) -> tuple[dict, dict]:
    """Co-simulate one zoo model's generation episode under the default
    (Table-4-anchored) constants and under the measured calibration —
    the analytical-vs-measured replay the error bars qualify."""
    from repro.config import get_config
    from repro.core.simulator import CALIB, simulate_generation
    from repro.core.traffic import Workload
    from repro.profile.calibrate import measured_calib

    mcal = measured_calib(table, n_chiplets=chiplets)
    w = Workload.from_config(get_config(arch), seq_len=prompt_len)

    def row(calib):
        g = simulate_generation(w, chiplets, prompt_len, gen_len,
                                arch="2.5D-HI", batch=batch, calib=calib)
        return {"ttft_ms": g.ttft_s * 1e3,
                "decode_step_ms": g.decode_step_s * 1e3,
                "decode_tok_s": g.decode_tok_s}

    default, measured = row(CALIB), row(mcal)
    cosim = {
        "model": arch, "chiplets": chiplets, "prompt_len": prompt_len,
        "gen_len": gen_len, "batch": batch,
        "default": default, "measured": measured,
        "decode_step_rel_delta": (measured["decode_step_ms"]
                                  / default["decode_step_ms"] - 1.0),
    }
    calinfo = {
        "default": {"sm_efficiency": CALIB.sm_efficiency,
                    "reram_fill": CALIB.reram_fill},
        "measured": {"sm_efficiency": mcal.sm_efficiency,
                     "reram_fill": mcal.reram_fill},
    }
    return cosim, calinfo


def run_engine_trace(arch: str, chiplets: int) -> dict:
    """Drain a traced reduced engine and show ``cosim_from_engine``
    carrying the measured per-step wall-clock next to the measured mix."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_config, reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=64, max_new_tokens=8, prefill_chunk=32,
        trace=True))
    rng = np.random.default_rng(0)
    for plen in (6, 10, 14, 10, 22, 6, 18, 10):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    stats = eng.stats()
    rec = cosim_from_engine(eng, cfg=get_config(arch), n_chiplets=chiplets)
    out = {k: stats[k] for k in stats if k.startswith("trace_")}
    out["mix_measured_step_s"] = rec["mix"]["measured_step_s"]
    out["mix_measured_prefill_s"] = rec["mix"]["measured_prefill_s"]
    out["mix_measured_d2h_s"] = rec["mix"]["measured_d2h_s"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grids; write BENCH_calib_smoke.json")
    ap.add_argument("--chiplets", type=int, default=64,
                    choices=(36, 64, 100))
    ap.add_argument("--cosim-arch", default="gpt-j")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_path = args.out or os.path.join(
        EXPERIMENTS,
        "BENCH_calib_smoke.json" if args.smoke else "BENCH_calib.json")

    import jax

    from repro.profile.bench import interpret_default
    from repro.profile.calibrate import phase_error_report
    from repro.profile.costmodel import build_table

    interp = interpret_default()
    print(f"# profiling (smoke={args.smoke}, interpret={interp}) ...")
    samples = collect_samples(smoke=args.smoke)
    print(f"# {len(samples)} samples; fitting ...")
    table = build_table(samples, meta={
        "smoke": args.smoke,
        "grid": sorted({s.kind for s in samples}),
        "archs": sorted({s.arch for s in samples}),
    })
    errors = phase_error_report(table, n_chiplets=args.chiplets)
    cosim, calinfo = cosim_delta(
        table, arch=args.cosim_arch, chiplets=args.chiplets,
        prompt_len=512, gen_len=128, batch=8)
    print("# tracing engine ...")
    trace = run_engine_trace(args.cosim_arch, args.chiplets)

    rec = {
        "bench": "calib",
        "backend": jax.default_backend(),
        "interpret": interp,
        "smoke": args.smoke,
        "tolerance_rel": (TOLERANCE_INTERPRET if interp
                          else TOLERANCE_COMPILED),
        "n_samples": len(samples),
        "samples": [s.to_json() for s in samples],
        "table": table.to_json(),
        "error_bar_rel": table.error_bar_rel,
        "phase_errors": errors,
        "calib": calinfo,
        "cosim": cosim,
        "engine_trace": trace,
    }
    check_schema(rec)

    for kind in KINDS:
        fit = table.fits[kind]
        print(f"  {kind:18s} rate={fit.rate:.3e}/s  "
              f"intercept={fit.intercept_s * 1e6:7.1f}us  "
              f"heldout_max={fit.heldout_max_rel_err:.3f}  r2={fit.r2:.3f}")
    print(f"# error bar ±{100 * rec['error_bar_rel']:.1f}%  "
          f"(pinned tolerance {rec['tolerance_rel']})")
    print(f"# cosim {args.cosim_arch}: decode step "
          f"{cosim['default']['decode_step_ms']:.3f}ms analytical vs "
          f"{cosim['measured']['decode_step_ms']:.3f}ms measured-calib "
          f"({100 * cosim['decode_step_rel_delta']:+.1f}%)")

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {os.path.relpath(out_path)}")


if __name__ == "__main__":
    main()
