"""Shared building blocks: norms, activations, RoPE, MLPs, init helpers."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel import constrain
from repro.quant.ops import qdense

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (always computed in f32)
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(key, cfg, width=None):
    d = width or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}  # rmsnorm stores (scale-1)


def apply_norm(p, x):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions, dim: int, theta: float):
    """positions (...,) int -> cos/sin of shape (..., dim//2), f32."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    cos, sin = rope_angles(positions, hd, theta)  # (B, S, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, dim: int):
    """Whisper/BERT-style absolute sinusoidal embedding, (..., dim) f32."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense FFN) — the paper's "static / ReRAM-macro" kernel class
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_in=None, d_ff=None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.glu:
        p["w_gate"] = dense_init(ks[0], (d, f), jnp.float32)
        p["w_up"] = dense_init(ks[1], (d, f), jnp.float32)
    else:
        p["w_up"] = dense_init(ks[1], (d, f), jnp.float32)
    p["w_down"] = dense_init(ks[2], (f, d), jnp.float32, fan_in=f)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), jnp.float32)
        p["b_down"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_mlp(p, x, cfg):
    act = activation(cfg.act)
    dt = x.dtype
    if cfg.glu:
        h = act(qdense(x, p["w_gate"], dt)) * qdense(x, p["w_up"], dt)
    else:
        h = qdense(x, p["w_up"], dt)
        if "b_up" in p:
            h = h + p["b_up"].astype(dt)
        h = act(h)
    h = constrain(h, "act_ff")
    y = qdense(h, p["w_down"], dt)
    if "b_down" in p:
        y = y + p["b_down"].astype(dt)
    return y
