"""Quantised serving benchmark: Plane-A throughput, token parity and logit
drift of the int8/int4 weight + quantised-KV paths, plus the Plane-B
projection of the traffic they remove.

Variants (all through the same fused ``ServingEngine`` on the reduced
config, greedy decode over identical prompt sets):

- ``fp``     — ``weight_bits=kv_bits=0``: the native path (bit-identical to
               the pre-quantisation engine);
- ``w8``     — per-channel int8 weight-only quantisation;
- ``kv8``    — int8 quantised slot-pool KV cache (per-(token, head) scales,
               quantise-on-commit / dequantise-on-read);
- ``w8kv8``  — both;
- ``w4kv4``  — packed int4 weights + int4 KV (the drift extreme).

Reported per variant: engine tokens/s, exact-sequence and prefix token
parity vs the fp drain, prefill/decode logit drift (max |Δ| on a fixed
batch), and — for ``w8`` — parity against the *fake-quant oracle* (an fp
engine running dequantise(quantise(W)) weights), which must be exact on
the ref path: there the weight path changes the values once, offline, not
the arithmetic.  (On TPU the fused kernel accumulates in f32 while the fp
oracle matmuls in bf16, so the schema gate only enforces exactness off-TPU.)

The Plane-B section projects each precision point onto the full-size model
through the co-simulation traffic model (``Workload(weight_bits=,
kv_bits=)``): decode fabric bytes and batched decode-step latency at 64
chiplets — the measured byte reduction propagating into decode-ms-per-token
(the deeper NoI sweep lives in ``benchmarks.perf_cosim``'s quant_sweep).

    PYTHONPATH=src python -m benchmarks.perf_quant [--smoke]

Results: ``experiments/BENCH_quant.json`` (``BENCH_quant_smoke.json`` with
``--smoke`` so CI never clobbers the recorded full run); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

VARIANTS = {
    "fp": (0, 0),
    "w8": (8, 0),
    "kv8": (0, 8),
    "w8kv8": (8, 8),
    "w4kv4": (4, 4),
}

_VARIANT_KEYS = {"weight_bits", "kv_bits", "tokens", "tokens_per_s",
                 "step_ms", "exact_parity", "prefix_parity"}
_DRIFT_KEYS = {"weight_bits", "kv_bits", "prefill_max_abs", "decode_max_abs"}
_PLANEB_KEYS = {"weight_bits", "kv_bits", "decode_gb", "weight_stream_gb",
                "decode_step_ms", "decode_traffic_reduction_vs_fp"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_quant.json record shape (CI bit-rot gate)."""
    for key in ("bench", "arch", "backend", "smoke", "results", "drift",
                "planeb", "fakequant_parity_w8"):
        assert key in rec, f"missing top-level key {key!r}"
    for name in VARIANTS:
        row = rec["results"][name]
        missing = _VARIANT_KEYS - set(row)
        assert not missing, f"variant {name!r} missing {missing}"
        drow = rec["drift"][name]
        missing = _DRIFT_KEYS - set(drow)
        assert not missing, f"drift {name!r} missing {missing}"
    assert rec["results"]["fp"]["exact_parity"] == 1.0, "fp must match itself"
    if rec["backend"] != "tpu":
        # on the ref path the w8 engine computes x @ dequant(W) — literally
        # the oracle's weights, so parity is exact by construction.  On TPU
        # the fused Pallas kernel accumulates in f32 while the fp oracle
        # matmuls in bf16, so near-tie tokens may legitimately differ.
        assert rec["fakequant_parity_w8"] == 1.0, \
            "w8 engine must exactly match the fake-quant fp oracle"
    for row in rec["planeb"]:
        missing = _PLANEB_KEYS - set(row)
        assert not missing, f"planeb row missing {missing}"


def _prompts(cfg, requests: int, prompt_len: int):
    import numpy as np

    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=prompt_len)
            for _ in range(requests)]


def _drain(cfg, params, prompts, *, weight_bits: int, kv_bits: int,
           impl: str, max_batch: int, kv_len: int, max_new_tokens: int,
           repeat: int = 3):
    """Drain the prompt set; returns (outputs per request, best timing)."""
    from repro.serving.engine import EngineConfig, ServingEngine

    from benchmarks.common import drain_best

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new_tokens,
        impl=impl, weight_bits=weight_bits, kv_bits=kv_bits))

    def once():
        n0, s0 = len(eng.finished), eng.decode_steps
        for p in prompts:
            eng.submit(p)
        eng.run_until_drained()
        done = sorted(eng.finished[n0:], key=lambda r: r.uid)
        toks = sum(len(r.output) for r in done)
        return [tuple(r.output) for r in done], toks, eng.decode_steps - s0

    # warm-up drain (compiles + the parity record) + best-of-repeat —
    # the shared serving-benchmark methodology (benchmarks.common)
    warm, (_, toks, steps), dt, _ = drain_best(
        once, repeat=repeat, score=lambda r, dt: r[1] / dt)
    return warm[0], (toks, steps, dt)


def _parity(ref, out) -> tuple[float, float]:
    import numpy as np

    exact = float(np.mean([a == b for a, b in zip(ref, out)]))
    prefix = float(np.mean([
        sum(x == y for x, y in zip(a, b)) / max(len(a), 1)
        for a, b in zip(ref, out)]))
    return exact, prefix


def measure_drift(cfg, params, *, weight_bits: int, kv_bits: int,
                  kv_len: int, prompt_len: int, batch: int = 4) -> dict:
    """Max |Δlogit| of the quantised path vs fp, on prefill and on one
    decode step from the (quantised) prefill cache."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import transformer as T
    from repro.quant.core import quantize_params

    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    size=(batch, prompt_len)), jnp.int32)
    qparams = quantize_params(params, weight_bits) if weight_bits else params

    lf, cf = T.prefill(params, cfg, {"tokens": toks}, kv_cap=kv_len)
    lq, cq = T.prefill(qparams, cfg, {"tokens": toks}, kv_cap=kv_len,
                       kv_bits=kv_bits)
    nxt = jnp.argmax(lf, -1).astype(jnp.int32)
    pos = jnp.full((batch,), prompt_len, jnp.int32)
    df, _ = T.decode_step(params, cfg, cf, nxt, pos)
    dq, _ = T.decode_step(qparams, cfg, cq, nxt, pos)
    f32 = lambda x: x.astype(jnp.float32)  # noqa: E731
    return {
        "weight_bits": weight_bits, "kv_bits": kv_bits,
        "prefill_max_abs": float(jnp.abs(f32(lf) - f32(lq)).max()),
        "decode_max_abs": float(jnp.abs(f32(df) - f32(dq)).max()),
    }


def planeb_projection(arch: str, chiplets: int, prompt_len: int,
                      gen_len: int, batch: int) -> list[dict]:
    """Full-size Plane-B projection of each precision point."""
    from repro.config import get_config
    from repro.core.simulator import simulate_generation
    from repro.core.traffic import Workload, decode_weight_stream_bytes

    steps = max(gen_len - 1, 1)
    rows, fp_gb = [], None
    for wb, kb in ((16, 16), (8, 8), (4, 4)):
        w = Workload.from_config(get_config(arch), seq_len=prompt_len,
                                 weight_bits=wb, kv_bits=kb)
        g = simulate_generation(w, chiplets, prompt_len, gen_len,
                                arch="2.5D-HI", batch=batch)
        gb = g.decode_bytes / 2**30
        fp_gb = gb if fp_gb is None else fp_gb
        rows.append({
            "weight_bits": wb, "kv_bits": kb, "decode_gb": gb,
            "weight_stream_gb":
                decode_weight_stream_bytes(w) * steps / batch / 2**30,
            "decode_step_ms": g.decode_step_s * 1e3,
            "decode_traffic_reduction_vs_fp": fp_gb / max(gb, 1e-30),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, still writes JSON)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--impl", default="ref",
                    help="attention impl for the drains (flash = Pallas)")
    ap.add_argument("--chiplets", type=int, default=64)
    ap.add_argument("--planeb-prompt-len", type=int, default=512)
    ap.add_argument("--planeb-gen-len", type=int, default=128)
    ap.add_argument("--planeb-batch", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS,
            "BENCH_quant_smoke.json" if args.smoke else "BENCH_quant.json")
    if args.smoke:
        args.max_batch, args.kv_len = 2, 64
        args.max_new_tokens, args.prompt_len, args.requests = 6, 8, 3
        args.planeb_prompt_len, args.planeb_gen_len = 64, 16
        args.planeb_batch = 4

    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit
    from repro.config import get_config, reduce_config
    from repro.models import transformer as T
    from repro.quant.core import fake_quantize_params

    cfg = reduce_config(get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    prompts = _prompts(cfg, args.requests, args.prompt_len)
    shape = dict(impl=args.impl, max_batch=args.max_batch,
                 kv_len=args.kv_len, max_new_tokens=args.max_new_tokens,
                 repeat=2 if args.smoke else 3)

    results, drift = {}, {}
    fp_out = None
    for name, (wb, kb) in VARIANTS.items():
        out, (toks, steps, dt) = _drain(cfg, params, prompts,
                                        weight_bits=wb, kv_bits=kb, **shape)
        fp_out = out if name == "fp" else fp_out
        exact, prefix = _parity(fp_out, out)
        results[name] = {
            "weight_bits": wb, "kv_bits": kb, "tokens": toks,
            "tokens_per_s": toks / max(dt, 1e-9),
            "step_ms": dt / max(steps, 1) * 1e3,
            "exact_parity": exact, "prefix_parity": prefix,
        }
        drift[name] = measure_drift(cfg, params, weight_bits=wb, kv_bits=kb,
                                    kv_len=args.kv_len,
                                    prompt_len=args.prompt_len)

    # fake-quant oracle: an fp engine on dequantise(quantise(W)) must match
    # the w8 engine token-for-token — the weight path changes values, not
    # arithmetic (any mismatch is a serving-plumbing bug, not drift)
    fq_out, _ = _drain(cfg, fake_quantize_params(params, 8), prompts,
                       weight_bits=0, kv_bits=0, **shape)
    w8_out, _ = _drain(cfg, params, prompts, weight_bits=8, kv_bits=0,
                       **shape)
    fq_exact, _ = _parity(fq_out, w8_out)

    rec = {
        "bench": "quant",
        "arch": args.arch,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "impl": args.impl,
        "max_batch": args.max_batch, "kv_len": args.kv_len,
        "max_new_tokens": args.max_new_tokens,
        "prompt_len": args.prompt_len, "requests": args.requests,
        "results": results,
        "drift": drift,
        "fakequant_parity_w8": fq_exact,
        "planeb": planeb_projection(args.arch, args.chiplets,
                                    args.planeb_prompt_len,
                                    args.planeb_gen_len, args.planeb_batch),
        "planeb_shape": {"chiplets": args.chiplets,
                         "prompt_len": args.planeb_prompt_len,
                         "gen_len": args.planeb_gen_len,
                         "batch": args.planeb_batch},
    }
    check_schema(rec)
    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)

    emit([{"variant": k, **v} for k, v in results.items()], "quant_serving")
    emit([{"variant": k, **v} for k, v in drift.items()], "quant_drift")
    emit(rec["planeb"], "quant_planeb_projection")
    print(f"fake-quant oracle parity (w8): {fq_exact:.2f} -> {args.out}")


if __name__ == "__main__":
    main()
