"""Table 4: absolute execution times (ms) — the calibration anchors plus
the full cross table.  Reports residuals explicitly."""
from repro.config import get_config
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.simulator import ANCHORS, simulate_2p5d_hi
from repro.core.traffic import Workload

from benchmarks.common import emit

PAPER = {  # (system, arch) -> paper ms
    ("2.5D-HI", "bert-base"): 50.0, ("2.5D-HI", "gpt-j"): 143.0,
    ("HAIMA_chiplet", "bert-base"): 340.0, ("HAIMA_chiplet", "gpt-j"): 975.0,
    ("TransPIM_chiplet", "bert-base"): 210.0,
    ("TransPIM_chiplet", "gpt-j"): 1435.0,
}
CHIPS = {"bert-base": 36, "gpt-j": 100}


def run(verbose: bool = True) -> list[dict]:
    sims = {"2.5D-HI": simulate_2p5d_hi,
            "HAIMA_chiplet": simulate_haima_chiplet,
            "TransPIM_chiplet": simulate_transpim_chiplet}
    rows = []
    for arch in ("bert-base", "gpt-j"):
        w = Workload.from_config(get_config(arch), seq_len=64)
        for name, fn in sims.items():
            got = fn(w, CHIPS[arch]).latency_s * 1e3
            want = PAPER[(name, arch)]
            rows.append({"system": name, "arch": arch,
                         "chiplets": CHIPS[arch], "ours_ms": got,
                         "paper_ms": want, "residual_pct": 100 * (got / want - 1)})
    if verbose:
        emit(rows, "table4: absolute execution time (n=64)")
    for r in rows:
        assert abs(r["residual_pct"]) < 16, r
    return rows


if __name__ == "__main__":
    run()
