"""Batched serving engine: the wiring layer of the serving stack.

The engine is deliberately thin.  Policy, device execution and slot
lifecycle live in three sibling layers with narrow interfaces::

    scheduler.py   admission + slot policy (Scheduler protocol:
                   FifoScheduler / SloScheduler) — who is admitted next,
                   may prefill preempt decode this iteration
    executor.py    the jitted device programs (fused decode step, packed
                   ragged prefill, chunked continuation, sequential
                   baselines) + the single device→host transfer point
    pool.py        the slotted (optionally quantised) KV cache, per-slot
                   decode state, slot lifecycle and its serialization API

``ServingEngine`` owns only the request queue, terminal bookkeeping and
the iteration loop that drives the three layers.  Each iteration runs:

1. **admission** — the scheduler picks queued requests (FIFO by
   default); all picked prompts pack back-to-back into one ragged
   ``(1, C)`` stream and prefill in a **single** jitted call, with one
   donated multi-slot scatter insert.  Prompts longer than ``C``
   contribute their first ``≤ C`` tokens and enter the *prefilling*
   state;
2. **chunked-prefill continuation** — every prefilling slot advances by
   at most one ``C``-token chunk per iteration, so a long prompt can
   never stall the decode pool for more than one chunk budget.  An
   SLO-aware scheduler may *defer* steps 1–2 while decode slack is too
   thin (slack-gated preemption); the default FIFO never does;
3. **decode** — one jitted, cache-donated step over the full slot pool;
   the only device→host traffic per iteration is one packed
   ``(K, 3, max_batch)`` int32 of ``(next_token, done, anomaly)``.

Hardening (defaults off → bit-identical to the plain engine):
per-request deadlines (``deadline_ms``), bounded-queue shedding
(``max_queue`` → retriable ``REJECTED``), NaN/inf logit quarantine
(``anomaly_retries``), and explicit ``run_until_drained`` failure
semantics (``EngineStallError`` — never a silent partial drain).  Every
submitted request ends in a terminal state.

``packed=False`` preserves the sequential admission baseline (one
bucket-padded batch-1 prefill+insert call per request) and
``fused=False`` the original host-looped decode step — both kept as
measurement baselines for ``benchmarks/perf_serving.py``.

The engine is mesh-aware: pass ``mesh=`` to shard the slot pool and run
the decode step over a pod (the executor activates the serving plans
from ``repro.parallel.sharding``).  Under the default config (FIFO, no
SLOs) token streams, ``stats()`` and checkpoint round-trips are
bit-identical to the pre-layering monolithic engine — pinned by
``tests/test_serving.py`` golden token streams and the HEAD snapshot
fixture in ``tests/data/``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.serving.executor import Executor
from repro.serving.pool import SlotPool
from repro.serving.scheduler import FifoScheduler, Scheduler


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # KV slot pool size
    kv_len: int = 256             # per-slot KV depth
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 → greedy
    eos_token: int = -1           # -1 → never stops early
    impl: str = "ref"             # attention impl ("flash" → Pallas kernels)
    seed: int = 0
    fused: bool = True            # zero-host-sync decode step (False = seed path)
    packed: bool = True           # packed ragged prefill + chunked prefill
    #   (False = sequential admission: one batch-1 prefill per request)
    prefill_chunk: int = 0        # packed-stream / chunk budget in tokens
    #   (0 → min(128, kv_len)); also the padding quantum for non-packable
    #   architectures, so every prefill shape is static
    decode_chunk: int = 1         # device decode iterations per step() —
    #   >1 runs a lax.scan of decode→sample on device (multi-step
    #   scheduling): host sync cost is amortised over the chunk, at the
    #   price of admitting new requests only at chunk boundaries
    weight_bits: int = 0          # 0 = native fp; 8/4 = weight-only
    #   quantisation (per-channel int8 / packed int4, repro.quant) of the
    #   dense projections — the fp path is bit-identical to weight_bits=0
    weight_group: int = 0         # rows of K per scale group (0 = per-channel)
    kv_bits: int = 0              # 0 = fp pool; 8/4 = quantised slot-pool KV
    #   cache (per-(token, head) scales, quantise-on-commit / dequantise-
    #   on-read; the jitted step never materialises an fp cache)
    deadline_ms: float = 0.0      # per-request TTL from submit (0 = none):
    #   expired requests are evicted (queued or mid-decode) and marked
    #   FAILED_DEADLINE instead of decoding forever
    max_queue: int = 0            # bounded-queue admission (0 = unbounded):
    #   submits beyond the bound are shed with the retriable REJECTED
    #   status instead of growing the backlog without bound
    anomaly_retries: int = 1      # NaN/inf-logit quarantine: a slot whose
    #   logits go non-finite is frozen (no token, no pos/budget advance)
    #   and retried this many times before only that request is failed —
    #   the rest of the batch keeps decoding
    spec_k: int = 0               # speculative decoding: draft this many
    #   tokens per step and verify them in ONE batched multi-position call
    #   (0 = off — token streams and stats() bit-identical to the
    #   non-speculative engine).  Requires the fused+packed path,
    #   decode_chunk == 1 and a packable stack; spec_k+1 must fit the
    #   smallest cache ring (min(window, kv_len))
    spec_draft: str = "self"      # "self": a quantised copy of the engine's
    #   own serving params drafts (precision spec_draft_bits); "model": a
    #   separate small draft model passed as ServingEngine(draft=(cfg,
    #   params)), with its own KV pool kept in lockstep
    spec_draft_bits: int = 8      # self-draft precision (8 / 4; 0 = draft
    #   with the serving params themselves — greedy acceptance rate 1,
    #   the bit-identity test configuration)
    clock: Callable[[], float] = time.monotonic
    #   the engine's time source for request timestamps and deadline
    #   arithmetic — injectable so deadline/eviction tests advance a fake
    #   clock instead of sleeping.  Every stats() latency is a difference
    #   of clock readings, so any monotonic float-seconds source works.
    trace: bool = False           # per-iteration wall-clock tracer
    #   (repro.profile measured-cost hook): every decode iteration
    #   appends {"prefill_s", "decode_s", "d2h_s", "step_s", "iters"} to
    #   ``ServingEngine.trace`` and stats() surfaces aggregates under
    #   trace_* keys — present only when tracing, so the dormant
    #   engine's stats() stay bit-identical (the spec_k contract).
    #   Durations come from time.perf_counter (real wall clock),
    #   independent of ``clock=``, which fake-clock tests may drive.


class EngineStallError(RuntimeError):
    """``run_until_drained`` exhausted ``max_iters`` with requests still in
    flight.  Every stranded request has been marked ``FAILED_MAX_ITERS``
    (terminal) before this is raised — nothing is silently dropped."""


# Request terminal states (Request.status).  A submitted request always
# ends in exactly one of the terminal states below — queue/slot limbo is
# never silent.
QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
FAILED_DEADLINE = "failed_deadline"    # missed its EngineConfig.deadline_ms
FAILED_ANOMALY = "failed_anomaly"      # non-finite logits past the retries
FAILED_MAX_ITERS = "failed_max_iters"  # stranded at max_iters exhaustion
REJECTED = "rejected"                  # shed at submit (bounded queue) —
#                                        retriable: resubmit later
TERMINAL = (DONE, FAILED_DEADLINE, FAILED_ANOMALY, FAILED_MAX_ITERS,
            REJECTED)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                       # (prompt_len,) int32
    max_new_tokens: Optional[int] = None
    priority: int = 0                        # scheduling class (larger =
    #                                          more urgent; FIFO ignores it)
    # -- filled by the engine -------------------------------------------------
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = QUEUED
    deadline: float = float("inf")           # absolute wall-clock bound
    t_enqueue: float = 0.0
    t_admit: float = 0.0                     # left the queue (slot assigned):
    #                                          t_admit - t_enqueue is pure
    #                                          scheduling delay, separable
    #                                          from prefill/decode service
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL


# prompt-length buckets for the sequential (packed=False) baseline path:
# one prefill compile per bucket, not per length
_MIN_BUCKET = 8


def _bucket_len(plen: int, kv_len: int) -> int:
    b = _MIN_BUCKET
    while b < plen:
        b *= 2
    return min(b, kv_len)


def _percentiles(xs) -> tuple:
    """(p50, p95, p99) of a sample list.  An empty class yields
    ``(None, None, None)`` — *absent*, not 0.0: a zero here used to be
    rendered by ``report.py`` as a real 0 ms latency."""
    if not xs:
        return (None, None, None)
    p = np.percentile(np.asarray(xs, np.float64), (50.0, 95.0, 99.0))
    return (float(p[0]), float(p[1]), float(p[2]))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: Optional[EngineConfig] = None,
                 *, mesh=None, scheduler: Optional[Scheduler] = None,
                 draft: Optional[tuple] = None):
        # NOTE: default built per-instance — a dataclass default argument
        # would be one shared mutable EngineConfig across all engines.
        self.cfg = cfg
        self.ecfg = ecfg = ecfg if ecfg is not None else EngineConfig()
        if ecfg.weight_bits not in (0, 4, 8):
            raise ValueError(f"weight_bits must be 0, 4 or 8, got {ecfg.weight_bits}")
        if ecfg.kv_bits not in (0, 4, 8):
            raise ValueError(f"kv_bits must be 0, 4 or 8, got {ecfg.kv_bits}")
        if ecfg.spec_k:
            if ecfg.spec_k < 0:
                raise ValueError(f"spec_k must be >= 0, got {ecfg.spec_k}")
            if not (ecfg.fused and ecfg.packed):
                raise ValueError("speculative decoding requires the "
                                 "fused=True, packed=True path")
            if ecfg.decode_chunk != 1:
                raise ValueError("spec_k > 0 requires decode_chunk == 1 "
                                 "(the spec step IS the multi-token step)")
            if ecfg.spec_draft not in ("self", "model"):
                raise ValueError(f"spec_draft must be 'self' or 'model', "
                                 f"got {ecfg.spec_draft!r}")
            if ecfg.spec_draft_bits not in (0, 4, 8):
                raise ValueError(f"spec_draft_bits must be 0, 4 or 8, "
                                 f"got {ecfg.spec_draft_bits}")
            caps = [ecfg.kv_len] + [cfg.window for k in cfg.layer_kinds
                                    if k == "local"]
            if ecfg.spec_k + 1 > min(caps):
                raise ValueError(
                    f"spec_k+1 ({ecfg.spec_k + 1}) exceeds the smallest "
                    f"cache ring ({min(caps)}): the saved-column rollback "
                    f"needs unique ring indices")
            if ecfg.spec_draft == "model" and draft is None:
                raise ValueError(
                    "spec_draft='model' needs draft=(draft_cfg, draft_params)")

        # the three layers: policy / device programs / slot lifecycle
        self.scheduler: Scheduler = scheduler if scheduler is not None \
            else FifoScheduler()
        self.executor = Executor(cfg, params, ecfg, mesh=mesh)
        self.pool = SlotPool(cfg, ecfg, shard_ctx=self.executor.shard_ctx)

        # indexed FIFO admission queue: popleft is O(1) however deep the
        # backlog; the scheduler picks *which* entry leaves it
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self.failed: list[Request] = []      # terminal failures (deadline /
        #                                      anomaly / max_iters)
        self.rejected: list[Request] = []    # shed at submit (retriable)
        self._uid = 0

        # prefill / schedule accounting (benchmarks/perf_serving.py)
        self.decode_steps = 0
        self.prefill_tokens = 0           # prompt tokens pushed through prefill
        self.prefill_time = 0.0           # host wall time spent in admission
        self.prefill_calls = 0
        self.max_stall_tokens = 0         # max prefill tokens between decodes
        self._stall_tokens = 0
        # crash-safety accounting (repro.serving.checkpoint)
        self.checkpoints_written = 0      # snapshots committed for this engine
        self.restores = 0                 # times this engine state was revived
        self.replayed_requests = 0        # journal-tail requests resubmitted
        # per-decode-iteration active-slot histogram {n_active: count} — the
        # measured slot-pool utilisation the Plane-B co-simulation batches
        # its decode steps with (repro.core.cosim.mix_from_stats)
        self.active_slot_hist: collections.Counter = collections.Counter()
        # per-iteration wall-clock records (EngineConfig(trace=)) — one
        # dict per decode iteration; the measured step times the
        # calibration plane (repro.profile) replays through Plane B
        self.trace: list[dict] = []

        # packed-stream / chunk budget (also the padding quantum)
        S = ecfg.kv_len
        self._chunk = min(ecfg.prefill_chunk or min(128, S), S)

        # pow2-bucketing (sequential baseline) is exact only when cache
        # index == token position for every self-attention cache.  The
        # packed path instead relies on length-exact prefill state for
        # every layer kind, so it never needs this distinction.
        self._bucketed = all(k in ("global", "cross") for k in cfg.layer_kinds)

        # multi-prompt packing / chunked continuation need (a) attention-only
        # stacks — SSM/recurrent state would integrate across prompt
        # boundaries — and (b) no MoE: packed prompts would compete for
        # expert capacity, breaking packed==sequential equivalence
        self._packable = (all(k in ("global", "local") for k in cfg.layer_kinds)
                          and not cfg.n_experts
                          and not cfg.cross_attn_decoder
                          and not cfg.n_encoder_layers)

        # speculative decoding wiring: acceptance accounting + (for
        # draft-model speculation) the draft params/cache attachment
        self.spec_steps = 0          # speculative steps run (== weight streams)
        self.spec_drafted = 0        # draft tokens proposed (spec_k per step/row)
        self.spec_accepted = 0       # draft tokens the verify pass accepted
        self.spec_committed = 0      # tokens actually committed (accepted
        #                              prefix + the correction token, after
        #                              budget/eos/depth caps)
        if ecfg.spec_k:
            if not self._packable:
                raise ValueError(
                    "speculative decoding needs a packable stack (attention-"
                    "only, no MoE/cross/encoder) — the verify step reuses "
                    "the segmented-prefill chunk path")
            if ecfg.spec_draft == "model":
                dcfg, dparams = draft
                if dcfg.vocab_size != cfg.vocab_size:
                    raise ValueError(
                        f"draft vocab ({dcfg.vocab_size}) != target vocab "
                        f"({cfg.vocab_size})")
                if not all(k in ("global", "local") for k in dcfg.layer_kinds):
                    raise ValueError("draft model must be attention-only")
                self.executor.set_draft(dcfg, dparams)
                self.pool.init_draft(dcfg)

        # seed-compat sampling key (fused=False host path)
        self._key = jax.random.PRNGKey(ecfg.seed)

    # -- layer delegation (stable public/test surface) -------------------------
    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.pool.cache

    @cache.setter
    def cache(self, value):
        self.pool.cache = value

    @property
    def _state(self):
        return self.pool.state

    @_state.setter
    def _state(self, value):
        self.pool.state = value

    @property
    def slot_req(self):
        return self.pool.slot_req

    @slot_req.setter
    def slot_req(self, value):
        self.pool.slot_req = list(value)

    @property
    def _prefilling(self):
        return self.pool.prefilling

    @_prefilling.setter
    def _prefilling(self, value):
        self.pool.prefilling = dict(value)

    @property
    def _slot_anomalies(self):
        return self.pool.anomalies

    @_slot_anomalies.setter
    def _slot_anomalies(self, value):
        self.pool.anomalies = list(value)

    @property
    def host_transfers(self):
        return self.executor.host_transfers

    @host_transfers.setter
    def host_transfers(self, value):
        self.executor.host_transfers = value

    @property
    def host_bytes(self):
        return self.executor.host_bytes

    @host_bytes.setter
    def host_bytes(self, value):
        self.executor.host_bytes = value

    # compiled-program handles (compile-count regression tests)
    @property
    def _jit_step(self):
        return self.executor.jit_step

    @property
    def _jit_prefill_insert(self):
        return self.executor.jit_prefill_insert

    @property
    def _jit_packed_prefill(self):
        return self.executor.jit_packed_prefill

    @property
    def _jit_chunk_step(self):
        return self.executor.jit_chunk_step

    def _now(self) -> float:
        """Engine time (``EngineConfig.clock`` — monotonic seconds)."""
        return self.ecfg.clock()

    def _fetch(self, x) -> np.ndarray:
        return self.executor.fetch(x)

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               *, priority: int = 0) -> Request:
        """Validate and enqueue one request.

        Malformed inputs (empty / over-long prompts, non-integer dtype,
        wrong ndim, negative budget) raise ``ValueError`` here — at submit
        time, not deep inside a jitted step.  When the bounded queue
        (``EngineConfig.max_queue``) is full the request is shed: returned
        with the retriable ``REJECTED`` status instead of enqueued.
        ``priority`` is the scheduling class (larger = more urgent) an
        SLO-aware scheduler orders by; the default FIFO ignores it."""
        arr = np.asarray(prompt)
        if arr.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got ndim={arr.ndim}")
        if arr.size == 0:
            raise ValueError("prompt must hold at least one token")
        if arr.dtype.kind not in "iu":
            raise ValueError(
                f"prompt must be integer token ids, got dtype={arr.dtype}")
        if arr.size + 1 >= self.ecfg.kv_len:
            raise ValueError(
                f"prompt ({arr.size}) ≥ kv_len ({self.ecfg.kv_len}): no room "
                f"for even one generated token in the KV budget")
        if max_new_tokens is not None and max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {max_new_tokens}")
        now = self._now()
        req = Request(uid=self._uid, prompt=arr.astype(np.int32),
                      max_new_tokens=max_new_tokens, priority=int(priority),
                      t_enqueue=now)
        if self.ecfg.deadline_ms > 0:
            req.deadline = now + self.ecfg.deadline_ms / 1e3
        self._uid += 1
        if self.ecfg.max_queue > 0 and len(self.queue) >= self.ecfg.max_queue:
            req.status = REJECTED
            req.t_done = now
            self.rejected.append(req)
            return req
        self.queue.append(req)
        return req

    def step(self) -> int:
        """One engine iteration: deadline eviction + (scheduler-gated)
        admission + chunked prefill continuation + one decode step over
        the slot pool.  Returns the number of occupied slots."""
        if self.ecfg.deadline_ms > 0:
            self._evict_expired()
        if self.ecfg.spec_k:
            return self._step_spec()
        if self.ecfg.fused:
            return self._step_fused()
        return self._step_host()

    # -- failure plumbing ------------------------------------------------------
    def _fail(self, req: Request, status: str, now: Optional[float] = None):
        """Move a request to a terminal failure state (never ``finished``)."""
        req.status = status
        req.t_done = now if now is not None else self._now()
        self.failed.append(req)

    def _evict_expired(self):
        """Fail every queued or in-flight request past its deadline —
        expired work is dropped before it spends another admission or
        decode step (the slot frees for a request that can still make it)."""
        now = self._now()
        if self.queue:
            kept = collections.deque()
            for req in self.queue:
                if now > req.deadline:
                    self._fail(req, FAILED_DEADLINE, now)
                else:
                    kept.append(req)
            self.queue = kept
        for i, req in enumerate(self.pool.slot_req):
            if req is not None and now > req.deadline:
                self._fail(req, FAILED_DEADLINE, now)
                self.pool.kill(i)

    # -- scheduler seams -------------------------------------------------------
    def _prefill_allowed(self) -> bool:
        """Ask the scheduler whether prefill (admission + chunk
        continuation) may preempt decode this iteration.  Only consulted
        when there is both prefill work to run and decode work to stall —
        an idle pool is never gated, so no policy can deadlock the
        drain."""
        if not (self.queue or self.pool.prefilling):
            return True
        decoding = self.pool.decoding()
        if not decoding:
            return True
        return self.scheduler.allow_prefill(decoding, self._now())

    def _pop_admissible(self) -> Optional[tuple]:
        """Pop the scheduler's next admissible queued request.  Requests
        asking for 0 tokens finish immediately; over-long prompts raise."""
        while self.queue:
            idx = self.scheduler.select(self.queue, self._now())
            if idx is None:
                return None
            req = self.queue[idx]
            del self.queue[idx]
            # a request may ask for fewer tokens than the engine default —
            # including 0 (`or` would silently swap in the default)
            budget = req.max_new_tokens if req.max_new_tokens is not None \
                else self.ecfg.max_new_tokens
            if budget <= 0:
                req.done = True
                req.status = DONE
                req.t_admit = req.t_first_token = req.t_done = self._now()
                self.finished.append(req)
                continue
            plen = len(req.prompt)
            if plen + 1 >= self.ecfg.kv_len:
                raise ValueError(f"prompt ({plen}) ≥ kv_len ({self.ecfg.kv_len})")
            return req, plen, budget
        return None

    # -- iteration loop --------------------------------------------------------
    def _step_fused(self) -> int:
        t0 = time.perf_counter()
        calls0 = self.prefill_calls
        if self._prefill_allowed():
            if self.ecfg.packed:
                self._admit_packed()
            else:
                self._admit_fused()
        dt = time.perf_counter() - t0
        self.prefill_time += dt
        if self.prefill_calls > calls0:
            self.scheduler.observe_prefill(dt)
        occupied = self.pool.occupied()
        if occupied == len(self.pool.prefilling):
            # no live slot: nothing to decode (and nothing being stalled —
            # mid-prefill-only iterations just advance their chunks)
            self._stall_tokens = 0
            return occupied
        tr = self.ecfg.trace
        td0 = time.perf_counter() if tr else 0.0
        self.pool.cache, self.pool.state, packed = self.executor.fused_step(
            self.pool.cache, self.pool.state)
        td1 = time.perf_counter() if tr else 0.0
        arr = self._fetch(packed)                 # ONE d2h transfer
        if tr:
            # dispatch is asynchronous: the d2h fetch waits on the device
            # step, so decode_s + d2h_s is the true step wall time
            td2 = time.perf_counter()
            self.trace.append({"prefill_s": dt, "decode_s": td1 - td0,
                               "d2h_s": td2 - td1, "step_s": td2 - t0,
                               "iters": int(arr.shape[0])})
        self.decode_steps += arr.shape[0]
        self.max_stall_tokens = max(self.max_stall_tokens, self._stall_tokens)
        self._stall_tokens = 0
        now = self._now()
        for it in range(arr.shape[0]):            # decode_chunk iterations
            # zero-active iterations (slots all finished mid-chunk) are real
            # device work — recording them keeps Σhist == decode_steps and
            # lets the occupancy mean discount the dead tail of a chunk
            self.active_slot_hist[int((arr[it, 0] >= 0).sum())] += 1
            for i, req in enumerate(self.pool.slot_req):
                if req is None or i in self.pool.prefilling:
                    continue
                if arr[it, 2, i]:                 # non-finite logits: the
                    # device froze the slot (no token, no pos advance) and
                    # will retry the identical step; quarantine after the
                    # configured retries — only this request fails, the
                    # rest of the batch keeps decoding
                    self.pool.anomalies[i] += 1
                    if self.pool.anomalies[i] > self.ecfg.anomaly_retries:
                        self._fail(req, FAILED_ANOMALY, now)
                        self.pool.kill(i)
                    continue
                if arr[it, 0, i] < 0:
                    continue
                self.pool.anomalies[i] = 0        # clean step: retry budget
                #                                   resets (transient fault)
                tok = int(arr[it, 0, i])
                if not req.output:
                    req.t_first_token = now
                req.output.append(tok)
                if arr[it, 1, i]:
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    self.pool.release(i)     # slot freed → continuous batching
        return self.pool.occupied()

    def _step_spec(self) -> int:
        """One speculative iteration: admission (same packed path), then a
        single draft+verify step over the slot pool.  One device→host
        transfer — a packed ``(spec_k+1, 4, B)`` of (token | -1, done,
        anomaly, n_accepted) — commits up to ``spec_k + 1`` tokens per
        slot per weight stream."""
        t0 = time.perf_counter()
        calls0 = self.prefill_calls
        if self._prefill_allowed():
            self._admit_packed()
        dt = time.perf_counter() - t0
        self.prefill_time += dt
        if self.prefill_calls > calls0:
            self.scheduler.observe_prefill(dt)
        occupied = self.pool.occupied()
        if occupied == len(self.pool.prefilling):
            self._stall_tokens = 0
            return occupied
        tr = self.ecfg.trace
        td0 = time.perf_counter() if tr else 0.0
        self.pool.cache, dcache, self.pool.state, packed = \
            self.executor.spec_step(self.pool.cache, self.pool.state,
                                    self.pool.draft_cache)
        td1 = time.perf_counter() if tr else 0.0
        if self.pool.draft_cache is not None:
            self.pool.draft_cache = dcache
        arr = self._fetch(packed)                 # ONE d2h transfer
        if tr:
            td2 = time.perf_counter()
            self.trace.append({"prefill_s": dt, "decode_s": td1 - td0,
                               "d2h_s": td2 - td1, "step_s": td2 - t0,
                               "iters": 1})
        self.decode_steps += 1                    # one target weight stream
        self.spec_steps += 1
        self.max_stall_tokens = max(self.max_stall_tokens, self._stall_tokens)
        self._stall_tokens = 0
        now = self._now()
        K = self.ecfg.spec_k
        # occupancy accounting mirrors the fused step: slots that committed
        # a token this iteration (frozen/anomalous slots are not active)
        self.active_slot_hist[int((arr[0, 0] >= 0).sum())] += 1
        for i, req in enumerate(self.pool.slot_req):
            if req is None or i in self.pool.prefilling:
                continue
            if arr[0, 2, i]:                      # non-finite verify logits:
                # the device restored all spec_k+1 columns and left the
                # state untouched — identical retry semantics to the fused
                # step's frozen slots
                self.pool.anomalies[i] += 1
                if self.pool.anomalies[i] > self.ecfg.anomaly_retries:
                    self._fail(req, FAILED_ANOMALY, now)
                    self.pool.kill(i)
                continue
            if arr[0, 0, i] < 0:
                continue
            self.pool.anomalies[i] = 0
            self.spec_drafted += K
            self.spec_accepted += int(arr[0, 3, i])
            for it in range(arr.shape[0]):        # committed prefix, in order
                if arr[it, 0, i] < 0:
                    break
                tok = int(arr[it, 0, i])
                if not req.output:
                    req.t_first_token = now
                req.output.append(tok)
                self.spec_committed += 1
                if arr[it, 1, i]:
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    self.pool.release(i)
                    break
        return self.pool.occupied()

    def _step_host(self) -> int:
        """Original per-token host round-trip step (measurement baseline)."""
        t0 = time.perf_counter()
        calls0 = self.prefill_calls
        if self._prefill_allowed():
            self._admit_host()
        dt = time.perf_counter() - t0
        self.prefill_time += dt
        if self.prefill_calls > calls0:
            self.scheduler.observe_prefill(dt)
        live = [i for i, r in enumerate(self.pool.slot_req) if r is not None]
        if not live:
            return 0
        host = self.pool.ensure_host()
        self.active_slot_hist[len(live)] += 1
        tokens = jnp.asarray(host["last_token"])
        pos = jnp.asarray(host["slot_pos"])
        tr = self.ecfg.trace
        td0 = time.perf_counter() if tr else 0.0
        logits, self.pool.cache = self.executor.decode(self.pool.cache,
                                                       tokens, pos)
        td1 = time.perf_counter() if tr else 0.0
        self.decode_steps += 1
        self.max_stall_tokens = max(self.max_stall_tokens, self._stall_tokens)
        self._stall_tokens = 0
        nxt, self._key = self.executor.sample_host(logits, self._key)
        if tr:
            # the host-path "d2h" is the sampling round-trip that waits
            # on the decode dispatch — same split as the fused path
            td2 = time.perf_counter()
            self.trace.append({"prefill_s": dt, "decode_s": td1 - td0,
                               "d2h_s": td2 - td1, "step_s": td2 - t0,
                               "iters": 1})
        now = self._now()
        for i in live:
            req = self.pool.slot_req[i]
            tok = int(nxt[i])
            if not req.output:
                req.t_first_token = now
            req.output.append(tok)
            host["last_token"][i] = tok
            host["slot_pos"][i] += 1
            host["slot_budget"][i] -= 1
            hit_eos = (self.ecfg.eos_token >= 0 and tok == self.ecfg.eos_token)
            if host["slot_budget"][i] <= 0 or hit_eos or \
                    host["slot_pos"][i] >= self.ecfg.kv_len:
                req.done = True
                req.status = DONE
                req.t_done = now
                self.finished.append(req)
                self.pool.release(i)     # slot freed → continuous batching
        return self.pool.occupied()

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        """Step until every request reaches a terminal state.

        Exhausting ``max_iters`` is an explicit failure, never a silent
        partial drain: every request still queued or in a slot is marked
        ``FAILED_MAX_ITERS`` (terminal, listed in ``self.failed``) and
        ``EngineStallError`` is raised."""
        it = 0
        while (self.queue or any(r is not None for r in self.pool.slot_req)):
            self.step()
            it += 1
            if it > max_iters:
                now = self._now()
                stranded = list(self.queue) + [r for r in self.pool.slot_req
                                               if r is not None]
                for req in self.queue:
                    self._fail(req, FAILED_MAX_ITERS, now)
                self.queue.clear()
                for i, req in enumerate(self.pool.slot_req):
                    if req is not None:
                        self._fail(req, FAILED_MAX_ITERS, now)
                        self.pool.kill(i)
                raise EngineStallError(
                    f"engine did not drain in {max_iters} iterations; "
                    f"{len(stranded)} request(s) marked "
                    f"{FAILED_MAX_ITERS}")
        return self.finished

    # -- admission: packed ragged prefill + chunked continuation ---------------
    def _pad_len(self, plen: int) -> int:
        """Smallest chunk multiple >= plen (capped at kv_len) — the static
        shape set for per-request prefill."""
        C = self._chunk
        return min(-(-max(plen, 1) // C) * C, self.ecfg.kv_len)

    def _admit_packed(self):
        B, C = self.ecfg.max_batch, self._chunk
        if self.pool.prefilling:
            self._continue_chunks()
        free = self.pool.free_slots()
        if not free or not self.queue:
            return
        if not self._packable:
            self._admit_padded(free)
            return

        segs = []                      # (req, slot, off, take, final, budget)
        used = 0
        try:
            while free and used < C:
                nxt = self._pop_admissible()
                if nxt is None:
                    break
                req, plen, budget = nxt
                if plen > C - used and used > 0:
                    # whole prompt doesn't fit the remaining stream: don't
                    # fragment it — a tail-sized first chunk would buy
                    # little and cost an extra continuation call; re-queue
                    # at the head (FIFO preserved) and admit next iteration
                    self.queue.appendleft(req)
                    break
                take = min(plen, C - used)
                slot = free.pop(0)
                segs.append((req, slot, used, take, take == plen, budget))
                used += take
        except ValueError:
            # an over-long prompt mid-burst must not strand the requests
            # already popped into this stream — put them back (FIFO) first
            for req, *_ in reversed(segs):
                self.queue.appendleft(req)
            raise
        if not segs:
            return

        toks = np.zeros((1, C), np.int32)
        seg = np.full((1, C), -1, np.int32)
        pos = np.zeros((1, C), np.int32)
        gather = np.zeros((B,), np.int32)
        off_v = np.zeros((B,), np.int32)
        len_v = np.zeros((B,), np.int32)
        fin_v = np.zeros((B,), bool)
        bud_v = np.ones((B,), np.int32)
        act_v = np.zeros((B,), bool)
        t_adm = self._now()               # left the queue: scheduling delay
        #                                   ends here, service time begins
        for req, slot, off, take, final, budget in segs:
            req.t_admit = t_adm
            toks[0, off:off + take] = req.prompt[:take]
            seg[0, off:off + take] = slot
            pos[0, off:off + take] = np.arange(take)
            gather[slot] = off + take - 1
            off_v[slot], len_v[slot] = off, take
            fin_v[slot], bud_v[slot], act_v[slot] = final, budget, True

        self.pool.cache, self.pool.state, first = self.executor.packed_prefill(
            self.pool.cache, self.pool.state, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(seg), jnp.asarray(gather),
            jnp.asarray(off_v), jnp.asarray(len_v), jnp.asarray(fin_v),
            jnp.asarray(bud_v), jnp.asarray(act_v))
        arr = self._fetch(first)                  # one d2h per admission burst
        self.prefill_tokens += used
        self.prefill_calls += 1
        self._stall_tokens += used
        now = self._now()
        for req, slot, off, take, final, budget in segs:
            if final:
                tok = int(arr[slot])
                req.output = [tok]
                req.t_first_token = now
                if budget == 1:     # the prefill sample was the whole budget
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    continue
                req.status = ACTIVE
                self.pool.slot_req[slot] = req
                self._draft_ingest(req, slot)
            else:                   # long prompt: first chunk only
                req.status = ACTIVE
                self.pool.slot_req[slot] = req
                self.pool.prefilling[slot] = (take, budget)

    def _continue_chunks(self):
        """Advance every mid-prefill slot by one <= C-token chunk (one
        batched jitted call), activating rows whose prompt completed."""
        B, C = self.ecfg.max_batch, self._chunk
        toks = np.zeros((B, C), np.int32)
        pos = np.full((B, C), -1, np.int32)
        take_idx = np.zeros((B,), np.int32)
        fin_v = np.zeros((B,), bool)
        bud_v = np.ones((B,), np.int32)
        plan = []                                  # (slot, start, c, budget)
        for slot, (start, budget) in self.pool.prefilling.items():
            req = self.pool.slot_req[slot]
            plen = len(req.prompt)
            c = min(plen - start, C)
            toks[slot, :c] = req.prompt[start:start + c]
            pos[slot, :c] = start + np.arange(c)
            take_idx[slot] = c - 1
            fin_v[slot] = start + c == plen
            bud_v[slot] = budget
            plan.append((slot, start, c, budget))

        self.pool.cache, self.pool.state, first = self.executor.chunk_step(
            self.pool.cache, self.pool.state, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(take_idx), jnp.asarray(fin_v),
            jnp.asarray(bud_v))
        arr = self._fetch(first)
        total = sum(c for _, _, c, _ in plan)
        self.prefill_tokens += total
        self.prefill_calls += 1
        self._stall_tokens += C                    # one batched chunk call
        now = self._now()
        for slot, start, c, budget in plan:
            req = self.pool.slot_req[slot]
            if start + c == len(req.prompt):       # prompt complete
                del self.pool.prefilling[slot]
                tok = int(arr[slot])
                req.output = [tok]
                req.t_first_token = now
                if budget == 1:
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    self.pool.release(slot)
                else:
                    self._draft_ingest(req, slot)
            else:
                self.pool.prefilling[slot] = (start + c, budget)

    def _draft_ingest(self, req, slot: int) -> None:
        """Draft-model speculation: mirror a completed prompt into the
        draft-model KV pool (one padded batch-1 draft prefill + insert) so
        the draft decodes with the same context as the target.  No-op for
        self-speculation (shared cache)."""
        if self.pool.draft_cache is None:
            return
        plen = len(req.prompt)
        pad = self._pad_len(plen)
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        self.pool.draft_cache = self.executor.draft_prefill(
            self.pool.draft_cache, jnp.asarray(toks), jnp.int32(slot),
            jnp.int32(plen))

    def _admit_one(self, req, slot: int, plen: int, budget: int, pad: int):
        """One right-padded batch-1 prefill+insert call and its bookkeeping
        (shared by the chunk-padded and pow2-bucketed sequential paths)."""
        req.t_admit = self._now()
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        self.pool.cache, self.pool.state, first = self.executor.prefill_insert(
            self.pool.cache, self.pool.state, jnp.asarray(toks),
            jnp.int32(slot), jnp.int32(plen), jnp.int32(budget))
        tok = int(self._fetch(first))
        self.prefill_tokens += plen
        self.prefill_calls += 1
        self._stall_tokens += pad
        req.output = [tok]
        req.t_first_token = self._now()
        if budget == 1:             # the prefill sample was the whole budget
            req.done = True
            req.status = DONE
            req.t_done = req.t_first_token
            self.finished.append(req)
        else:
            req.status = ACTIVE
            self.pool.slot_req[slot] = req

    def _admit_padded(self, free):
        """Per-request admission for non-packable architectures: prompts
        right-padded to a chunk multiple with length-exact prefill state —
        static shapes, no compile-per-distinct-length."""
        while free and self.queue:
            nxt = self._pop_admissible()
            if nxt is None:
                break
            req, plen, budget = nxt
            self._admit_one(req, free.pop(0), plen, budget,
                            self._pad_len(plen))

    # -- admission: sequential baselines ---------------------------------------
    def _next_request(self, slot: int) -> Optional[tuple]:
        """Pop the next admissible queued request and its padded prompt, or
        None (sequential baseline paths)."""
        if self.pool.slot_req[slot] is not None:
            return None
        nxt = self._pop_admissible()
        if nxt is None:
            return None
        req, plen, budget = nxt
        pad = _bucket_len(plen, self.ecfg.kv_len) if self._bucketed else plen
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        return req, toks, plen, budget

    def _admit_fused(self):
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            self._admit_one(req, slot, plen, budget, toks.shape[1])

    def _admit_host(self):
        host = self.pool.ensure_host()
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            req.t_admit = self._now()
            logits, pcache = self.executor.prefill(jnp.asarray(toks),
                                                   jnp.int32(plen))
            self.pool.cache = self.executor.insert(
                self.pool.cache, pcache, jnp.int32(slot), jnp.int32(plen))
            first, self._key = self.executor.sample_host(logits, self._key)
            self.prefill_tokens += plen
            self.prefill_calls += 1
            self._stall_tokens += toks.shape[1]
            req.output = [int(first[0])]
            req.t_first_token = self._now()
            if budget == 1:         # the prefill sample was the whole budget
                req.done = True
                req.status = DONE
                req.t_done = req.t_first_token
                self.finished.append(req)
                continue
            req.status = ACTIVE
            self.pool.slot_req[slot] = req
            host["slot_pos"][slot] = plen
            host["slot_budget"][slot] = budget - 1
            host["last_token"][slot] = int(first[0])

    # -- crash safety ---------------------------------------------------------
    @classmethod
    def restore(cls, cfg: ModelConfig, params, ckpt_dir: str, *,
                ecfg: Optional[EngineConfig] = None, mesh=None,
                scheduler: Optional[Scheduler] = None,
                replay: bool = True, draft: Optional[tuple] = None
                ) -> "ServingEngine":
        """Revive an engine from its newest intact snapshot in
        ``ckpt_dir`` (written by ``repro.serving.checkpoint``), resuming
        mid-decode bit-identically and replaying journal-tail requests
        admitted after the snapshot.  See
        :func:`repro.serving.checkpoint.restore_engine`."""
        from repro.serving.checkpoint import restore_engine
        return restore_engine(cfg, params, ckpt_dir, ecfg=ecfg, mesh=mesh,
                              scheduler=scheduler, replay=replay,
                              draft=draft)

    # -- stats ---------------------------------------------------------------
    def _failure_stats(self) -> dict:
        by_status: collections.Counter = collections.Counter(
            r.status for r in self.failed)
        return {
            "failed": len(self.failed),
            "rejected": len(self.rejected),
            "failed_deadline": by_status.get(FAILED_DEADLINE, 0),
            "failed_anomaly": by_status.get(FAILED_ANOMALY, 0),
            "failed_max_iters": by_status.get(FAILED_MAX_ITERS, 0),
            # crash-safety counters (repro.serving.checkpoint): snapshots
            # committed, revivals of this engine state, journal-tail
            # requests resubmitted during restore
            "checkpoints_written": self.checkpoints_written,
            "restores": self.restores,
            "replayed_requests": self.replayed_requests,
        }

    def stats(self) -> dict:
        done = self.finished
        if not done:
            return {"finished": 0, **self._failure_stats()}
        lat = [r.t_done - r.t_enqueue for r in done]
        ttft = [r.t_first_token - r.t_enqueue for r in done]
        # per-token cadence after the first token (needs >= 2 tokens);
        # queue wait is pure scheduling delay (enqueue → slot assignment),
        # separable from prefill/decode service time.  t_admit may be
        # unset (0.0) on requests restored from pre-layering snapshots.
        tpot = [(r.t_done - r.t_first_token) / (len(r.output) - 1)
                for r in done if len(r.output) > 1]
        qwait = [r.t_admit - r.t_enqueue for r in done if r.t_admit > 0.0]
        lat_p = _percentiles(lat)
        ttft_p = _percentiles(ttft)
        tpot_p = _percentiles(tpot)
        qwait_p = _percentiles(qwait)
        toks = sum(len(r.output) for r in done)
        span = max(r.t_done for r in done) - min(r.t_enqueue for r in done)
        # speculative-decoding acceptance accounting — keys present only
        # when spec_k > 0, so the dormant engine's stats() stay
        # bit-identical to the non-speculative engine's
        spec: dict = {}
        if self.ecfg.spec_k:
            spec = {
                "spec_k": self.ecfg.spec_k,
                "spec_draft": self.ecfg.spec_draft,
                "spec_draft_bits": self.ecfg.spec_draft_bits,
                "spec_steps": self.spec_steps,
                "spec_drafted": self.spec_drafted,
                "spec_accepted": self.spec_accepted,
                "spec_committed": self.spec_committed,
                # per-draft acceptance probability (the Plane-B traffic
                # model's alpha) and tokens committed per slot per target
                # weight stream (the amortisation the fabric sees;
                # drafted / spec_k == participating row-steps)
                "spec_acceptance": (self.spec_accepted / self.spec_drafted
                                    if self.spec_drafted else None),
                "spec_tokens_per_step": (
                    self.spec_committed * self.ecfg.spec_k / self.spec_drafted
                    if self.spec_drafted else None),
            }
        # measured per-iteration wall clock (EngineConfig(trace=)) — keys
        # present only when tracing, mirroring the spec_k dormancy
        # contract; empty sample classes report None, never a fake 0.0
        trace: dict = {}
        if self.ecfg.trace:
            steps = [t["decode_s"] + t["d2h_s"] for t in self.trace]
            step_p = _percentiles(steps)
            trace = {
                "trace_iterations": len(self.trace),
                "trace_prefill_s": float(sum(t["prefill_s"]
                                             for t in self.trace)),
                "trace_decode_s": float(sum(t["decode_s"]
                                            for t in self.trace)),
                "trace_d2h_s": float(sum(t["d2h_s"] for t in self.trace)),
                # wall time of one decode iteration — dispatch plus the
                # d2h fetch that waits on it: the measured analogue of
                # the simulator's decode_step_s
                "trace_decode_step_s": (float(np.mean(steps))
                                        if steps else None),
                "trace_decode_step_p50_s": step_p[0],
                "trace_decode_step_p95_s": step_p[1],
            }
        return {
            "finished": len(done),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "mean_latency_s": float(np.mean(lat)),
            "mean_ttft_s": float(np.mean(ttft)),
            # empty sample classes report None (absent), never a fake 0.0:
            # every finished request with gen_len <= 1 has no TPOT sample,
            # and pre-layering snapshots may carry no t_admit stamps
            "mean_tpot_s": float(np.mean(tpot)) if tpot else None,
            "mean_queue_wait_s": float(np.mean(qwait)) if qwait else None,
            "latency_p50_s": lat_p[0],
            "latency_p95_s": lat_p[1],
            "latency_p99_s": lat_p[2],
            "ttft_p50_s": ttft_p[0],
            "ttft_p95_s": ttft_p[1],
            "ttft_p99_s": ttft_p[2],
            "tpot_p50_s": tpot_p[0],
            "tpot_p95_s": tpot_p[1],
            "tpot_p99_s": tpot_p[2],
            "queue_wait_p50_s": qwait_p[0],
            "queue_wait_p95_s": qwait_p[1],
            "queue_wait_p99_s": qwait_p[2],
            "decode_steps": self.decode_steps,
            "host_transfers": self.host_transfers,
            "host_bytes": self.host_bytes,
            "host_bytes_per_token": self.host_bytes / max(toks, 1),
            "prefill_tokens": self.prefill_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_time_s": self.prefill_time,
            "prefill_tokens_per_s": self.prefill_tokens / max(self.prefill_time, 1e-9),
            "max_stall_tokens": self.max_stall_tokens,
            # per-request episode shape + schedule, consumed by the Plane-B
            # co-simulation bridge (repro.core.cosim.mix_from_stats)
            "prompt_lens": [len(r.prompt) for r in done],
            "gen_lens": [len(r.output) for r in done],
            "prefill_chunk": self._chunk,
            "max_batch": self.ecfg.max_batch,
            # measured serving precision (16 = native fp16-class), consumed
            # by the Plane-B bridge so quantisation propagates into the
            # traffic model (repro.core.cosim.mix_from_stats)
            "weight_bits": self.ecfg.weight_bits or 16,
            "kv_bits": self.ecfg.kv_bits or 16,
            # {n_active_slots: decode iterations at that occupancy} — the
            # measured continuous-batching utilisation of the slot pool
            "active_slots_hist": dict(sorted(self.active_slot_hist.items())),
            **spec,
            **trace,
            **self._failure_stats(),
        }
