"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the slotted continuous-batching engine on the requested mesh
and drives a synthetic request workload (Zipf prompt lengths), reporting
throughput / TTFT / latency — the serving-side analogue of train.py.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "auto", "flash", "pallas",
                             "pallas_interpret"],
                    help="attention impl (flash = Pallas decode kernel)")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="device decode iterations per host sync")
    ap.add_argument("--host-loop", action="store_true",
                    help="use the legacy host-looped step (fused=False)")
    ap.add_argument("--weight-bits", type=int, default=0, choices=[0, 4, 8],
                    help="weight-only quantisation (0 = native fp)")
    ap.add_argument("--kv-bits", type=int, default=0, choices=[0, 4, 8],
                    help="quantised slot-pool KV cache (0 = fp pool)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import get_config, reduce_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only architectures have no decode step")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           param_dtype=jnp.bfloat16)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch, kv_len=args.kv_len,
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        seed=args.seed, impl=args.impl, fused=not args.host_loop,
        decode_chunk=args.decode_chunk,
        weight_bits=args.weight_bits, kv_bits=args.kv_bits))

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, min(64, args.kv_len - args.max_new_tokens - 1)))
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        engine.submit(prompt)

    engine.run_until_drained()
    stats = engine.stats()
    bits = (f"w{args.weight_bits or 'fp'}/kv{args.kv_bits or 'fp'} "
            if (args.weight_bits or args.kv_bits) else "")
    print(f"arch={cfg.name} {bits}requests={stats['finished']} "
          f"tokens={stats['tokens']} "
          f"throughput={stats['tokens_per_s']:.1f} tok/s "
          f"ttft={stats['mean_ttft_s']*1e3:.0f}ms "
          f"latency={stats['mean_latency_s']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
