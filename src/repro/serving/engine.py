"""Batched serving engine with a slotted KV cache and continuous batching.

The paper's evaluation is *inference*; this is the inference runtime for
Plane A.  Design follows the production pattern (vLLM/TGI-style, expressed
in JAX with static shapes):

- a fixed pool of ``max_batch`` KV slots, each ``kv_len`` tokens deep
  (static shapes → one compiled decode step, no recompilation as requests
  come and go);
- **continuous batching**: finished requests free their slot immediately
  and a queued request is prefilled into it while other slots keep
  decoding — the decode step always runs over the full slot pool with a
  validity mask;
- prefill writes its cache into the slot via ``dynamic_update_slice`` on
  the stacked cache pytree;
- greedy or temperature sampling, per-request max-token budget.

The engine is mesh-aware: pass shardings built by
``repro.parallel.sharding`` to serve a model sharded over a pod; on CPU
tests everything runs on one device with the same code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # KV slot pool size
    kv_len: int = 256             # per-slot KV depth
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 → greedy
    eos_token: int = -1           # -1 → never stops early
    impl: str = "ref"
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                       # (prompt_len,) int32
    max_new_tokens: Optional[int] = None
    # -- filled by the engine -------------------------------------------------
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig = EngineConfig()):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        B, S = ecfg.max_batch, ecfg.kv_len
        self.cache = T.init_cache(cfg, B, S, dtype=jnp.bfloat16)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)        # next position to write
        self.slot_budget = np.zeros(B, np.int32)
        self.last_token = np.zeros(B, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._uid = 0

        self._jit_decode = jax.jit(self._decode_fn)
        self._jit_prefill = jax.jit(self._prefill_fn)

    # -- jitted cores ---------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos):
        logits, cache = T.decode_step(params, self.cfg, cache, tokens, pos,
                                      impl=self.ecfg.impl)
        return logits, cache

    def _prefill_fn(self, params, tokens):
        # single-request prefill padded to kv_len (static shape)
        logits, cache = T.prefill(params, self.cfg, {"tokens": tokens},
                                  impl=self.ecfg.impl, kv_cap=self.ecfg.kv_len)
        return logits, cache

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> Request:
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, t_enqueue=time.time())
        self._uid += 1
        self.queue.append(req)
        return req

    def step(self) -> int:
        """One engine iteration: admit queued requests into free slots
        (prefill), then one decode step over the slot pool.  Returns the
        number of live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.slot_pos)
        logits, self.cache = self._jit_decode(self.params, self.cache,
                                              tokens, pos)
        nxt = self._sample(logits)
        now = time.time()
        for i in live:
            req = self.slot_req[i]
            tok = int(nxt[i])
            if not req.output:
                req.t_first_token = now
            req.output.append(tok)
            self.last_token[i] = tok
            self.slot_pos[i] += 1
            self.slot_budget[i] -= 1
            hit_eos = (self.ecfg.eos_token >= 0 and tok == self.ecfg.eos_token)
            if self.slot_budget[i] <= 0 or hit_eos or \
                    self.slot_pos[i] >= self.ecfg.kv_len:
                req.done = True
                req.t_done = now
                self.finished.append(req)
                self.slot_req[i] = None      # slot freed → continuous batching
        return sum(r is not None for r in self.slot_req)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)):
            self.step()
            it += 1
            if it > max_iters:
                raise RuntimeError("engine did not drain")
        return self.finished

    # -- internals ---------------------------------------------------------------
    def _admit(self):
        for slot in range(self.ecfg.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            if plen + 1 >= self.ecfg.kv_len:
                raise ValueError(f"prompt ({plen}) ≥ kv_len ({self.ecfg.kv_len})")
            logits, pcache = self._jit_prefill(
                self.params, jnp.asarray(req.prompt)[None, :])
            self._write_slot(slot, pcache)
            nxt = self._sample(logits)
            req.output = [int(nxt[0])]
            req.t_first_token = time.time()
            self.slot_req[slot] = req
            self.slot_pos[slot] = plen
            budget = req.max_new_tokens or self.ecfg.max_new_tokens
            self.slot_budget[slot] = budget - 1
            self.last_token[slot] = int(nxt[0])

    def _write_slot(self, slot: int, pcache):
        """Insert a batch-1 prefill cache into slot ``slot`` of the pool.

        Cache leaves are stacked (R, B, ...); SSM/recurrent state leaves
        are (R, B, ...) as well — the batch axis is always axis 1.
        """
        def ins(pool, one):
            one = one.astype(pool.dtype)
            # pad/crop the kv-depth axis if prefill produced shorter S
            if one.shape[2:] != pool.shape[2:] and one.ndim >= 3:
                pad = [(0, 0)] * one.ndim
                pad[2] = (0, pool.shape[2] - one.shape[2])
                one = jnp.pad(one, pad)
            idx = (slice(None), slice(slot, slot + 1))
            return pool.at[idx].set(one)

        self.cache = jax.tree_util.tree_map(ins, self.cache, pcache)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.ecfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.ecfg.temperature, axis=-1))

    # -- stats ---------------------------------------------------------------
    def stats(self) -> dict:
        done = self.finished
        if not done:
            return {"finished": 0}
        lat = [r.t_done - r.t_enqueue for r in done]
        ttft = [r.t_first_token - r.t_enqueue for r in done]
        toks = sum(len(r.output) for r in done)
        span = max(r.t_done for r in done) - min(r.t_enqueue for r in done)
        return {
            "finished": len(done),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "mean_latency_s": float(np.mean(lat)),
            "mean_ttft_s": float(np.mean(ttft)),
        }
