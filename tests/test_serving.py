"""Serving engine: continuous batching, slot reuse, decode==teacher-forced
consistency, stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    return cfg, params


def _engine(cfg, params, **kw):
    defaults = dict(max_batch=3, kv_len=48, max_new_tokens=6, impl="ref")
    defaults.update(kw)
    return ServingEngine(cfg, params, EngineConfig(**defaults))


def test_engine_drains_all_requests(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=8))
            for _ in range(7)]
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(r.done and len(r.output) == 6 for r in reqs)


def test_continuous_batching_reuses_slots(small_model):
    """More requests than slots: the engine must cycle slots (finished →
    freed → re-admitted) rather than waiting for a full drain."""
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=2)
    rng = np.random.default_rng(1)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, size=4))
    live_trace = []
    while eng.queue or any(r is not None for r in eng.slot_req):
        live_trace.append(eng.step())
    assert len(eng.finished) == 5
    assert max(live_trace) <= 2                 # never exceeds the pool
    assert sum(1 for x in live_trace if x == 2) >= 2  # pool actually shared


def test_greedy_decode_matches_teacher_forcing(small_model):
    """Engine greedy outputs == argmax chain from repeated full forwards."""
    cfg, params = small_model
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    eng = _engine(cfg, params, max_batch=1, max_new_tokens=5)
    eng.submit(prompt)
    eng.run_until_drained()
    got = eng.finished[0].output

    toks = list(prompt)
    want = []
    for _ in range(5):
        logits, _ = T.prefill(params, cfg,
                              {"tokens": jnp.asarray([toks], jnp.int32)},
                              kv_cap=48, compute_dtype=jnp.bfloat16)
        nxt = int(jnp.argmax(logits[0]))
        want.append(nxt)
        toks.append(nxt)
    assert got == want, (got, want)


def test_prompt_too_long_rejected(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params, kv_len=16)
    eng.submit(np.arange(20) % cfg.vocab_size)
    with pytest.raises(ValueError, match="kv_len"):
        eng.step()


def test_stats(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    eng.submit(np.asarray([1, 2, 3]))
    eng.run_until_drained()
    s = eng.stats()
    assert s["finished"] == 1
    assert s["tokens"] == 6
    assert s["tokens_per_s"] > 0
    assert s["mean_ttft_s"] <= s["mean_latency_s"]


def test_temperature_sampling_varies(small_model):
    cfg, params = small_model
    outs = set()
    for seed in range(3):
        eng = _engine(cfg, params, temperature=5.0, seed=seed, max_batch=1)
        eng.submit(np.asarray([1, 2, 3]))
        eng.run_until_drained()
        outs.add(tuple(eng.finished[0].output))
    assert len(outs) > 1


def test_moe_arch_serves(small_model):
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1),
                           param_dtype=jnp.float32)
    eng = _engine(cfg, params, max_batch=2, max_new_tokens=4)
    eng.submit(np.asarray([1, 2, 3, 4]))
    eng.submit(np.asarray([4, 3, 2, 1]))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.output) == 4 for r in done)
