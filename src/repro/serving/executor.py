"""Executor layer: the jitted device programs of the serving engine and
its single device→host transfer point.

Everything that touches XLA lives here — the fused decode step
(decode → sample → bookkeeping with a donated cache), the packed ragged
prefill, the chunked-prefill continuation, the per-request prefill+insert
of the sequential baseline, and the ``fused=False`` host-looped pieces.
The executor owns the (optionally quantised) parameters, the mesh plans
(``parallel.sharding.serving_decode_plan`` / ``serving_prefill_plan``)
and the host-transfer accounting; it holds **no** request or slot
bookkeeping — callers pass ``(cache, state)`` in and adopt what comes
back, so scheduling policy (``scheduler.py``) and slot lifecycle
(``pool.py``) are independently testable.

The function bodies are the pre-layering engine's jitted cores, moved
verbatim: under the default config every compiled program, donation
alias and sampled token is bit-identical to the monolith (pinned by
``tests/test_serving.py`` against recorded token streams).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.parallel.api import activate_plan


class Executor:
    def __init__(self, cfg: ModelConfig, params, ecfg, *, mesh=None):
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        if ecfg.weight_bits:
            from repro.quant.core import quantize_params
            self.params = quantize_params(params, ecfg.weight_bits,
                                          group=ecfg.weight_group)

        # optional decode/prefill sharding plans for the slot pool
        self._plan = None
        self._prefill_plan = None
        self.shard_ctx = None          # consumed by SlotPool for the cache
        if mesh is not None:
            from repro.parallel.sharding import (serving_decode_plan,
                                                 serving_prefill_plan)
            self._plan, self.shard_ctx = serving_decode_plan(
                cfg, mesh, max_batch=ecfg.max_batch, kv_len=ecfg.kv_len)
            self._prefill_plan, _ = serving_prefill_plan(
                cfg, mesh, prefill_chunk=min(ecfg.prefill_chunk
                                             or min(128, ecfg.kv_len),
                                             ecfg.kv_len))

        # host-transfer accounting (benchmarks/perf_serving.py)
        self.host_transfers = 0
        self.host_bytes = 0

        # -- speculative decoding (dormant unless ecfg.spec_k > 0) ----------
        # self-speculation drafts with a quantised copy of the serving
        # params (spec_draft_bits; 0 = the serving params themselves);
        # draft-model speculation gets its (cfg, params) via set_draft()
        self.draft_cfg = cfg
        self.draft_params = None
        if getattr(ecfg, "spec_k", 0) > 0:
            if ecfg.spec_draft == "self":
                if ecfg.spec_draft_bits:
                    from repro.quant.core import quantize_params
                    self.draft_params = quantize_params(
                        params, ecfg.spec_draft_bits, group=ecfg.weight_group)
                else:
                    self.draft_params = self.params
            self.jit_spec_step = jax.jit(self._spec_step_fn,
                                         donate_argnums=(2, 3, 4))
            self.jit_draft_prefill = jax.jit(self._draft_prefill_fn,
                                             donate_argnums=(1,))

        # -- fused path ------------------------------------------------------
        self.jit_step = jax.jit(self._fused_step_fn, donate_argnums=(1, 2))
        self.jit_prefill_insert = jax.jit(self._prefill_insert_fn,
                                          donate_argnums=(1, 2))
        self.jit_packed_prefill = jax.jit(self._packed_prefill_fn,
                                          donate_argnums=(1, 2))
        self.jit_chunk_step = jax.jit(self._chunk_step_fn,
                                      donate_argnums=(1, 2))
        # -- seed-compat path (fused=False) ----------------------------------
        self.jit_decode = jax.jit(self._decode_fn)
        self.jit_prefill = jax.jit(self._prefill_fn)
        self.jit_insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    # -- device→host choke point ---------------------------------------------
    def fetch(self, x) -> np.ndarray:
        """The engine's single device→host transfer point (explicit, so
        tests can fence everything else with a d2h transfer guard)."""
        arr = jax.device_get(x)
        arr = np.asarray(arr)
        self.host_transfers += 1
        self.host_bytes += arr.nbytes
        return arr

    # -- public wrappers (what the engine drives) ------------------------------
    def fused_step(self, cache, state):
        return self.jit_step(self.params, cache, state)

    def prefill_insert(self, cache, state, tokens, slot, length, budget):
        return self.jit_prefill_insert(self.params, cache, state, tokens,
                                       slot, length, budget)

    def packed_prefill(self, cache, state, *args):
        return self.jit_packed_prefill(self.params, cache, state, *args)

    def chunk_step(self, cache, state, *args):
        return self.jit_chunk_step(self.params, cache, state, *args)

    def spec_step(self, cache, state, dcache=None):
        """One speculative decode step: draft ``spec_k`` tokens, verify
        them in a single batched multi-position call, commit the accepted
        prefix and roll back the rest.  ``dcache`` is the draft-model KV
        pool (None for self-speculation, which shares ``cache``)."""
        return self.jit_spec_step(self.params, self.draft_params, cache,
                                  dcache, state)

    def set_draft(self, draft_cfg, draft_params):
        """Attach a separate draft model (spec_draft='model')."""
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params

    def draft_prefill(self, dcache, tokens, slot, length):
        """Mirror a completed prompt into the draft-model cache (one
        padded batch-1 draft prefill + slot insert)."""
        return self.jit_draft_prefill(self.draft_params, dcache, tokens,
                                      slot, length)

    def decode(self, cache, tokens, pos):
        return self.jit_decode(self.params, cache, tokens, pos)

    def prefill(self, tokens, length):
        return self.jit_prefill(self.params, tokens, length)

    def insert(self, cache, pcache, slot, length):
        return self.jit_insert(cache, pcache, slot, length)

    def sample_host(self, logits, key):
        """Host-path sampling (fused=False baseline): returns the sampled
        token array (fetched) and the advanced PRNG key."""
        if self.ecfg.temperature <= 0.0:
            return self.fetch(jnp.argmax(logits, axis=-1)), key
        key, sub = jax.random.split(key)
        return self.fetch(jax.random.categorical(
            sub, logits / self.ecfg.temperature, axis=-1)), key

    # -- jitted cores: fused path ---------------------------------------------
    def _sample_dev(self, logits, key):
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits / self.ecfg.temperature,
                                     axis=-1)
        return nxt.astype(jnp.int32), key

    def _fused_step_fn(self, params, cache, state):
        """decode → sample → bookkeeping, all on device.  Runs
        ``decode_chunk`` iterations (lax.scan for >1) and returns the new
        (cache, state) plus a packed (K, 3, B) int32 of (next_token | -1,
        done, anomaly) — the only array the host reads back per step.

        A slot whose logits come back non-finite is *frozen*: no token
        committed, pos/budget untouched, still live — the identical step
        re-runs next iteration (the KV write at the same pos is
        idempotent), so a transient fault costs one retry and a persistent
        one is quarantined by the host without touching the other slots
        (decode is batch-parallel, no cross-slot mixing).  With finite
        logits ``ok == live`` and every value below reduces to the
        anomaly-free step bit-identically."""
        def one(carry, _):
            cache, state = carry
            live = state["live"]
            # dead / mid-prefill slots write at pos -1 → dropped, so a
            # half-prefilled row is never corrupted by the decode sweep
            pos_w = jnp.where(live, state["pos"], -1)
            logits, cache = T.decode_step(params, self.cfg, cache,
                                          state["tokens"], pos_w,
                                          impl=self.ecfg.impl)
            nxt, key = self._sample_dev(logits, state["key"])
            bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
            ok = live & ~bad
            pos_new = jnp.where(ok, state["pos"] + 1, state["pos"])
            budget_new = jnp.where(ok, state["budget"] - 1, state["budget"])
            done = (budget_new <= 0) | (pos_new >= self.ecfg.kv_len)
            if self.ecfg.eos_token >= 0:
                done = done | (nxt == self.ecfg.eos_token)
            done = ok & done
            packed = jnp.stack([jnp.where(ok, nxt, -1),
                                done.astype(jnp.int32),
                                (live & bad).astype(jnp.int32)])
            state = {
                "tokens": jnp.where(ok, nxt, state["tokens"]),
                "pos": pos_new,
                "budget": budget_new,
                "live": live & ~done,
                "key": key,
            }
            return (cache, state), packed

        with activate_plan(self._plan):
            chunk = max(1, self.ecfg.decode_chunk)
            if chunk == 1:
                (cache, state), packed = one((cache, state), None)
                packed = packed[None]
            else:
                (cache, state), packed = jax.lax.scan(
                    one, (cache, state), None, length=chunk)
        return cache, state, packed

    def _prefill_insert_fn(self, params, cache, state, tokens, slot, length,
                           budget):
        """prompt forward pass → first-token sample → slot insert → state
        update, one jitted cache-donated call per admission (sequential
        baseline + non-packable architectures)."""
        with activate_plan(self._plan):
            logits, pcache = T.prefill(params, self.cfg, {"tokens": tokens},
                                       impl=self.ecfg.impl,
                                       kv_cap=self.ecfg.kv_len, length=length,
                                       kv_bits=self.ecfg.kv_bits)
            nxt, key = self._sample_dev(logits, state["key"])
            tok = nxt[0]
            cache = self._insert_fn(cache, pcache, slot, length)
            state = {
                "tokens": state["tokens"].at[slot].set(tok),
                "pos": state["pos"].at[slot].set(length),
                "budget": state["budget"].at[slot].set(budget - 1),
                "live": state["live"].at[slot].set(budget > 1),
                "key": key,
            }
        return cache, state, tok

    def _insert_fn(self, cache, pcache, slot, length):
        """Insert a batch-1 prefill cache into slot ``slot`` of the pool
        with one ``dynamic_update_slice`` per leaf (batch axis is axis 1 of
        every stacked leaf).  ``pos`` entries at cache indices >= ``length``
        are invalidated so right-padding never leaves attendable entries
        (exact-length prefill makes it a no-op; ring caches only hold
        positions < length)."""
        def ins(path, pool, one):
            one = one.astype(pool.dtype)
            if str(getattr(path[-1], "key", "")) == "pos":
                idx = jnp.arange(one.shape[-1], dtype=jnp.int32)
                one = jnp.where(idx[None, None, :] < length, one, -1)
            start = (0, slot) + (0,) * (one.ndim - 2)
            return jax.lax.dynamic_update_slice(pool, one, start)

        return jax.tree_util.tree_map_with_path(ins, cache, pcache)

    def _packed_prefill_fn(self, params, cache, state, tokens, positions,
                           seg, gather_idx, seg_off, seg_len, final, budget,
                           active):
        """One ragged prefill for every admitted segment: packed forward
        pass (segment-masked attention) → per-segment first-token sample →
        one multi-slot scatter insert → state update.  Segment id == target
        slot index; ``active`` masks unused slots, ``final`` the segments
        whose prompt completed in this stream (non-final = first chunk of a
        long prompt, which only inserts KV)."""
        with activate_plan(self._prefill_plan):
            logits, pcache = T.prefill_packed(
                params, self.cfg, tokens, positions, seg, gather_idx,
                impl=self.ecfg.impl, kv_bits=self.ecfg.kv_bits)
        with activate_plan(self._plan):
            nxt, key = self._sample_dev(logits, state["key"])
            cache = self._packed_insert(cache, pcache["stack"], seg,
                                        positions, seg_len, active)
            fin = active & final
            state = {
                "tokens": jnp.where(fin, nxt, state["tokens"]),
                "pos": jnp.where(fin, seg_len, state["pos"]),
                "budget": jnp.where(fin, budget - 1, state["budget"]),
                "live": jnp.where(fin, budget > 1, state["live"]),
                "key": key,
            }
        return cache, state, jnp.where(fin, nxt, -1)

    def _packed_insert(self, cache, pstack, seg, positions, seg_len, active):
        """Scatter each packed segment into its KV slot — one scatter per
        cache leaf for the whole admission burst (replaces the per-request
        ``dynamic_update_slice`` loop).  Validity is governed entirely by
        the ``pos`` leaves, so those rows are rebuilt per slot (ring slot
        ``s`` of a cap-``c`` cache holds position ``p ≡ s (mod c)``,
        ``p ∈ [len-c, len)`` — identity layout for global caches), while
        k/v/latent leaves scatter the C packed tokens straight to their
        (slot, ring index) targets — O(C) work, independent of pool size."""
        B = self.ecfg.max_batch
        tgt = jnp.where(active, jnp.arange(B), B)       # B = dropped
        seg1 = seg[0]                                    # (C,) slot id, -1 pad
        pos1 = positions[0]                              # (C,) within-seg pos

        from repro.models.attention import ring_positions

        def ins(path, pool, packed):
            cap = pool.shape[2]
            if str(getattr(path[-1], "key", "")) == "pos":
                p = ring_positions(seg_len[:, None], cap)   # (B, cap)
                valid = (p >= 0) & active[:, None]
                rows = jnp.broadcast_to(
                    jnp.where(valid, p, -1)[None], (pool.shape[0], B, cap))
                return pool.at[:, tgt].set(rows, mode="drop")
            # only the last `cap` tokens of a segment survive its ring —
            # dropping the rest keeps scatter targets unique
            keep = (seg1 >= 0) & (pos1 >= jnp.take(seg_len, jnp.clip(seg1, 0),
                                                   mode="clip") - cap)
            row = jnp.where(keep, seg1, B)
            ring = jnp.where(keep, pos1 % cap, cap)
            return pool.at[:, row, ring].set(
                packed[:, 0].astype(pool.dtype), mode="drop")

        new_stack = [jax.tree_util.tree_map_with_path(ins, pool, packed)
                     for pool, packed in zip(cache["stack"], pstack)]
        return {"stack": new_stack}

    def _chunk_step_fn(self, params, cache, state, tokens, pos, take_idx,
                       final, budget):
        """One chunked-prefill continuation over the pool: write each
        prefilling row's next chunk into its cache at explicit positions,
        attend to the whole cache, and activate rows whose prompt completed
        (sample their first token)."""
        with activate_plan(self._plan):
            logits, cache = T.chunk_prefill_step(
                params, self.cfg, cache, tokens, pos, take_idx,
                impl=self.ecfg.impl)
            nxt, key = self._sample_dev(logits, state["key"])
            pos_end = jnp.max(jnp.where(pos >= 0, pos + 1, 0), axis=1)
            state = {
                "tokens": jnp.where(final, nxt, state["tokens"]),
                "pos": jnp.where(final, pos_end, state["pos"]),
                "budget": jnp.where(final, budget - 1, state["budget"]),
                "live": jnp.where(final, budget > 1, state["live"]),
                "key": key,
            }
        return cache, state, jnp.where(final, nxt, -1)

    # -- jitted cores: speculative decoding -----------------------------------
    def _spec_cols(self, cache, p):
        """Snapshot the ``spec_k + 1`` cache columns at ring indices
        ``(p + j) % cap`` of every leaf — everything a speculative step can
        write — so the rollback can scatter the pre-step bytes back for
        rejected positions.  Exact under ring aliasing because
        ``spec_k + 1 <= cap`` (validated at engine init) keeps the gathered
        indices of a row unique."""
        K1 = self.ecfg.spec_k + 1
        jj = jnp.arange(K1, dtype=jnp.int32)

        def take(pool):
            cap = pool.shape[2]
            ring = (p[:, None] + jj[None, :]) % cap          # (B, K1)
            bidx = jnp.arange(pool.shape[1])[:, None]
            return pool[:, bidx, ring]                       # (R, B, K1, ...)

        return jax.tree_util.tree_map(take, cache)

    def _spec_restore(self, cache, saved, p, mask):
        """Scatter saved columns back where ``mask`` (B, spec_k+1) is set —
        the jitted truncate-on-reject (and the pre-verify scratch wipe), one
        donation-friendly scatter per leaf."""
        K1 = self.ecfg.spec_k + 1
        jj = jnp.arange(K1, dtype=jnp.int32)

        def put(pool, sv):
            cap = pool.shape[2]
            ring = (p[:, None] + jj[None, :]) % cap
            bidx = jnp.arange(pool.shape[1])[:, None]
            cur = pool[:, bidx, ring]
            m = mask.reshape((1,) + mask.shape + (1,) * (cur.ndim - 3))
            return pool.at[:, bidx, ring].set(jnp.where(m, sv, cur))

        return jax.tree_util.tree_map(put, cache, saved)

    def _spec_step_fn(self, params, dparams, cache, dcache, state):
        """The speculative analogue of ``_fused_step_fn``: K sequential
        draft decode steps (draft params / draft cache), one batched
        ``verify_step`` scoring ``[t0, d_1..d_K]`` at positions
        ``p..p+K``, greedy or rejection-sampling acceptance, then a
        saved-column rollback of everything past the committed prefix.

        Commit accounting per live row: with ``n`` accepted drafts the step
        commits ``c = m + 1`` tokens ``[d_1..d_m, t_next]`` where
        ``m = min(n, budget-1, kv_len-1-pos, eos_idx)`` — the cache ends
        valid through ``pos + m`` (the K/V of every committed *input*) and
        the last committed token becomes the new pending token at
        ``pos + c``, exactly the invariant the non-speculative step
        maintains one token at a time.  Rows whose verify logits are
        non-finite are frozen bit-exactly (all K+1 columns restored, state
        untouched) and flagged on the anomaly channel.

        Returns ``(cache, dcache, state, packed)`` with ``packed`` a
        ``(spec_k+1, 4, B)`` int32 of (token | -1, done, anomaly,
        n_accepted) — still one host transfer per step."""
        ecfg = self.ecfg
        K, B = ecfg.spec_k, ecfg.max_batch
        K1 = K + 1
        live, p, t0 = state["live"], state["pos"], state["tokens"]
        self_draft = dcache is None
        jidx = jnp.arange(K1, dtype=jnp.int32)

        with activate_plan(self._plan):
            saved = self._spec_cols(cache, p)
            if not self_draft:
                dsaved = self._spec_cols(dcache, p)

            # -- draft: K sequential decode steps at draft precision --------
            def dstep(carry, _):
                dc, tok, dpos, key = carry
                pos_w = jnp.where(live, dpos, -1)
                logits, dc = T.decode_step(dparams, self.draft_cfg, dc, tok,
                                           pos_w, impl=ecfg.impl)
                nxt, key = self._sample_dev(logits, key)
                return (dc, nxt, dpos + 1, key), (nxt, logits)

            dc0 = cache if self_draft else dcache
            (dc1, _, _, key), (dtoks, dlogits) = jax.lax.scan(
                dstep, (dc0, t0, p, state["key"]), None, length=K)
            dtoks = dtoks.T                                  # (B, K)
            dlogits = jnp.swapaxes(dlogits, 0, 1)            # (B, K, V)
            if self_draft:
                # wipe the draft's scratch K/V: the verify chunk requires
                # every valid cache position strictly below the in-stream
                # block's, and the restore returns the exact pre-draft bytes
                cache = self._spec_restore(
                    dc1, saved, p, jnp.broadcast_to(live[:, None], (B, K1)))
            else:
                dcache = dc1

            # -- verify: score [t0, d_1..d_K] in one chunk call -------------
            vtoks = jnp.concatenate([t0[:, None], dtoks], axis=1)   # (B, K1)
            vpos = jnp.where(live[:, None], p[:, None] + jidx[None, :], -1)
            vlogits, cache = T.verify_step(params, self.cfg, cache, vtoks,
                                           vpos, impl=ecfg.impl)

            bad = ~jnp.all(jnp.isfinite(vlogits), axis=(1, 2))      # (B,)
            ok = live & ~bad

            # -- acceptance -------------------------------------------------
            if ecfg.temperature <= 0.0:
                tgt = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)
                acc = dtoks == tgt[:, :K]
                n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1),
                            axis=1)
                t_next = jnp.take_along_axis(tgt, n[:, None], axis=1)[:, 0]
            else:
                # rejection sampling (Leviathan et al.): accept d_j iff
                # u * q(d_j) <= p(d_j); on first reject resample from the
                # normalised residual max(p - q, 0); q := 0 past the drafts
                # so full acceptance samples from the final target dist
                tau = ecfg.temperature
                qd = jax.nn.softmax(dlogits.astype(jnp.float32) / tau, -1)
                pt = jax.nn.softmax(vlogits.astype(jnp.float32) / tau, -1)
                q_at = jnp.take_along_axis(qd, dtoks[..., None], -1)[..., 0]
                p_at = jnp.take_along_axis(pt[:, :K], dtoks[..., None],
                                           -1)[..., 0]
                key, ku, kr = jax.random.split(key, 3)
                u = jax.random.uniform(ku, (B, K))
                acc = u * q_at <= p_at
                n = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1),
                            axis=1)
                p_n = jnp.take_along_axis(pt, n[:, None, None], axis=1)[:, 0]
                qpad = jnp.concatenate([qd, jnp.zeros_like(pt[:, :1])], 1)
                q_n = jnp.take_along_axis(qpad, n[:, None, None],
                                          axis=1)[:, 0]
                res = jnp.maximum(p_n - q_n, 0.0)
                res = res / jnp.maximum(res.sum(-1, keepdims=True), 1e-30)
                t_next = jax.random.categorical(
                    kr, jnp.log(jnp.maximum(res, 1e-30)), axis=-1
                ).astype(jnp.int32)

            # -- commit bound: budget, cache depth, eos ---------------------
            comm = jnp.concatenate([dtoks, jnp.zeros((B, 1), jnp.int32)], 1)
            comm = jnp.where(jidx[None, :] == n[:, None], t_next[:, None],
                             comm)
            m = jnp.minimum(n, state["budget"] - 1)
            m = jnp.minimum(m, ecfg.kv_len - 1 - p)
            eos_idx = jnp.full((B,), K1, jnp.int32)
            if ecfg.eos_token >= 0:
                eos_idx = jnp.min(jnp.where(comm == ecfg.eos_token,
                                            jidx[None, :], K1), axis=1)
                m = jnp.minimum(m, eos_idx)
            m = jnp.maximum(m, 0)

            # -- rollback past the committed prefix -------------------------
            mask = live[:, None] & ((jidx[None, :] > m[:, None])
                                    | bad[:, None])
            cache = self._spec_restore(cache, saved, p, mask)
            if not self_draft:
                # full acceptance leaves the draft cache one entry short
                # (input d_K at pos p+K was never drafted): one catch-up
                # decode step writes it, logits discarded
                cu_pos = jnp.where(ok & (m == K), p + K, -1)
                _, dcache = T.decode_step(dparams, self.draft_cfg, dcache,
                                          dtoks[:, K - 1], cu_pos,
                                          impl=ecfg.impl)
                dcache = self._spec_restore(dcache, dsaved, p, mask)

            # -- state update ----------------------------------------------
            c = jnp.where(ok, m + 1, 0)
            t_last = jnp.take_along_axis(comm, m[:, None], axis=1)[:, 0]
            pos_new = p + c
            budget_new = state["budget"] - c
            done = ok & ((budget_new <= 0) | (pos_new >= ecfg.kv_len)
                         | (eos_idx <= m))
            state = {
                "tokens": jnp.where(ok, t_last, state["tokens"]),
                "pos": pos_new,
                "budget": budget_new,
                "live": live & ~done,
                "key": key,
            }

            # -- packed host array (K+1, 4, B) ------------------------------
            tok_rows = jnp.where((jidx[:, None] <= m[None, :]) & ok[None, :],
                                 comm.T, -1)
            done_rows = ((jidx[:, None] == m[None, :])
                         & done[None, :]).astype(jnp.int32)
            row0 = (jidx[:, None] == 0)
            anom_rows = jnp.where(row0, (live & bad)[None, :], False)
            acc_rows = jnp.where(row0 & ok[None, :], n[None, :], 0)
            packed = jnp.stack([tok_rows, done_rows,
                                anom_rows.astype(jnp.int32), acc_rows],
                               axis=1)
        return cache, dcache, state, packed

    def _draft_prefill_fn(self, dparams, dcache, tokens, slot, length):
        """Batch-1 prompt prefill through the *draft* model, inserted into
        the draft KV pool — keeps the draft cache in lockstep with the
        target when a separate draft model speculates."""
        with activate_plan(self._prefill_plan):
            _, pcache = T.prefill(dparams, self.draft_cfg, {"tokens": tokens},
                                  impl=self.ecfg.impl,
                                  kv_cap=self.ecfg.kv_len, length=length)
        return self._insert_fn(dcache, pcache, slot, length)

    # -- jitted cores: seed-compat path ---------------------------------------
    def _decode_fn(self, params, cache, tokens, pos):
        logits, cache = T.decode_step(params, self.cfg, cache, tokens, pos,
                                      impl=self.ecfg.impl)
        return logits, cache

    def _prefill_fn(self, params, tokens, length):
        # single-request prefill padded to a bucketed length (static shape)
        logits, cache = T.prefill(params, self.cfg, {"tokens": tokens},
                                  impl=self.ecfg.impl, kv_cap=self.ecfg.kv_len,
                                  length=length, kv_bits=self.ecfg.kv_bits)
        return logits, cache
