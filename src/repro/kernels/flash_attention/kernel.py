"""Pallas TPU flash-attention forward kernel (contiguous and ragged/packed).

TPU-native adaptation of the paper's SM-chiplet attention dataflow: the
paper partitions Q/K/V across SM chiplets with the FlashAttention schedule
and fuses score+softmax so the O(N²) intermediate never crosses the NoI
(§3.2 steps 2-4).  On TPU the analogous fast/slow boundary is VMEM↔HBM:
this kernel tiles Q into MXU-aligned blocks held in VMEM, streams K/V
blocks through, and keeps the online-softmax running statistics (m, l) and
the output accumulator in VMEM scratch for the whole K/V sweep.

Grid: ``(B, Hq, Sq/bq, Skv/bk)`` — the trailing (minor) grid axis is
sequential on TPU, so scratch carries state across the K/V sweep of each
Q block.  GQA folds the head-group mapping into the K/V index_map.

**Ragged / packed-segment mode** (``segments=``): multiple prompts are
packed back-to-back into one token stream; ``segments`` gives each token
its prompt id (``-1`` = pad).  Masking adds a same-segment predicate, so a
query never attends across a prompt boundary.  Because segments are
contiguous, packed-index causality + segment equality is exactly
within-prompt causality, and the packed-index distance equals the
positional distance for the sliding window.  Tiles whose mask is entirely
false — causally-dead tiles at trace time, segment-crossing tiles at run
time — skip the MXU work entirely.

Forward only: the serving path (the paper's setting — inference) uses it
directly; training uses the reference path (XLA fuses adequately there and
the dry-run needs portable HLO).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.common import NEG_INF, block_size, vmem


def _flash_fwd_kernel(
    *refs,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    kv_len: int,
    segmented: bool,
):
    if segmented:
        (q_ref, k_ref, v_ref, qseg_ref, kseg_ref,
         o_ref, m_scr, l_scr, acc_scr) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # the mask depends only on indices and segment ids — computed before the
    # MXU body so a fully-masked tile (segment-crossing, pad-only) skips the
    # matmuls entirely
    mask = k_idx < kv_len
    if causal:
        mask &= k_idx <= q_idx
    if window:
        mask &= q_idx - k_idx < window
    if segmented:
        qseg = qseg_ref[0][:, None]
        mask &= (qseg == kseg_ref[0][None, :]) & (qseg >= 0)  # pad q rows -> 0

    # grid-structural skip (trace-time shape, no data needed) ...
    block_needed = True
    if causal:
        block_needed = jnp.logical_and(block_needed, ik * bk <= iq * bq + bq - 1)
    if window:
        block_needed = jnp.logical_and(block_needed, (iq * bq) - (ik * bk + bk - 1) < window)
    # ... plus the data-dependent skip for segment-crossing tiles
    block_needed = jnp.logical_and(block_needed, jnp.any(mask))

    @pl.when(block_needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hdv)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # explicit zero for masked entries: a row that is fully masked
        # within a computed block (pad row in a mixed tile) has
        # m_new == NEG_INF, where exp(s - m_new) would be exp(0) = 1
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)    # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,   # (B, Hq, Sq, hd)
    k: jax.Array,   # (B, Hkv, Skv, hd)
    v: jax.Array,   # (B, Hkv, Skv, hdv)
    *,
    segments: jax.Array | None = None,   # (B, S) int32 prompt ids, -1 = pad
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, hdv = v.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    bq = block_size(block_q, Sq)
    bk = block_size(block_k, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")
    if segments is not None and Sq != Skv:
        raise ValueError("packed-segment attention is self-attention: Sq must equal Skv")

    grid = (B, Hq, Sq // bq, Skv // bk)
    kern = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_len=Skv,
        segmented=segments is not None)

    in_specs = [
        pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
        pl.BlockSpec((1, 1, bk, hdv), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
    ]
    operands = [q, k, v]
    if segments is not None:
        seg = segments.astype(jnp.int32)
        in_specs += [
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),   # q-side ids
            pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),   # k-side ids
        ]
        operands += [seg, seg]

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, hdv), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hdv), q.dtype),
        scratch_shapes=[
            vmem((bq, 1)),
            vmem((bq, 1)),
            vmem((bq, hdv)),
        ],
        interpret=interpret,
    )(*operands)
