"""Property-based tests (hypothesis) on the paper-plane invariants:
space-filling curves, placement constraints, NoI evaluation, Pareto/PHV."""
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core.sfc import CURVES, curve_positions
from repro.core.placement import (Placement, initial_placement, mesh_links,
                                  neighbors, random_placement)
from repro.core.noi import evaluate_noi, mesh_baseline_eval
from repro.core.traffic import Workload, transformer_phases
from repro.core.moo import Archive, dominates, hypervolume, pareto_front


# ---------------------------------------------------------------------------
# space-filling curves
# ---------------------------------------------------------------------------

@given(st.sampled_from(sorted(CURVES)),
       st.integers(1, 5).map(lambda k: 2 ** k),
       st.integers(1, 5).map(lambda k: 2 ** k))
@settings(max_examples=60, deadline=None)
def test_sfc_bijective(curve, w, h):
    """Every curve visits every cell exactly once."""
    pos = curve_positions(curve, w, h)
    assert pos.shape == (w * h, 2)
    cells = {(int(x), int(y)) for x, y in pos}
    assert len(cells) == w * h
    assert all(0 <= x < w and 0 <= y < h for x, y in cells)


@given(st.sampled_from(["hilbert", "boustrophedon"]),
       st.integers(2, 5).map(lambda k: 2 ** k))
@settings(max_examples=20, deadline=None)
def test_sfc_contiguity(curve, n):
    """Hilbert/boustrophedon consecutive steps are grid neighbours
    (contiguity = the property the paper uses for the ReRAM macro)."""
    pos = curve_positions(curve, n, n)
    d = np.abs(np.diff(pos, axis=0)).sum(axis=1)
    assert int(d.max()) == 1


def test_hilbert_locality_beats_rowmajor():
    """Mean |Δposition| over index windows: Hilbert preserves locality
    better than row-major — the reason the paper prefers SFCs."""
    n = 16
    h = curve_positions("hilbert", n, n).astype(float)
    r = curve_positions("rowmajor", n, n).astype(float)

    def window_spread(pos, k=8):
        sp = []
        for i in range(0, len(pos) - k):
            win = pos[i:i + k]
            sp.append(np.abs(win - win.mean(0)).sum(1).mean())
        return float(np.mean(sp))

    assert window_spread(h) < window_spread(r)


# ---------------------------------------------------------------------------
# placement moves keep the paper's constraints
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from([36, 64, 100]))
@settings(max_examples=30, deadline=None)
def test_placement_moves_preserve_constraints(seed, n_chiplets):
    rng = random.Random(seed)
    p = random_placement(n_chiplets, rng)
    budget = len(mesh_links(p.grid_w, p.grid_h))
    for q in neighbors(p, rng, k=6):
        assert q.connected(), "constraint 1: no islands"
        assert len(q.links) <= budget, "constraint 2: ≤ mesh link budget"
        # chiplet multiset preserved by swaps
        assert sorted(q.types) == sorted(p.types)
        # reram_order is a permutation of the ReRAM cells
        assert sorted(q.reram_order) == sorted(
            i for i, t in enumerate(q.types) if t == "ReRAM")


def test_initial_placement_reram_macro_contiguous():
    for n in (36, 64, 100):
        p = initial_placement(n)
        xy = np.array([p.xy(i) for i in p.reram_order])
        d = np.abs(np.diff(xy, axis=0)).sum(axis=1)
        assert int(d.max()) == 1, "ReRAM macro must be SFC-contiguous"


# ---------------------------------------------------------------------------
# NoI evaluation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bert_phases():
    from repro.config import get_config
    w = Workload.from_config(get_config("bert-base"), seq_len=64)
    return transformer_phases(w)


def test_noi_eval_finite_and_positive(bert_phases):
    ev = mesh_baseline_eval(36, bert_phases)
    assert np.isfinite(ev.mu) and ev.mu > 0
    assert np.isfinite(ev.sigma)
    assert ev.max_util >= ev.mu
    assert ev.total_byte_hops > 0


def test_noi_disconnected_is_infeasible(bert_phases):
    p = initial_placement(36)
    # cut the grid in half vertically
    p.links = {(a, b) for (a, b) in p.links
               if not (a % p.grid_w == 2 and b == a + 1)}
    if not p.connected():
        ev = evaluate_noi(p, bert_phases)
        assert ev.mu == np.inf


def test_noi_traffic_conservation(bert_phases):
    """Total byte-hops ≥ total bytes injected (every flow crosses ≥1 link)."""
    from repro.core.traffic import phase_traffic_matrix
    p = initial_placement(36)
    ev = evaluate_noi(p, bert_phases)
    injected = 0.0
    for ph in bert_phases:
        F = phase_traffic_matrix(ph, p.roles(), p.n)
        injected += sum(F.values()) * ph.repeat
    assert ev.total_byte_hops >= injected * 0.999


def test_more_links_cannot_hurt_best_case(bert_phases):
    """Adding a direct link between the two hottest chiplets cannot raise
    total byte-hops under shortest-path routing (sanity of the router)."""
    p = initial_placement(36)
    ev0 = evaluate_noi(p, bert_phases)
    q = p.copy()
    # link the ReRAM head to an MC directly
    roles = q.roles()
    a, b = roles["ReRAM"][0], roles["MC"][0]
    q.links.add((min(a, b), max(a, b)))
    ev1 = evaluate_noi(q, bert_phases)
    assert ev1.total_byte_hops <= ev0.total_byte_hops + 1e-6


# ---------------------------------------------------------------------------
# Pareto / hypervolume
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_pareto_front_is_mutually_nondominated(pts):
    idx = pareto_front(pts)
    assert idx, "front never empty"
    for i in idx:
        for j in idx:
            if i != j:
                assert not dominates(pts[i], pts[j])


@given(st.lists(st.tuples(st.floats(0.1, 5), st.floats(0.1, 5)),
                min_size=1, max_size=20),
       st.tuples(st.floats(0.1, 5), st.floats(0.1, 5)))
@settings(max_examples=60, deadline=None)
def test_archive_add_monotone_phv(pts, extra):
    """Adding a point never lowers the Pareto hypervolume."""
    ref = (10.0, 10.0)
    arch = Archive()
    prev = 0.0
    for p in pts:
        arch.add(None, p)
        cur = arch.phv(ref)
        assert cur >= prev - 1e-9
        prev = cur


def test_hypervolume_2d_exact():
    # single point (1,1) vs ref (2,2) -> area 1
    assert hypervolume(np.array([[1.0, 1.0]]), np.array([2.0, 2.0])) == 1.0
    # two staircase points
    hv = hypervolume(np.array([[1.0, 2.0], [2.0, 1.0]]),
                     np.array([3.0, 3.0]))
    assert abs(hv - 3.0) < 1e-9


def test_hypervolume_mc_close_to_exact():
    pts = np.array([[1.0, 1.0, 1.0]])
    ref = np.array([2.0, 2.0, 2.0])
    hv = hypervolume(pts, ref, n_mc=20_000)
    assert abs(hv - 1.0) < 0.08


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_dominates_antisymmetry(seed):
    rng = np.random.default_rng(seed)
    a = tuple(rng.random(3))
    b = tuple(rng.random(3))
    assert not (dominates(a, b) and dominates(b, a))
    assert not dominates(a, a)
