"""Serving engine: continuous batching, slot reuse, decode==teacher-forced
consistency, stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    return cfg, params


def _engine(cfg, params, **kw):
    defaults = dict(max_batch=3, kv_len=48, max_new_tokens=6, impl="ref")
    defaults.update(kw)
    return ServingEngine(cfg, params, EngineConfig(**defaults))


def test_engine_drains_all_requests(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=8))
            for _ in range(7)]
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(r.done and len(r.output) == 6 for r in reqs)


def test_continuous_batching_reuses_slots(small_model):
    """More requests than slots: the engine must cycle slots (finished →
    freed → re-admitted) rather than waiting for a full drain."""
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=2)
    rng = np.random.default_rng(1)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, size=4))
    live_trace = []
    while eng.queue or any(r is not None for r in eng.slot_req):
        live_trace.append(eng.step())
    assert len(eng.finished) == 5
    assert max(live_trace) <= 2                 # never exceeds the pool
    assert sum(1 for x in live_trace if x == 2) >= 2  # pool actually shared


def test_greedy_decode_matches_teacher_forcing(small_model):
    """Engine greedy outputs == argmax chain from repeated full forwards."""
    cfg, params = small_model
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    eng = _engine(cfg, params, max_batch=1, max_new_tokens=5)
    eng.submit(prompt)
    eng.run_until_drained()
    got = eng.finished[0].output

    toks = list(prompt)
    want = []
    for _ in range(5):
        logits, _ = T.prefill(params, cfg,
                              {"tokens": jnp.asarray([toks], jnp.int32)},
                              kv_cap=48, compute_dtype=jnp.bfloat16)
        nxt = int(jnp.argmax(logits[0]))
        want.append(nxt)
        toks.append(nxt)
    assert got == want, (got, want)


def test_prompt_too_long_rejected(small_model):
    """Over-long prompts are rejected at submit time (clear ValueError),
    not deep inside a jitted step."""
    cfg, params = small_model
    eng = _engine(cfg, params, kv_len=16)
    with pytest.raises(ValueError, match="kv_len"):
        eng.submit(np.arange(20) % cfg.vocab_size)
    assert not eng.queue                     # nothing enqueued


def test_stats(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    eng.submit(np.asarray([1, 2, 3]))
    eng.run_until_drained()
    s = eng.stats()
    assert s["finished"] == 1
    assert s["tokens"] == 6
    assert s["tokens_per_s"] > 0
    assert s["mean_ttft_s"] <= s["mean_latency_s"]


def test_temperature_sampling_varies(small_model):
    cfg, params = small_model
    outs = set()
    for seed in range(3):
        eng = _engine(cfg, params, temperature=5.0, seed=seed, max_batch=1)
        eng.submit(np.asarray([1, 2, 3]))
        eng.run_until_drained()
        outs.add(tuple(eng.finished[0].output))
    assert len(outs) > 1


def _outputs_by_uid(eng):
    return [r.output for r in sorted(eng.finished, key=lambda r: r.uid)]


def _drain_workload(cfg, params, **kw):
    eng = _engine(cfg, params, **kw)
    rng = np.random.default_rng(7)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, size=3 + 2 * i))
    eng.run_until_drained()
    return eng


def test_fused_step_matches_host_path(small_model):
    """The fused on-device step must reproduce the seed engine's outputs
    exactly (greedy, fixed seed, slot churn across 6 requests / 2 slots)."""
    cfg, params = small_model
    host = _drain_workload(cfg, params, max_batch=2, fused=False)
    fused = _drain_workload(cfg, params, max_batch=2, fused=True)
    assert _outputs_by_uid(host) == _outputs_by_uid(fused)


def test_flash_engine_matches_ref_engine(small_model):
    """impl='flash' (Pallas decode kernel, interpret on CPU) end-to-end
    against impl='ref' through the same fused engine."""
    cfg, params = small_model
    ref = _drain_workload(cfg, params, max_batch=2)
    fl = _drain_workload(cfg, params, max_batch=2, impl="flash")
    assert _outputs_by_uid(ref) == _outputs_by_uid(fl)


def test_decode_chunk_matches_unchunked(small_model):
    """decode_chunk>1 (multi-step scheduling: one lax.scan of K decode
    iterations per host sync) must emit token-for-token identical outputs,
    including requests that finish mid-chunk."""
    cfg, params = small_model
    one = _drain_workload(cfg, params, max_batch=2, max_new_tokens=5)
    chk = _drain_workload(cfg, params, max_batch=2, max_new_tokens=5,
                          decode_chunk=4)
    assert _outputs_by_uid(one) == _outputs_by_uid(chk)


def test_single_host_transfer_per_decode_iteration(small_model):
    """Steady-state decode makes exactly one device→host transfer per
    iteration (the packed (2,B) token/done array); everything else is
    fenced off by a d2h transfer guard."""
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=2, max_new_tokens=8)
    eng.submit(np.asarray([1, 2, 3, 4]))
    eng.submit(np.asarray([5, 6, 7]))
    eng.step()                       # admissions + first decode
    base = eng.host_transfers
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(3):
            eng.step()
    assert eng.host_transfers - base == 3
    assert eng.host_bytes > 0


def test_no_recompilation_across_drain(small_model):
    """Sequential path: one compiled fused step for the whole drain;
    prefill compiles at most once per prompt-length bucket.  (The packed
    default compiles once total — tests/test_packed_prefill.py.)"""
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=3, max_new_tokens=4, packed=False)
    rng = np.random.default_rng(3)
    for plen in (3, 5, 8, 10, 12, 4):          # buckets: 8, 16
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    assert eng._jit_step._cache_size() == 1
    assert eng._jit_prefill_insert._cache_size() <= 2


def test_max_new_tokens_zero_and_one(small_model):
    """A request's own budget wins over the engine default — including 0
    (the seed's ``or`` swapped in the default) and 1 (off-by-one)."""
    cfg, params = small_model
    eng = _engine(cfg, params)                 # engine default: 6
    r0 = eng.submit(np.asarray([1, 2, 3]), max_new_tokens=0)
    r1 = eng.submit(np.asarray([1, 2, 3]), max_new_tokens=1)
    r2 = eng.submit(np.asarray([1, 2, 3]), max_new_tokens=3)
    eng.run_until_drained()
    assert r0.done and r0.output == []
    assert r1.done and len(r1.output) == 1
    assert r2.done and len(r2.output) == 3


def test_moe_arch_serves(small_model):
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1),
                           param_dtype=jnp.float32)
    eng = _engine(cfg, params, max_batch=2, max_new_tokens=4)
    eng.submit(np.asarray([1, 2, 3, 4]))
    eng.submit(np.asarray([4, 3, 2, 1]))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.output) == 4 for r in done)


# ---------------------------------------------------------------------------
# resilience: validation, shedding, deadlines, anomaly quarantine, stall
# ---------------------------------------------------------------------------

def test_submit_validation(small_model):
    """Malformed submissions fail loudly at submit(), never inside a
    jitted step: wrong rank, empty, float dtype, negative budget."""
    cfg, params = small_model
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(np.asarray([[1, 2], [3, 4]]))
    with pytest.raises(ValueError, match="at least one token"):
        eng.submit(np.asarray([], np.int32))
    with pytest.raises(ValueError, match="integer"):
        eng.submit(np.asarray([1.0, 2.0]))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.asarray([1, 2, 3]), max_new_tokens=-1)
    assert not eng.queue


def test_bounded_queue_sheds_not_strands(small_model):
    """With max_queue set, overload is shed as retriable REJECTED at
    submit; admitted requests still finish — every request terminal."""
    from repro.serving.engine import DONE, REJECTED
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=1, max_new_tokens=2, max_queue=2)
    reqs = [eng.submit(np.asarray([1, 2, 3])) for _ in range(5)]
    statuses = [r.status for r in reqs]
    assert statuses.count(REJECTED) == 3
    eng.run_until_drained()
    assert [r.status for r in reqs].count(DONE) == 2
    assert all(r.terminal for r in reqs)
    assert all(r.output == [] for r in reqs if r.status == REJECTED)
    s = eng.stats()
    assert s["rejected"] == 3 and s["finished"] == 2


class FakeClock:
    """Injectable EngineConfig(clock=): deterministic, no sleeping."""

    def __init__(self, t: float = 100.0, auto_advance: float = 0.0):
        self.t, self.auto = t, auto_advance

    def __call__(self) -> float:
        self.t += self.auto
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def test_deadline_expires_queued_request(small_model):
    """A request whose deadline passes while still queued is evicted as
    FAILED_DEADLINE on the next step — it never occupies a slot.  Driven
    by an injected fake clock: no wall-clock sleeps."""
    from repro.serving.engine import FAILED_DEADLINE
    cfg, params = small_model
    clk = FakeClock()
    eng = _engine(cfg, params, max_batch=1, deadline_ms=20, clock=clk)
    r = eng.submit(np.asarray([1, 2, 3]))
    clk.advance(0.05)
    eng.step()
    assert r.status == FAILED_DEADLINE and r.terminal
    assert not eng.queue and all(x is None for x in eng.slot_req)
    assert eng.stats()["failed_deadline"] == 1


def test_deadline_evicts_mid_decode(small_model):
    """An in-flight request past its deadline is evicted mid-decode with
    whatever tokens it produced — the drain terminates.  The fake clock
    self-advances per reading, so expiry is deterministic in iterations
    rather than host speed."""
    from repro.serving.engine import FAILED_DEADLINE
    cfg, params = small_model
    clk = FakeClock(auto_advance=0.005)
    eng = _engine(cfg, params, max_batch=1, deadline_ms=30,
                  max_new_tokens=200_000, clock=clk)
    r = eng.submit(np.asarray([1, 2, 3, 4]))
    eng.run_until_drained()
    assert r.status == FAILED_DEADLINE and r.terminal
    assert len(r.output) < 200_000


def test_clock_injection_defaults_to_monotonic(small_model):
    """Default EngineConfig wires time.monotonic; an injected clock is
    the one the engine actually reads."""
    import time
    cfg, params = small_model
    assert _engine(cfg, params).ecfg.clock is time.monotonic
    clk = FakeClock(t=42.0)
    eng = _engine(cfg, params, clock=clk)
    r = eng.submit(np.asarray([1, 2, 3]))
    assert r.t_enqueue == clk.t


def test_run_until_drained_marks_stranded(small_model):
    """max_iters exhaustion is an explicit failure: EngineStallError, and
    every stranded request lands in FAILED_MAX_ITERS (regression for the
    silent-partial-drain bug)."""
    from repro.serving.engine import FAILED_MAX_ITERS, EngineStallError
    cfg, params = small_model
    eng = _engine(cfg, params, max_batch=1, max_new_tokens=50)
    reqs = [eng.submit(np.asarray([1, 2, 3])) for _ in range(4)]
    with pytest.raises(EngineStallError, match="did not drain"):
        eng.run_until_drained(max_iters=2)
    assert all(r.terminal for r in reqs)
    assert any(r.status == FAILED_MAX_ITERS for r in reqs)
    assert not eng.queue and all(x is None for x in eng.slot_req)
    assert eng.stats()["failed_max_iters"] >= 1


def _poison_slot(cache, slot):
    """NaN one slot's KV pages (batch axis 1 of every stacked leaf)."""
    return jax.tree_util.tree_map(
        lambda x: x.at[:, slot].set(jnp.nan)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, cache)


def test_nan_quarantine_spares_the_batch(small_model):
    """A slot producing non-finite logits is quarantined and failed alone;
    the co-resident request's output stays bit-identical to a clean run."""
    from repro.serving.engine import DONE, FAILED_ANOMALY
    cfg, params = small_model
    good_prompt = np.asarray([1, 2, 3, 4])
    bad_prompt = np.asarray([7, 8, 9])

    ref = _engine(cfg, params, max_batch=2, max_new_tokens=5)
    ref.submit(good_prompt)
    ref.run_until_drained()
    want = ref.finished[0].output

    eng = _engine(cfg, params, max_batch=2, max_new_tokens=5)
    good = eng.submit(good_prompt)
    bad = eng.submit(bad_prompt)
    eng.step()                                   # both admitted + 1 decode
    victim = eng.slot_req.index(bad)
    eng.cache = _poison_slot(eng.cache, victim)
    eng.run_until_drained()
    assert bad.status == FAILED_ANOMALY and bad.terminal
    assert good.status == DONE and good.output == want
    assert eng.stats()["failed_anomaly"] == 1


def test_transient_anomaly_retries_and_recovers(small_model):
    """A transient non-finite step within the retry budget freezes the
    slot (same position, no token emitted) and retries: once the fault
    clears the request completes with the clean-run output, exactly."""
    cfg, params = small_model
    prompt = np.asarray([1, 2, 3, 4])

    ref = _engine(cfg, params, max_batch=1, max_new_tokens=6)
    ref.submit(prompt)
    ref.run_until_drained()
    want = ref.finished[0].output

    eng = _engine(cfg, params, max_batch=1, max_new_tokens=6,
                  anomaly_retries=3)
    r = eng.submit(prompt)
    eng.step()
    snap = jax.tree_util.tree_map(jnp.copy, eng.cache)
    eng.cache = _poison_slot(eng.cache, 0)
    eng.step()                                   # anomaly: frozen, no token
    eng.cache = snap                             # fault clears
    eng.run_until_drained()
    assert r.done and r.output == want
    assert eng.stats()["failed_anomaly"] == 0


def test_default_config_has_no_failure_paths(small_model):
    """Defaults (no deadline, unbounded queue) leave the failure machinery
    dormant: all DONE, zero failure counters."""
    from repro.serving.engine import DONE
    cfg, params = small_model
    eng = _drain_workload(cfg, params, max_batch=2)
    assert all(r.status == DONE for r in eng.finished)
    s = eng.stats()
    assert s["failed"] == 0 and s["rejected"] == 0
