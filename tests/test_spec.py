"""Speculative decoding: lossless greedy streams, saved-column KV
rollback, chunked-prefill interaction, dormancy, and the Plane-B
acceptance-parameterised traffic model.

Greedy speculation is lossless by construction — accepted drafts equal
the target argmax at their position and the bonus/correction token *is*
the target argmax after the accepted prefix — so every greedy spec drain
must reproduce the non-speculative token streams bit-for-bit, whatever
the draft quality.  The draft only changes *cadence* (decode steps,
acceptance counters), never content.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, ServingEngine

# every engine-servable zoo model (decoder-only, packable): the
# acceptance-1 bit-identity contract must hold on all of them
SERVABLE = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b")

_MODELS = {}


def _model(arch: str):
    if arch not in _MODELS:
        cfg = reduce_config(get_config(arch))
        _MODELS[arch] = (cfg, T.init_params(cfg, jax.random.PRNGKey(0),
                                            param_dtype=jnp.float32))
    return _MODELS[arch]


@pytest.fixture(scope="module")
def small_model():
    return _model("qwen2.5-3b")


def _drain(cfg, params, *, n_req=4, draft=None, **kw):
    defaults = dict(max_batch=2, kv_len=48, max_new_tokens=6, impl="ref")
    defaults.update(kw)
    eng = ServingEngine(cfg, params, EngineConfig(**defaults), draft=draft)
    rng = np.random.default_rng(7)
    for i in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=3 + 2 * i))
    eng.run_until_drained()
    outs = {r.uid: list(map(int, r.output))
            for r in sorted(eng.finished, key=lambda r: r.uid)}
    return eng, outs


def _tree_equal(a, b) -> bool:
    fa = jax.tree_util.tree_leaves_with_path(a)
    fb = dict(jax.tree_util.tree_leaves_with_path(b))
    return all(np.array_equal(np.asarray(x), np.asarray(fb[k]))
               for k, x in fa)


# ---------------------------------------------------------------------------
# tentpole: greedy speculative streams are bit-identical to plain decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SERVABLE)
def test_acceptance_one_bit_identical_streams(arch):
    """spec_draft_bits=0 drafts with the serving params themselves, so the
    verify pass accepts every draft (acceptance exactly 1) and the spec
    engine must emit the plain engine's streams in ~1/(k+1) the steps."""
    cfg, params = _model(arch)
    base, want = _drain(cfg, params)
    eng, outs = _drain(cfg, params, spec_k=4, spec_draft_bits=0)
    assert outs == want
    s = eng.stats()
    assert s["spec_acceptance"] == 1.0
    assert s["spec_tokens_per_step"] == pytest.approx(5.0)
    assert eng.decode_steps < base.decode_steps


@pytest.mark.parametrize("bits", [8, 4])
def test_lossy_self_draft_streams_still_exact(small_model, bits):
    """int8/int4 self-drafts mispredict, but greedy acceptance commits
    only target-argmax tokens — the streams stay exact while the
    acceptance rate (and step count) degrades."""
    cfg, params = small_model
    _, want = _drain(cfg, params)
    eng, outs = _drain(cfg, params, spec_k=4, spec_draft_bits=bits)
    assert outs == want
    s = eng.stats()
    assert 0.0 <= s["spec_acceptance"] <= 1.0
    # prefill emits each request's first token; spec steps commit the rest
    assert s["spec_committed"] == s["tokens"] - s["finished"]


def test_draft_model_speculation_streams_exact(small_model):
    """A separate (here: 1-layer, randomly initialised — worst-case)
    draft model drives the same lossless greedy contract through the
    draft-cache ingest/rollback path."""
    cfg, params = small_model
    dcfg = dataclasses.replace(cfg, n_layers=1, name=cfg.name + "-draft")
    dparams = T.init_params(dcfg, jax.random.PRNGKey(9),
                            param_dtype=jnp.float32)
    _, want = _drain(cfg, params)
    eng, outs = _drain(cfg, params, spec_k=3, spec_draft="model",
                       draft=(dcfg, dparams))
    assert outs == want
    assert eng.pool.draft_cache is not None
    assert eng.stats()["spec_draft"] == "model"


def test_quantized_target_with_spec_streams_exact(small_model):
    """Speculation composes with the quantised serving path: the w8kv8
    engine's own greedy streams are the reference."""
    cfg, params = small_model
    _, want = _drain(cfg, params, weight_bits=8, kv_bits=8)
    _, outs = _drain(cfg, params, weight_bits=8, kv_bits=8,
                     spec_k=4, spec_draft_bits=4)
    assert outs == want


# ---------------------------------------------------------------------------
# rollback: rejected drafts leave the slot pool bit-identical
# ---------------------------------------------------------------------------

def test_spec_step_touches_only_committed_columns(small_model):
    """One draft+verify step against a live slot: every cache column
    outside the committed ring range ``p .. p+m`` must come back
    byte-identical to the pre-step pool — the saved-column restore
    erased the drafts' speculative writes beyond the accepted prefix
    (and the step never touched anything else)."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=1, kv_len=48, max_new_tokens=8, impl="ref",
        spec_k=4, spec_draft_bits=4))
    rng = np.random.default_rng(7)
    eng.submit(rng.integers(0, cfg.vocab_size, size=5))
    eng.step()                            # admission + prefill
    assert eng.pool.occupied() == 1
    p = eng.pool.valid_len(0)
    pre = {k: np.asarray(v).copy() for k, v in
           jax.tree_util.tree_leaves_with_path(eng.pool.cache)}
    c0 = eng.spec_committed
    eng.step()                            # one speculative step
    m = eng.spec_committed - c0 - 1       # accepted drafts (commit = m+1)
    assert 0 <= m <= 4
    post = dict(jax.tree_util.tree_leaves_with_path(eng.pool.cache))
    for key, before in pre.items():
        after = np.asarray(post[key])
        cap = before.shape[2]             # axis 2 is the ring for all leaves
        touched = {(p + j) % cap for j in range(m + 1)}
        for c in range(cap):
            if c not in touched:
                assert np.array_equal(before[:, :, c], after[:, :, c]), \
                    f"column {c} of {jax.tree_util.keystr(key)} changed"


def test_rejection_rollback_quantized_pool_positions_identical(small_model):
    """The kv8 pool quantises from chunk-mode f32 values whose last-ulp
    can differ from the decode path, so full byte-identity is not the
    contract there — but the *validity* plane (per-layer pos leaves) and
    the emitted streams must match the plain kv8 engine exactly.
    ``max_batch=1`` pins slot assignment: with more slots the faster
    spec drain legally admits requests into different slots."""
    cfg, params = small_model
    base, want = _drain(cfg, params, kv_bits=8, max_batch=1)
    eng, outs = _drain(cfg, params, kv_bits=8, max_batch=1,
                       spec_k=4, spec_draft_bits=4)
    assert outs == want
    pos_a = [(k, v) for k, v in
             jax.tree_util.tree_leaves_with_path(eng.pool.cache)
             if "pos" in jax.tree_util.keystr(k)]
    pos_b = dict(jax.tree_util.tree_leaves_with_path(base.pool.cache))
    assert pos_a
    for k, v in pos_a:
        assert np.array_equal(np.asarray(v), np.asarray(pos_b[k]))


def test_saved_column_restore_roundtrip_byte_exact(small_model):
    """The device rollback primitive itself: corrupt the spec_k+1 ring
    columns of a live cache, then restore from the saved columns — the
    cache must come back byte-identical everywhere."""
    cfg, params = small_model
    eng, _ = _drain(cfg, params, spec_k=4, spec_draft_bits=0)
    ex = eng.executor
    cache = eng.pool.cache
    B = eng.ecfg.max_batch
    p = jnp.asarray(np.arange(B) % 7 + 3, jnp.int32)
    ones = jnp.ones((B, eng.ecfg.spec_k + 1), bool)
    saved = ex._spec_cols(cache, p)
    garbage = jax.tree_util.tree_map(lambda a: a * 0 - 1, saved)
    corrupted = ex._spec_restore(cache, garbage, p, ones)
    restored = ex._spec_restore(corrupted, saved, p, ones)
    assert not _tree_equal(corrupted, cache)
    assert _tree_equal(restored, cache)


# ---------------------------------------------------------------------------
# scheduling interactions: chunked prefill, temperature, dormancy
# ---------------------------------------------------------------------------

def test_spec_through_chunked_prefill_keeps_stall_invariant(small_model):
    """spec_k composes with chunked prefill: streams match the chunked
    baseline and no admission burst stalls decode for more than two
    chunk budgets (one continuation + one packed admission per step)."""
    cfg, params = small_model
    _, want = _drain(cfg, params, n_req=6, prefill_chunk=8,
                     max_new_tokens=4)
    eng, outs = _drain(cfg, params, n_req=6, prefill_chunk=8,
                       max_new_tokens=4, spec_k=4, spec_draft_bits=0)
    assert outs == want
    assert eng.stats()["max_stall_tokens"] <= 2 * 8


def test_spec_temperature_rejection_sampling_drains(small_model):
    """The temperature path (rejection sampling + residual resample) is
    distributional, not stream-pinned: it must drain every request with
    full budgets and sane acceptance accounting."""
    cfg, params = small_model
    eng, outs = _drain(cfg, params, temperature=0.8, seed=3,
                       spec_k=4, spec_draft_bits=8)
    assert len(outs) == 4
    assert all(len(v) == 6 for v in outs.values())
    s = eng.stats()
    assert 0.0 <= s["spec_acceptance"] <= 1.0
    assert s["spec_committed"] == s["tokens"] - s["finished"]


def test_spec_dormant_stats_carry_no_spec_keys(small_model):
    """spec_k=0 engines must not grow stats keys — the dormancy half of
    the bit-identity contract (the golden fixtures pin the streams)."""
    cfg, params = small_model
    eng, _ = _drain(cfg, params)
    assert not any(k.startswith("spec_") for k in eng.stats())
    spec_eng, _ = _drain(cfg, params, spec_k=2, spec_draft_bits=0)
    assert "spec_acceptance" in spec_eng.stats()


def test_spec_config_validation():
    cfg, params = _model("qwen2.5-3b")
    with pytest.raises(ValueError, match="fused"):
        ServingEngine(cfg, params, EngineConfig(
            max_batch=2, kv_len=48, packed=False, spec_k=2))
    with pytest.raises(ValueError, match="decode_chunk"):
        ServingEngine(cfg, params, EngineConfig(
            max_batch=2, kv_len=48, decode_chunk=2, spec_k=2))
    with pytest.raises(ValueError, match="ring"):
        ServingEngine(cfg, params, EngineConfig(
            max_batch=2, kv_len=4, spec_k=4))
    with pytest.raises(ValueError, match="draft"):
        ServingEngine(cfg, params, EngineConfig(
            max_batch=2, kv_len=48, spec_k=2, spec_draft="model"))


# ---------------------------------------------------------------------------
# Plane B: acceptance-parameterised traffic + cosim threading
# ---------------------------------------------------------------------------

def test_spec_tokens_per_step_curve():
    from repro.core.traffic import spec_tokens_per_step

    assert spec_tokens_per_step(4, 0.0) == 1.0
    assert spec_tokens_per_step(4, 1.0) == 5.0
    es = [spec_tokens_per_step(4, a) for a in (0.0, 0.3, 0.6, 0.9, 1.0)]
    assert all(a < b for a, b in zip(es, es[1:]))
    with pytest.raises(ValueError):
        spec_tokens_per_step(4, 1.5)


def test_spec_step_phases_k0_identity_and_monotone_bytes():
    """spec_k=0 returns the plain decode step unchanged (the PR 3-5
    batch pins stay pinned), and fabric bytes per committed token fall
    monotonically in acceptance at fixed step traffic."""
    from repro.core.traffic import (Workload, decode_step_phases,
                                    spec_decode_step_phases,
                                    spec_tokens_per_step,
                                    total_traffic_bytes)

    w = Workload.from_config(get_config("llama2-7b"), seq_len=128)
    assert (spec_decode_step_phases(w, 64, 4, spec_k=0)
            == decode_step_phases(w, 64, 4))
    dw = dataclasses.replace(w, weight_bits=8)
    step = total_traffic_bytes(
        spec_decode_step_phases(w, 64, 4, spec_k=4, draft_w=dw))
    per_tok = [step / (4 * spec_tokens_per_step(4, a))
               for a in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a > b for a, b in zip(per_tok, per_tok[1:]))
    # the verify pass streams target weights once: a whole spec step must
    # cost less than k+1 separate target steps plus k draft steps
    plain = total_traffic_bytes(decode_step_phases(w, 64, 4))
    assert step < (2 * 4 + 1) * plain


def test_spec_step_phases_reject_enc_dec():
    from repro.core.traffic import Workload, spec_decode_step_phases

    w = Workload.from_config(get_config("whisper-large-v3"), seq_len=32)
    with pytest.raises(ValueError, match="decoder-only"):
        spec_decode_step_phases(w, 8, 1, spec_k=2)


def test_cosim_threads_measured_acceptance(small_model):
    """cosim_from_engine on a speculative drain carries the measured
    acceptance into the mix, and generation_phases swaps the decode
    segment to draft+verify phases."""
    from repro.core.cosim import (cosim_from_engine, generation_phases,
                                  mix_from_stats)

    cfg, params = small_model
    eng, _ = _drain(cfg, params, spec_k=4, spec_draft_bits=8)
    out = cosim_from_engine(eng, "qwen2.5-3b", n_chiplets=36)
    assert out["mix"]["spec_k"] == 4
    assert 0.0 <= out["mix"]["spec_acceptance"] <= 1.0
    assert 1.0 <= out["mix"]["spec_tokens_per_step"] <= 5.0
    mix = mix_from_stats(eng.stats())
    names = {p.name for p in generation_phases("qwen2.5-3b", mix)}
    assert any(n.startswith("verify_") for n in names)
    # the dormant engine's mix carries no speculation
    base, _ = _drain(cfg, params)
    mix0 = mix_from_stats(base.stats())
    assert mix0.spec_k == 0 and mix0.expected_tokens_per_step == 1.0
    names0 = {p.name for p in generation_phases("qwen2.5-3b", mix0)}
    assert not any(n.startswith(("verify_", "draft")) for n in names0)
