import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
``jit(step).lower(input_specs).compile()`` on the 16×16 single-pod mesh and
the 2×16×16 multi-pod mesh, print ``memory_analysis()`` (proves fit) and
derive the three roofline terms (§Roofline) from the optimized HLO.

Results stream to ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` so
reruns are incremental.  The 512 fake host devices are forced by the first
two lines above — before any other import — and ONLY here.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.config import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import V5E, roofline_terms
from repro.models.transformer import count_params

OUT_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "dryrun"))

# grad-accumulation per arch for the train_4k cell (activation-memory knob;
# chosen during the §Perf loop — see EXPERIMENTS.md)
TRAIN_ACCUM = {
    "deepseek-v2-236b": 16,
    "llama-3.2-vision-90b": 16,
    "gemma3-27b": 8,
    "whisper-large-v3": 4,
    "gemma2-9b": 2,
    "recurrentgemma-9b": 2,
    "minitron-8b": 2,
    "qwen3-moe-30b-a3b": 2,
}


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active per trained token; 2·N_active per inferred
    token (fwd only), × tokens processed in the step."""
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             save_hlo: bool = False, force: bool = False) -> dict:
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok", "ts": time.time()}

    ok, reason = cfg.supports(shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        _save(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    accum = TRAIN_ACCUM.get(arch, 1) if shape.kind == "train" else 1
    # microbatches must still divide the data axes
    dp_size = mesh.shape["data"] * mesh.shape.get("pod", 1)
    while accum > 1 and (shape.global_batch // accum) % dp_size:
        accum //= 2

    try:
        t0 = time.time()
        jfn, args, plan = build_cell(cfg, shape, mesh, accum=accum)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        }
        live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
        mem["live_bytes"] = live
        # fits_v5e uses the TPU-true liveness: XLA:CPU float-normalization
        # materialises f32 work copies of loop-carried bf16 buffers (KV
        # caches, scan-stacked weights) that do not exist on TPU — they are
        # measured from the HLO and reported separately below.

        hlo = compiled.as_text()
        from repro.roofline.hlo import (cpu_bf16_promotion_bytes,
                                        cpu_bf16_promotion_bytes_serving,
                                        normalize_cost_analysis)
        ca = normalize_cost_analysis(compiled.cost_analysis())
        if shape.kind == "train":
            promo = cpu_bf16_promotion_bytes(hlo)
        else:
            promo = cpu_bf16_promotion_bytes_serving(hlo)
        promo = min(promo, ma.temp_size_in_bytes)
        floor = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 - ma.alias_size_in_bytes)
        mem["cpu_bf16_promotion_bytes"] = promo
        mem["live_bytes_tpu"] = max(live - promo, floor)
        mem["fits_v5e"] = bool(mem["live_bytes_tpu"] <= V5E.hbm_bytes)
        rep = roofline_terms(
            hlo, arch=arch, shape=shape_name, mesh_name=mesh_kind,
            n_devices=n_dev, model_flops=model_flops_for(cfg, shape))
        rec.update(
            t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
            n_devices=n_dev, accum=accum,
            memory=mem,
            xla_cost_analysis=ca,
            roofline=dataclasses.asdict(rep),
        )
        if save_hlo:
            hlo_path = out_path.replace(".json", ".hlo.txt")
            with open(hlo_path, "w") as f:
                f.write(hlo)
            rec["hlo_path"] = hlo_path
    except Exception as e:  # a failing cell is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(out_path, rec)
    return rec


def _save(path, rec):
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(path + ".tmp", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="", help="single arch (default: all)")
    ap.add_argument("--shape", default="", help="single shape (default: all)")
    ap.add_argument("--mesh", default="", choices=["", "single", "multi"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind,
                               save_hlo=args.save_hlo, force=args.force)
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    mem = rec["memory"]
                    print(f"{arch:22s} {shape:12s} {mesh_kind:6s} OK "
                          f"compile={rec['t_compile_s']:7.1f}s "
                          f"live={mem['live_bytes_tpu']/2**30:6.2f}GiB "
                          f"fits={mem['fits_v5e']} "
                          f"terms(c/m/n)={r['compute_s']:.3e}/"
                          f"{r['memory_s']:.3e}/{r['collective_s']:.3e}s "
                          f"bound={r['bottleneck']}", flush=True)
                elif status == "skipped":
                    print(f"{arch:22s} {shape:12s} {mesh_kind:6s} SKIP "
                          f"({rec['reason']})", flush=True)
                else:
                    n_bad += 1
                    print(f"{arch:22s} {shape:12s} {mesh_kind:6s} ERROR "
                          f"{rec['error']}", flush=True)
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
