"""Random-forest regressor (numpy, from scratch) — the MOO-STAGE surrogate.

The paper's evaluation-function learner ([10][39]) uses random forests for
speed and robustness on small tabular design-feature data; sklearn is not
available in this environment so we implement bagged CART regression trees
directly.  Property-tested in tests/test_moo.py (fits simple functions,
beats mean-predictor).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class RegressionTree:
    def __init__(self, max_depth=6, min_leaf=2, n_features=None, rng=None):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.n_features = n_features
        self.rng = rng or np.random.default_rng(0)
        self.nodes: list[_Node] = []

    def fit(self, X, y):
        self.nodes = []
        self._build(X, y, 0)
        return self

    def _build(self, X, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean())))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or y.std() < 1e-12:
            return idx
        d = X.shape[1]
        k = self.n_features or max(1, int(np.sqrt(d)))
        feats = self.rng.choice(d, size=min(k, d), replace=False)
        best = (None, None, np.inf)
        for f in feats:
            xs = X[:, f]
            order = np.argsort(xs)
            xs_s, y_s = xs[order], y[order]
            csum = np.cumsum(y_s)
            csq = np.cumsum(y_s ** 2)
            n = len(y_s)
            for cut in range(self.min_leaf, n - self.min_leaf):
                if xs_s[cut] == xs_s[cut - 1]:
                    continue
                nl, nr = cut, n - cut
                sl, sr = csum[cut - 1], csum[-1] - csum[cut - 1]
                ql, qr = csq[cut - 1], csq[-1] - csq[cut - 1]
                sse = (ql - sl * sl / nl) + (qr - sr * sr / nr)
                if sse < best[2]:
                    best = (f, 0.5 * (xs_s[cut] + xs_s[cut - 1]), sse)
        if best[0] is None:
            return idx
        f, thr, _ = best
        mask = X[:, f] <= thr
        node = self.nodes[idx]
        node.feature, node.threshold = int(f), float(thr)
        node.left = self._build(X[mask], y[mask], depth + 1)
        node.right = self._build(X[~mask], y[~mask], depth + 1)
        return idx

    def predict(self, X):
        out = np.empty(len(X))
        for i, x in enumerate(X):
            n = 0
            while self.nodes[n].feature >= 0:
                nd = self.nodes[n]
                n = nd.left if x[nd.feature] <= nd.threshold else nd.right
            out[i] = self.nodes[n].value
        return out


class RandomForest:
    """Bagged regression trees; the paper's surrogate learner."""

    def __init__(self, n_trees=24, max_depth=6, min_leaf=2, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.seed = seed
        self.trees: list[RegressionTree] = []
        self._fallback = 0.0

    def fit(self, X, y):
        X = np.asarray(X, float)
        y = np.asarray(y, float)
        self.trees = []
        self._fallback = float(y.mean()) if len(y) else 0.0
        if len(y) < 4:
            return self
        rng = np.random.default_rng(self.seed)
        n = len(y)
        for t in range(self.n_trees):
            idx = rng.integers(0, n, size=n)
            tree = RegressionTree(self.max_depth, self.min_leaf,
                                  rng=np.random.default_rng(self.seed + t + 1))
            tree.fit(X[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, X):
        X = np.atleast_2d(np.asarray(X, float))
        if not self.trees:
            return np.full(len(X), self._fallback)
        return np.mean([t.predict(X) for t in self.trees], axis=0)
