"""Three-term roofline from the compiled dry-run artifact (§Roofline).

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = wire_bytes  / (chips × link_bw)

HLO_FLOPs / bytes / wire-bytes come from :mod:`repro.roofline.hlo` (per
device, loop-corrected); hardware constants are TPU v5e per the assignment.
"""
from __future__ import annotations

import dataclasses

from repro.roofline.hlo import HloCost, analyze_hlo_text


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float        # bf16 FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link per chip (~busiest-link model)
    hbm_bytes: float         # capacity per chip


V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
             hbm_bytes=16 << 30)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_dev: float
    hbm_bytes_per_dev: float
    wire_bytes_per_dev: float
    model_flops: float            # 6·N_active·D tokens (train) / fwd analogue
    useful_ratio: float           # model_flops / (hlo_flops × devices)
    bottleneck: str = ""
    step_s: float = 0.0           # max of the three terms (no-overlap bound)
    roofline_frac: float = 0.0    # compute_s / step_s (1.0 = compute-bound)

    def finalize(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.step_s = max(terms.values())
        self.roofline_frac = (self.compute_s / self.step_s) if self.step_s else 0.0
        return self


def roofline_terms(hlo_text: str, *, arch: str, shape: str, mesh_name: str,
                   n_devices: int, model_flops: float,
                   hw: HwSpec = V5E) -> RooflineReport:
    cost: HloCost = analyze_hlo_text(hlo_text, num_devices=n_devices)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.bytes_hbm / hw.hbm_bw,
        collective_s=cost.total_collective_bytes / hw.ici_bw,
        hlo_flops_per_dev=cost.flops,
        hbm_bytes_per_dev=cost.bytes_hbm,
        wire_bytes_per_dev=cost.total_collective_bytes,
        model_flops=model_flops,
        useful_ratio=(model_flops / (cost.flops * n_devices)
                      if cost.flops else 0.0),
    )
    return rep.finalize()
