"""DeepSeek-V2 236B — MLA (kv_lora=512) + MoE 160 routed top-6, 2 shared.
[arXiv:2405.04434; hf]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA: latent cache replaces per-head KV
    head_dim=128,         # per-head no-rope q/k dim
    d_ff=12_288,          # dense FFN used by the first_k_dense layer
    d_ff_expert=1536,
    vocab_size=102_400,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    first_k_dense=1,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    act="silu",
    glu=True,
    source="arXiv:2405.04434",
    notes="MLA latent-KV cache (absorbed decode path); 2 shared experts",
))
