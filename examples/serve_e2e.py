"""End-to-end serving driver (the paper's setting is inference): bring up
the continuous-batching engine on a reduced assigned architecture and push
a batched request workload through it, reporting throughput/TTFT/latency —
then cross-check one greedy completion against teacher forcing.

Run:  PYTHONPATH=src python examples/serve_e2e.py [--arch gemma2-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=96)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    if cfg.family == "encoder":
        raise SystemExit("encoder-only archs have no decode step")
    print(f"arch={cfg.name} (reduced: {cfg.param_count()/1e6:.1f}M params), "
          f"slots={args.max_batch} kv_len={args.kv_len}")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           param_dtype=jnp.float32)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch, kv_len=args.kv_len,
        max_new_tokens=args.max_new_tokens, impl="ref"))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        engine.submit(rng.integers(0, cfg.vocab_size, size=plen))
    engine.run_until_drained()
    s = engine.stats()
    print(f"drained {s['finished']} requests / {s['tokens']} tokens in "
          f"{time.time()-t0:.1f}s -> {s['tokens_per_s']:.1f} tok/s, "
          f"TTFT {s['mean_ttft_s']*1e3:.0f} ms, "
          f"latency {s['mean_latency_s']*1e3:.0f} ms")

    # consistency check: engine greedy == teacher-forced argmax chain
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    engine2 = ServingEngine(cfg, params, EngineConfig(
        max_batch=1, kv_len=args.kv_len, max_new_tokens=6, impl="ref"))
    engine2.submit(prompt)
    engine2.run_until_drained()
    got = engine2.finished[0].output
    toks = list(prompt)
    want = []
    for _ in range(6):
        logits, _ = T.prefill(params, cfg,
                              {"tokens": jnp.asarray([toks], jnp.int32)},
                              kv_cap=args.kv_len)
        want.append(int(jnp.argmax(logits[0])))
        toks.append(want[-1])
    status = "MATCH" if got == want else f"MISMATCH ({got} vs {want})"
    print(f"incremental-vs-teacher-forced greedy decode: {status}")


if __name__ == "__main__":
    main()
