from repro.serving.engine import EngineConfig, Request, ServingEngine  # noqa: F401
