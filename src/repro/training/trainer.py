"""Fault-tolerant training driver.

Production posture for a 1000+-node job, scaled down to run anywhere:

- **Checkpoint/restart**: periodic atomic saves + preemption-triggered
  saves (SIGTERM) + resume-from-LATEST on construction.
- **Step retry**: transient executor failures (the CPU-container stand-in
  for a flaky host) are retried with backoff from the last good state —
  params/opt are only committed after the step completes.
- **Straggler watchdog**: an EMA of step wall-time; steps slower than
  ``slow_step_factor``× the EMA are counted and surfaced in metrics (on a
  real pod this signal feeds the scheduler's hot-spare swap; here it
  feeds the test suite).
- **Elastic re-mesh**: ``Trainer.remesh(new_mesh)`` re-builds the jitted
  step and re-shards live state onto a different device count; the
  counter-based data pipeline replays the identical token stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.config import ModelConfig, ShapeSpec
from repro.data.pipeline import DataConfig, DataState, LMDataPipeline
from repro.launch.steps import make_train_step, params_specs
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.training import checkpoint as CKPT
from repro.training.optimizer import OptConfig, adamw_init


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = ""
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    retry_backoff_s: float = 0.2
    slow_step_factor: float = 3.0
    ema_alpha: float = 0.2
    accum: int = 1
    impl: str = "ref"
    remat: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                 opt_cfg: OptConfig = OptConfig(),
                 tcfg: TrainerConfig = TrainerConfig(),
                 data_cfg: Optional[DataConfig] = None,
                 seed: int = 0):
        self.cfg, self.shape, self.tcfg, self.opt_cfg = cfg, shape, tcfg, opt_cfg
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=seed)
        self.pipeline = LMDataPipeline(self.data_cfg)
        self.step = 0
        self.metrics_log: list[dict] = []
        self.slow_steps = 0
        self._ema_dt: Optional[float] = None
        self.preemption = CKPT.PreemptionHandler()
        self._build(mesh)
        self._init_or_restore(seed)

    # -- construction -------------------------------------------------------
    def _build(self, mesh):
        self.mesh = mesh
        import jax.numpy as jnp
        plan, ctx = SH.build_plan(self.cfg, self.shape, mesh, mode="train")
        self.ctx = ctx
        pspecs = params_specs(self.cfg, jnp.float32)
        self.pshard = SH.params_shardings(pspecs, ctx)
        ospecs = jax.eval_shape(adamw_init, pspecs)
        from jax.sharding import NamedSharding, PartitionSpec as P
        self.oshard = {
            "m": SH.params_shardings(ospecs["m"], ctx),
            "v": SH.params_shardings(ospecs["v"], ctx),
            "count": NamedSharding(mesh, P()),
        }
        self._pspecs, self._ospecs = pspecs, ospecs
        bspecs = {"tokens": jax.ShapeDtypeStruct(
            (self.shape.global_batch, self.shape.seq_len), jnp.int32)}
        self.bshard = SH.batch_shardings(bspecs, ctx)
        fn = make_train_step(self.cfg, plan, opt_cfg=self.opt_cfg,
                             accum=self.tcfg.accum, impl=self.tcfg.impl,
                             remat=self.tcfg.remat)
        rep = NamedSharding(mesh, P())
        self.jstep = jax.jit(
            fn, in_shardings=(self.pshard, self.oshard, self.bshard),
            out_shardings=(self.pshard, self.oshard,
                           {"loss": rep, "gnorm": rep, "lr": rep}))

    def _init_or_restore(self, seed):
        t = self.tcfg
        if t.ckpt_dir and CKPT.latest_step(t.ckpt_dir) is not None:
            params, opt, meta = CKPT.restore_checkpoint(
                t.ckpt_dir, params_template=self._pspecs,
                opt_template=self._ospecs,
                shardings=self.pshard, opt_shardings=self.oshard)
            self.params, self.opt_state = params, opt
            self.step = int(meta["step"])
            ds = meta.get("data_state") or {}
            if ds:
                self.pipeline.state = DataState.from_dict(ds)
            self.pipeline.at_step(self.step)
            return
        key = jax.random.PRNGKey(seed)
        init = jax.jit(lambda k: T.init_params(self.cfg, k),
                       out_shardings=self.pshard)
        with self.mesh:
            self.params = init(key)
        self.opt_state = jax.jit(adamw_init, out_shardings=self.oshard)(self.params)

    # -- one step with retry + watchdog --------------------------------------
    def train_step(self, batch: dict[str, np.ndarray],
                   fault_hook: Optional[Callable[[int], None]] = None) -> dict:
        last_err: Optional[Exception] = None
        for attempt in range(self.tcfg.max_retries + 1):
            try:
                if fault_hook is not None:
                    fault_hook(attempt)  # test harness injects failures here
                t0 = time.time()
                with self.mesh:
                    new_p, new_o, metrics = self.jstep(
                        self.params, self.opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                # commit only after success
                self.params, self.opt_state = new_p, new_o
                self._watchdog(dt)
                metrics.update(step=self.step, dt=dt, retries=attempt,
                               slow_steps=self.slow_steps)
                self.step += 1
                self.pipeline.at_step(self.step)
                self.metrics_log.append(metrics)
                return metrics
            except (RuntimeError, ValueError, OSError) as e:  # executor fault
                last_err = e
                time.sleep(self.tcfg.retry_backoff_s * (2 ** attempt))
        raise RuntimeError(
            f"step {self.step} failed after {self.tcfg.max_retries + 1} "
            f"attempts") from last_err

    def _watchdog(self, dt: float):
        if self._ema_dt is None:
            self._ema_dt = dt
            return
        if dt > self.tcfg.slow_step_factor * self._ema_dt:
            self.slow_steps += 1
        a = self.tcfg.ema_alpha
        self._ema_dt = (1 - a) * self._ema_dt + a * dt

    # -- loop ----------------------------------------------------------------
    def run(self, n_steps: int) -> list[dict]:
        out = []
        for _ in range(n_steps):
            batch = self.pipeline.global_batch_at(self.step)
            m = self.train_step(batch)
            out.append(m)
            t = self.tcfg
            if t.ckpt_dir and (
                    self.preemption.should_save
                    or (t.ckpt_every and self.step % t.ckpt_every == 0)):
                self.save()
                if self.preemption.should_save:
                    self.preemption.reset()
                    break
        return out

    def save(self) -> Optional[str]:
        if not self.tcfg.ckpt_dir:
            return None
        return CKPT.save_checkpoint(
            self.tcfg.ckpt_dir, self.step,
            params=jax.device_get(self.params),
            opt_state=jax.device_get(self.opt_state),
            data_state=self.pipeline.state.to_dict(), keep=self.tcfg.keep)

    # -- elastic -------------------------------------------------------------
    def remesh(self, new_mesh):
        """Re-shard live state onto a new device topology (elastic scale
        up/down after losing or gaining hosts)."""
        host_params = jax.device_get(self.params)
        host_opt = jax.device_get(self.opt_state)
        self._build(new_mesh)
        self.params = jax.device_put(host_params, self.pshard)
        self.opt_state = jax.device_put(host_opt, self.oshard)
