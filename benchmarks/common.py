"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

from repro.profile.bench import measure


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, us_per_call) — median of ``repeat`` runs."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def drain_best(once, *, repeat: int = 3, score,
               clock=time.perf_counter):
    """Warm-up + best-of-repeat engine drains — the timing methodology
    every serving benchmark shares, routed through the calibration
    plane's micro-timer (``repro.profile.bench.measure``).

    ``once`` drains the engine once and returns its counter deltas; the
    first call absorbs all compiles (warm-up), the following ``repeat``
    calls are steady state, and the drain maximising
    ``score(result, dt_s)`` wins.

    Returns ``(warmup_result, best_result, best_dt_s, timing)`` where
    ``timing`` is the underlying :class:`repro.profile.bench.Timing`
    (compile-inclusive warm-up wall time + steady-state times).
    """
    results: list = []

    def call():
        results.append(once())
        return None

    timing = measure(call, warmup=1, repeat=repeat, clock=clock, sync=None)
    steady = list(zip(results[1:], timing.times_s))
    best_r, best_dt = max(steady, key=lambda rd: score(rd[0], rd[1]))
    return results[0], best_r, best_dt, timing


def emit(rows: list[dict], name: str):
    """Print a labelled CSV block (consumed by benchmarks.run + EXPERIMENTS)."""
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0].keys())
    print(f"# --- {name} ---")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
