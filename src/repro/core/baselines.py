"""Baseline architectures (§2, §4.2): HAIMA_chiplet, TransPIM_chiplet, the
original (non-chiplet) HAIMA/TransPIM, and the ReTransformer endurance
analysis (§4.4).

Execution models follow the paper's descriptions:

- **HAIMA_chiplet** [3]: SRAM chiplets compute score (eqs 5-6), DRAM-PIM
  chiplets compute self-attention projections + FF; host chiplets do the
  arithmetic (softmax) → per-layer host round-trips; disintegrated banks
  cause frequent SRAM↔DRAM exchange and contention.
- **TransPIM_chiplet** [2]: all kernels bit-serial row-parallel in DRAM-PIM;
  ACUs do vector reduction + softmax; token-sharing ring broadcast among
  memory chiplets carries activations (simple dataflow, lower energy, but
  per-kernel latency overhead from ACU hand-offs).
- **Originals**: monolithic 3-D PIM stacks whose concurrent bank activation
  is thermally capped (§4.3) — modelled as a fraction of banks active.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import chiplets as C
from repro.core.noi import evaluate_noi, noi_energy, noi_phase_time
from repro.core.placement import Placement, grid_for, initial_placement, mesh_links
from repro.core.simulator import Calib, CALIB, SimResult, _energy
from repro.core.traffic import BYTES, Phase, Workload, transformer_phases


def _baseline_placement(n_chiplets: int, kinds: dict) -> Placement:
    """Mesh-linked placement with the baseline's own chiplet mix, placed by
    the same MOO seed layout (iso-chiplet comparison, §4.1.1)."""
    w, h = grid_for(n_chiplets)
    types = []
    for t, cnt in kinds.items():
        types += [t] * cnt
    types += ["DRAM"] * (w * h - len(types))
    return Placement(w, h, types[: w * h], mesh_links(w, h),
                     [i for i, t in enumerate(types[: w * h]) if t == "ReRAM"])


# ---------------------------------------------------------------------------
# HAIMA_chiplet
# ---------------------------------------------------------------------------

def _dim_util(dim: int, exponent: float = 1.0) -> float:
    """Structural dimensional-utilisation curve (same family as 2.5D-HI's,
    see simulator.py): achieved/peak grows with the stationary operand dim
    until the compute saturates.

    ``exponent`` encodes *how parallelism scales with model size* per
    architecture (§4.2):
      - 1.0 — row-width utilisation only (SM/SRAM pipelines; TransPIM's
        token-sharding spreads work by tokens, so weight size buys nothing);
      - 1.5 — HAIMA's DRAM-PIM bank-level parallelism: concurrently
        activated banks grow with the weight footprint (∝ D·F) *and*
        per-bank row utilisation grows with row width (∝ D) — the paper's
        "HAIMA maximizes throughput by activating multiple banks in
        parallel".
    """
    return min(1.0, (dim / C.SM_SAT_DIM) ** exponent)


def _phase_dim(name: str, w: Workload) -> int:
    """Governing parallelism dim per phase for *in-memory* compute.

    Bit-serial row-parallel PIM parallelism is set by the stationary
    matrix's ROW width — d_model for every transformer kernel (FC1 rows =
    D, FC2 activations re-written per token).  This is the structural
    asymmetry behind the paper's Fig-8 "gain is maximum for the FF layer":
    2.5D-HI's ReRAM macro scales with the full F width via weight
    duplication (simulator.py uses d_ff there), while the baselines' PIM
    banks stay row-bound at D ≪ F.
    """
    return w.d_model


# Dynamic-operand write penalty (the paper's central thesis, §3.1/§4.4):
# compute-in-memory arrays must WRITE per-token operands (Q, K, V, score
# rows) into the array before each MVM — bit-(de)serialisation of 16-bit
# dynamic operands costs ~an order of magnitude over weight-stationary
# operation.  2.5D-HI avoids this entirely by running dynamic kernels on
# SM chiplets with fused score+softmax.
DYNAMIC_WRITE_PENALTY = 8.0

# Milder factor for kernels whose *outputs* (not stationary operands) are
# dynamic intermediates that must be written back into banks before the
# next in-memory kernel (TransPIM's K/Q/V → score hand-off): the write-back
# work is ~a quarter of the MAC work at fp16 into bit-serial banks.
KQV_WRITEBACK = 1.25


def simulate_haima_chiplet(w: Workload, n_chiplets: int, *,
                           calib: Calib = CALIB,
                           chiplet: bool = True) -> SimResult:
    n_sram = max(n_chiplets // 6, 2)
    n_host = max(n_chiplets // 18, 1)
    n_dram = n_chiplets - n_sram - n_host
    pl = _baseline_placement(n_chiplets,
                             {"SRAM": n_sram, "HOST": n_host, "DRAM": n_dram})

    # score/softmax spill: the N²·h attention matrix leaves the SRAM plane
    # for the host (softmax) and back (§4.2 — "repeated data exchange with
    # the host"; 2.5D-HI avoids this via fused score+softmax on SMs).
    score_spill = 2.0 * w.seq_len * w.seq_len * w.n_heads * BYTES

    phases = transformer_phases(w)
    # HAIMA adds host round-trips for softmax/arithmetic on every layer and
    # SRAM↔DRAM exchange for the score operands
    for p in phases:
        if p.name == "score":
            p.host_bytes = 2 * w.seq_len * w.d_model * BYTES + score_spill
            p.sm_mc_bytes *= 2.0          # contention paths (§4.2)
        if p.name == "embed":
            # token vectors leave the banks for the compute plane (2.5D-HI
            # keeps this on the contiguous ReRAM macro instead)
            p.sm_mc_bytes += w.seq_len * w.d_model * BYTES
    noi_t_list, ev = _phase_noi_times_baseline(pl, phases)
    noi_by = {p.name: t for p, t in zip(phases, noi_t_list)}

    # DRAM-PIM effective rate: banks × bit-serial MAC rate × calibrated eff.
    bank_rate = 32e9                      # ops/s per chiplet's PIM banks
    cap = 1.0 if chiplet else calib.orig_bank_cap
    pim_rate0 = n_dram * bank_rate * 64 * calib.haima_eff * cap
    sram_rate0 = n_sram * 2.0e12 * calib.haima_eff * 24

    def host_time(p):
        return (p.host_bytes / C.HOST_LINK.bw
                + (2 * C.HOST_LINK.latency_s if p.host_bytes else 0.0))

    by = {p.name: p for p in phases}

    def t_of(p, rate0, *, exponent=1.5, dyn=1.0):
        rate = rate0 * _dim_util(_phase_dim(p.name, w), exponent) / dyn
        return max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                   p.dram_bytes / (n_dram * C.DRAM.bw)) + host_time(p)

    # weight-stationary kernels on DRAM-PIM: bank-parallelism exponent
    # (fitted to the Table-4 GPT-J anchor — HAIMA activates more banks as
    # the weight footprint grows); score on the SRAM plane: linear
    # row-width util × dynamic-write penalty
    e = calib.haima_scale_exp
    t_embed = t_of(by["embed"], pim_rate0, exponent=e)
    t_kqv = t_of(by["kqv"], pim_rate0, exponent=e)
    t_score = t_of(by["score"], sram_rate0, exponent=1.0,
                   dyn=DYNAMIC_WRITE_PENALTY)
    t_ff = t_of(by["ff"], pim_rate0, exponent=e)
    t_cross = t_of(by["cross"], pim_rate0, exponent=e) if "cross" in by else 0.0
    t_head = t_of(by["lm_head"], pim_rate0, exponent=e)

    k = w.n_layers
    total = t_embed + k * (t_kqv + t_score + t_ff) + t_head  # serialized
    if "cross" in by:
        total += by["cross"].repeat * t_cross

    per_kernel = {"embed": t_embed, "kqv": t_kqv * k, "score": t_score * k,
                  "ff": t_ff * k, "lm_head": t_head}
    times = {"embed": t_embed, "kqv": t_kqv, "score": t_score, "ff": t_ff,
             "lm_head": t_head}
    alloc = {"SRAM": n_sram, "HOST": n_host, "DRAM": n_dram}
    # per-phase active units: score on the SRAM plane + host softmax; the
    # weight-stationary kernels on DRAM-PIM banks
    busy = {n: ({"SRAM", "HOST"} if n == "score" else {"DRAM"})
            for n in times}
    energy = _energy(phases, times, alloc, ev, busy) * 1.35  # contention (§4.2)
    name = "HAIMA_chiplet" if chiplet else "HAIMA"
    if not chiplet:
        energy *= 1.15
    return SimResult(name, w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


# ---------------------------------------------------------------------------
# TransPIM_chiplet
# ---------------------------------------------------------------------------

def simulate_transpim_chiplet(w: Workload, n_chiplets: int, *,
                              calib: Calib = CALIB,
                              chiplet: bool = True) -> SimResult:
    n_acu = max(n_chiplets // 9, 1)
    n_dram = n_chiplets - n_acu
    pl = _baseline_placement(n_chiplets, {"ACU": n_acu, "DRAM": n_dram})

    phases = transformer_phases(w)
    ring_bytes = w.seq_len * w.d_model * BYTES
    # softmax runs on the ACUs: the N²·h score matrix crosses bank→ACU→bank
    # (TransPIM "suffers from latency overhead at each kernel" §2)
    acu_spill = 2.0 * w.seq_len * w.seq_len * w.n_heads * BYTES
    for p in phases:
        if p.name in ("kqv", "score"):
            # token-sharing ring broadcast among memory chiplets
            p.sm_mc_bytes += ring_bytes
        if p.name == "score":
            p.sm_mc_bytes += acu_spill
        if p.name == "embed":
            p.sm_mc_bytes += w.seq_len * w.d_model * BYTES
    noi_t_list, ev = _phase_noi_times_baseline(pl, phases)
    noi_by = {p.name: t for p, t in zip(phases, noi_t_list)}

    bank_rate = 32e9
    cap = 1.0 if chiplet else calib.orig_bank_cap
    pim_rate0 = n_dram * bank_rate * 64 * calib.transpim_eff * cap
    acu_latency = 1.2e-6                 # per-kernel ACU hand-off (§2)
    acu_bw = 25e9                        # ACU vector-unit stream bandwidth

    by = {p.name: p for p in phases}

    def t_of(p):
        # token-sharding parallelism is ~width-linear (fitted exponent —
        # sub-linear: ring-broadcast overheads grow with row width); score
        # pays the bit-serial dynamic-operand write penalty in-bank; kqv
        # pays a milder write-back factor (K/Q/V are dynamic intermediates
        # bit-serially written into banks for the score phase)
        dyn = 1.0
        if p.name == "score":
            dyn = DYNAMIC_WRITE_PENALTY
        elif p.name == "kqv":
            dyn = KQV_WRITEBACK
        rate = (pim_rate0
                * _dim_util(_phase_dim(p.name, w), calib.transpim_scale_exp)
                / dyn)
        spill_t = (acu_spill / (n_acu * acu_bw)) if p.name == "score" else 0.0
        return (max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                    p.dram_bytes / (n_dram * C.DRAM.bw)) + acu_latency
                + spill_t)

    t = {n: t_of(p) for n, p in by.items()}
    k = w.n_layers
    total = t["embed"] + k * (t["kqv"] + t["score"] + t["ff"]) + t["lm_head"]
    if "cross" in by:
        total += by["cross"].repeat * t["cross"]

    per_kernel = {"embed": t["embed"], "kqv": t["kqv"] * k,
                  "score": t["score"] * k, "ff": t["ff"] * k,
                  "lm_head": t["lm_head"]}
    alloc = {"ACU": n_acu, "DRAM": n_dram}
    busy = {n: ({"ACU", "DRAM"} if n == "score" else {"DRAM"}) for n in t}
    energy = _energy(phases, t, alloc, ev, busy)
    name = "TransPIM_chiplet" if chiplet else "TransPIM"
    if not chiplet:
        energy *= 1.15
    return SimResult(name, w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


def _phase_noi_times_baseline(pl, phases):
    """Baseline NoI evaluation with role aliasing: the traffic model speaks
    SM/MC/DRAM/ReRAM; in the baselines the compute plane is SRAM (HAIMA) or
    the ACUs (TransPIM) and the DRAM-PIM banks are both memory and compute —
    a subset of banks act as the 'MC' heads the many-to-few traffic hits."""
    roles = pl.roles()
    aliased = dict(roles)
    aliased["SM"] = roles.get("SRAM", []) + roles.get("ACU", [])
    drams = roles.get("DRAM", [])
    aliased["MC"] = drams[: max(len(drams) // 8, 1)]
    ev = evaluate_noi(pl, phases, roles_override=aliased)
    times = [noi_phase_time(u) for u in ev.per_phase_link_bytes] or [0.0] * len(phases)
    return times, ev


# ---------------------------------------------------------------------------
# ReTransformer endurance analysis (§4.4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EnduranceReport:
    writes_per_cell_per_token: float
    writes_per_encoder: float
    days_to_failure_at_1khz: float
    feasible: bool


def retransformer_endurance(w: Workload) -> EnduranceReport:
    """Quantifies §4.4: KQV intermediates rewrite ReRAM cells ~1e7×/token;
    at N=4096 a single encoder reaches ~1e10 writes — far past the ~1e8
    endurance bound [28]."""
    from repro.core.traffic import rewrites_per_token

    per_tok = rewrites_per_token(w)
    per_encoder = per_tok * w.seq_len
    # token rate 1 kHz: lifetime until endurance bound
    seconds = C.RERAM.write_endurance / max(per_tok, 1e-9) / 1e3
    return EnduranceReport(
        writes_per_cell_per_token=per_tok,
        writes_per_encoder=per_encoder,
        days_to_failure_at_1khz=seconds * 1e3 / 86_400,
        feasible=per_encoder < C.RERAM.write_endurance)
