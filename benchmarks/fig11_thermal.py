"""Fig. 11: thermal feasibility + EDP of 3D-HI vs the original 3-D
baselines.  Validates: baselines 120–131 °C > 95 °C DRAM limit; 3D-HI
feasible; EDP gain grows with model size / N (order of magnitude at
BERT-Large n=2056)."""
from repro.config import get_config
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.simulator import simulate_2p5d_hi
from repro.core.thermal import baseline_stack_report, hi3d_stack_report
from repro.core.traffic import Workload

from benchmarks.common import emit


def run(verbose: bool = True) -> list[dict]:
    rows = []
    # temperatures
    trows = []
    for kind in ("haima", "transpim"):
        r = baseline_stack_report(kind)
        trows.append({"stack": kind, "peak_c": r.peak_c,
                      "dram_feasible": r.dram_feasible,
                      "noise_sigma": r.reram_noise_sigma})
    for chips in (36, 100):
        r = hi3d_stack_report(chips)
        trows.append({"stack": f"3d-hi-{chips}", "peak_c": r.peak_c,
                      "dram_feasible": r.dram_feasible,
                      "noise_sigma": r.reram_noise_sigma})
    if verbose:
        emit(trows, "fig11a: steady-state stack temperatures")
    assert all(not t["dram_feasible"] for t in trows[:2])
    assert all(110 < t["peak_c"] < 140 for t in trows[:2]), trows[:2]
    assert all(t["dram_feasible"] for t in trows[2:])

    # EDP across models / seq lens
    for arch, n in (("bert-large", 64), ("bert-large", 2056),
                    ("bart-large", 1024), ("gpt-j", 256)):
        chips = 100 if arch == "gpt-j" else 64
        w = Workload.from_config(get_config(arch), seq_len=n)
        hi = simulate_2p5d_hi(w, chips)
        ha = simulate_haima_chiplet(w, chips)
        tp = simulate_transpim_chiplet(w, chips)
        rows.append({"arch": arch, "seq_len": n,
                     "hi_edp": hi.edp,
                     "haima_edp_gain_x": ha.edp / hi.edp,
                     "transpim_edp_gain_x": tp.edp / hi.edp})
    if verbose:
        emit(rows, "fig11b: EDP vs baselines")
    big = [r for r in rows if r["arch"] == "bert-large" and r["seq_len"] == 2056]
    assert big[0]["haima_edp_gain_x"] > 5.0, big
    return trows + rows


if __name__ == "__main__":
    run()
