"""Multi-objective optimisation of the NoI design (§3.3).

Implements the paper's solver — **MOO-STAGE** (learned evaluation function
over local-search trajectories, random-forest surrogate, Pareto-hypervolume
objective [10][39]) — plus the reference solvers it is compared against in
the cited literature: AMOSA-style archived simulated annealing [40] and an
NSGA-II-style evolutionary loop [42].  All share the same move set
(core/placement.neighbors) and objective evaluator, so benchmark
comparisons are solver-only.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Sequence

import numpy as np

from repro.core.placement import Placement, design_features, neighbors, random_placement
from repro.core.rf import RandomForest


# ---------------------------------------------------------------------------
# Pareto utilities (minimisation)
# ---------------------------------------------------------------------------

def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(points: list) -> list[int]:
    """Indices of non-dominated points."""
    idx = []
    for i, p in enumerate(points):
        if not any(dominates(q, p) for j, q in enumerate(points) if j != i):
            idx.append(i)
    return idx


def hypervolume(points: np.ndarray, ref: np.ndarray, n_mc: int = 4096,
                seed: int = 0) -> float:
    """Pareto-hypervolume (PHV), minimisation, w.r.t. reference point.

    Exact sweep in 2-D; Monte-Carlo for ≥3 objectives (the paper's 3D-HI
    MOO has 4)."""
    pts = np.asarray([p for p in points if np.all(p <= ref)], float)
    if len(pts) == 0:
        return 0.0
    d = pts.shape[1]
    if d == 2:
        # sweep left→right; each non-dominated point adds a rectangle
        pts = pts[np.argsort(pts[:, 0])]
        hv = 0.0
        cur_y = ref[1]
        for x, y in pts:
            if y < cur_y:
                hv += (ref[0] - x) * (cur_y - y)
                cur_y = y
        return float(hv)
    rng = np.random.default_rng(seed)
    lo = pts.min(axis=0)
    samples = lo + rng.random((n_mc, d)) * (ref - lo)
    dominated = np.zeros(n_mc, bool)
    for p in pts:
        dominated |= np.all(samples >= p, axis=1)
    vol = np.prod(ref - lo)
    return float(dominated.mean() * vol)


@dataclasses.dataclass
class Archive:
    """Pareto archive of (design, objectives)."""
    designs: list = dataclasses.field(default_factory=list)
    objs: list = dataclasses.field(default_factory=list)

    def add(self, d, o) -> bool:
        o = tuple(float(x) for x in o)
        if any(not np.isfinite(x) for x in o):
            return False
        if any(dominates(e, o) for e in self.objs):
            return False
        keep = [i for i, e in enumerate(self.objs) if not dominates(o, e)]
        self.designs = [self.designs[i] for i in keep] + [d]
        self.objs = [self.objs[i] for i in keep] + [o]
        return True

    def phv(self, ref) -> float:
        if not self.objs:
            return 0.0
        return hypervolume(np.asarray(self.objs), np.asarray(ref, float))


# ---------------------------------------------------------------------------
# greedy Pareto local search (the "base search" in MOO-STAGE)
# ---------------------------------------------------------------------------

def local_search(start: Placement, objective_fn: Callable, archive: Archive,
                 rng: random.Random, max_steps: int = 40,
                 trajectory: list | None = None) -> Placement:
    cur = start
    cur_obj = objective_fn(cur)
    archive.add(cur, cur_obj)
    if trajectory is not None:
        trajectory.append((cur, cur_obj))
    for _ in range(max_steps):
        improved = False
        for cand in neighbors(cur, rng):
            o = objective_fn(cand)
            archive.add(cand, o)
            if trajectory is not None:
                trajectory.append((cand, o))
            if dominates(o, cur_obj):
                cur, cur_obj = cand, o
                improved = True
                break
        if not improved:
            break
    return cur


# ---------------------------------------------------------------------------
# MOO-STAGE (paper §3.3, [39])
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MooStageResult:
    archive: Archive
    phv_history: list
    n_evals: int


def moo_stage(n_chiplets: int, objective_fn: Callable, ref_point,
              *, iterations: int = 6, seed: int = 0,
              meta_candidates: int = 24, extra_alloc: dict | None = None,
              ls_steps: int = 30) -> MooStageResult:
    """Iterate: (1) pick a start state by maximising the learned PHV
    predictor over candidate starts (meta search); (2) run greedy Pareto
    local search (base search); (3) add (trajectory design → resulting PHV)
    regression examples and refit the random forest."""
    rng = random.Random(seed)
    archive = Archive()
    surrogate = RandomForest(seed=seed)
    X_train: list[np.ndarray] = []
    y_train: list[float] = []
    phv_hist = []
    n_evals = 0

    for it in range(iterations):
        cands = [random_placement(n_chiplets, rng, extra=extra_alloc)
                 for _ in range(meta_candidates)]
        if X_train:
            feats = np.stack([design_features(c) for c in cands])
            scores = surrogate.predict(feats)
            start = cands[int(np.argmax(scores))]
        else:
            start = cands[0]

        traj: list = []
        local_search(start, objective_fn, archive, rng, max_steps=ls_steps,
                     trajectory=traj)
        n_evals += len(traj)
        phv = archive.phv(ref_point)
        phv_hist.append(phv)
        for d, _ in traj:
            X_train.append(design_features(d))
            y_train.append(phv)
        surrogate.fit(np.stack(X_train), np.asarray(y_train))
    return MooStageResult(archive, phv_hist, n_evals)


# ---------------------------------------------------------------------------
# AMOSA-style archived simulated annealing [40]
# ---------------------------------------------------------------------------

def amosa(n_chiplets: int, objective_fn: Callable, ref_point, *,
          steps: int = 200, t0: float = 1.0, cooling: float = 0.97,
          seed: int = 0, extra_alloc: dict | None = None) -> MooStageResult:
    rng = random.Random(seed)
    archive = Archive()
    cur = random_placement(n_chiplets, rng, extra=extra_alloc)
    cur_obj = objective_fn(cur)
    archive.add(cur, cur_obj)
    T = t0
    phv_hist = []
    scale = np.asarray(ref_point, float)
    for s in range(steps):
        cand = neighbors(cur, rng, k=1)
        if not cand:
            continue
        cand = cand[0]
        o = objective_fn(cand)
        archive.add(cand, o)
        delta = float(np.mean((np.asarray(o) - np.asarray(cur_obj)) / scale))
        if delta <= 0 or rng.random() < np.exp(-delta / max(T, 1e-9)):
            cur, cur_obj = cand, o
        T *= cooling
        if (s + 1) % 25 == 0:
            phv_hist.append(archive.phv(ref_point))
    return MooStageResult(archive, phv_hist, steps)


# ---------------------------------------------------------------------------
# NSGA-II-style evolutionary loop [42]
# ---------------------------------------------------------------------------

def _crowding(objs: np.ndarray) -> np.ndarray:
    n, m = objs.shape
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(objs[:, k])
        dist[order[0]] = dist[order[-1]] = np.inf
        rng_ = objs[order[-1], k] - objs[order[0], k] or 1.0
        for i in range(1, n - 1):
            dist[order[i]] += (objs[order[i + 1], k] - objs[order[i - 1], k]) / rng_
    return dist


def nsga2(n_chiplets: int, objective_fn: Callable, ref_point, *,
          pop: int = 16, generations: int = 12, seed: int = 0,
          extra_alloc: dict | None = None) -> MooStageResult:
    rng = random.Random(seed)
    archive = Archive()
    population = [random_placement(n_chiplets, rng, extra=extra_alloc)
                  for _ in range(pop)]
    objs = [objective_fn(p) for p in population]
    for p, o in zip(population, objs):
        archive.add(p, o)
    phv_hist = []
    n_evals = pop
    for g in range(generations):
        children = []
        for p in population:
            children += neighbors(p, rng, k=1)
        c_objs = [objective_fn(c) for c in children]
        n_evals += len(children)
        for c, o in zip(children, c_objs):
            archive.add(c, o)
        allp = population + children
        allo = objs + c_objs
        # non-dominated sort (two fronts suffice at this pop size)
        front = pareto_front(allo)
        rest = [i for i in range(len(allp)) if i not in front]
        chosen = list(front)[:pop]
        if len(chosen) < pop and rest:
            ro = np.asarray([allo[i] for i in rest])
            cd = _crowding(ro)
            order = np.argsort(-cd)
            chosen += [rest[i] for i in order[:pop - len(chosen)]]
        population = [allp[i] for i in chosen]
        objs = [allo[i] for i in chosen]
        phv_hist.append(archive.phv(ref_point))
    return MooStageResult(archive, phv_hist, n_evals)
