from repro.serving.engine import EngineConfig, Request, ServingEngine  # noqa: F401
from repro.serving.checkpoint import (  # noqa: F401
    EngineCheckpointer, restore_engine, save_engine)
