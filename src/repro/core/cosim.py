"""Plane-A ↔ Plane-B co-simulation bridge.

The serving engine (`repro.serving.engine`) runs real prefill+decode
schedules on JAX; the analytical simulator (`core/simulator`) evaluates
chiplet architectures.  This module closes the loop:

1. **measure** — ``mix_from_stats`` turns ``ServingEngine.stats()`` into a
   :class:`EpisodeMix`: the batch mix of (prompt_len, gen_len) episodes the
   engine actually served, plus its chunked-prefill schedule, its
   measured per-step active-slot histogram and its decode-stall bound;
2. **replay** — ``cosim_mix`` replays that mix through
   ``simulate_generation`` for every architecture, on the *full* model
   config (the engine typically serves a ``reduce_config`` shrink of it),
   with decode batched at the measured slot-pool occupancy
   (``EpisodeMix.effective_batch``), reporting TTFT, decode tok/s and
   energy/token per architecture — directly comparable to the engine's
   continuous-batching tok/s, not a single stream;
3. **design** — ``generation_phases`` expands the mix into a decode-heavy
   phase list whose repeats weight prefill vs decode by their measured
   token counts — decode batch-amortised, prefill split at the measured
   chunked-prefill interleave granularity — and ``generation_objective``
   feeds it to the existing MOO solvers (`core/moo`) — so NoI
   placement/link search optimises for the traffic a *generation*
   workload actually produces (KV-cache reads dominating), not a single
   fixed-length forward pass.

The single-pass calibration contract is untouched: everything here is
built from ``prefill_phases`` / ``decode_step_phases`` on top of the
anchored single-pass models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.config import ModelConfig, get_config
from repro.core.noi import (NoIEval, evaluate_noi, mesh_baseline_eval,
                            noi_phase_time)
from repro.core.simulator import (CALIB, Calib, _decode_positions,
                                  simulate_generation)
from repro.core.traffic import (Phase, Workload, decode_step_phases,
                                prefill_phases, spec_decode_step_phases,
                                spec_tokens_per_step)

ARCHS = ("2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet")


@dataclasses.dataclass(frozen=True)
class Episode:
    """One served request class: prompt_len tokens in, gen_len tokens out."""
    prompt_len: int
    gen_len: int
    count: int = 1


@dataclasses.dataclass
class EpisodeMix:
    """The measured workload of a serving run (the Plane-A ground truth)."""
    episodes: list[Episode]
    prefill_chunk: int = 0        # engine chunked-prefill budget (tokens)
    max_batch: int = 0            # engine slot-pool size
    # {n_active_slots: decode iterations at that occupancy} — the measured
    # slot-pool utilisation (ServingEngine.stats()["active_slots_hist"])
    active_hist: dict = dataclasses.field(default_factory=dict)
    max_stall_tokens: int = 0     # max prefill tokens between decode steps
    weight_bits: int = 16         # measured serving precision (16 = fp) —
    kv_bits: int = 16             #   scales the Plane-B weight/KV byte terms
    # speculative decoding — measured from the engine's spec counters
    # (all zero when speculation was off, leaving the mix bit-identical
    # to the pre-speculation model)
    spec_k: int = 0               # draft depth per speculative step
    spec_acceptance: float = 0.0  # measured per-draft acceptance rate
    spec_tokens: float = 0.0      # measured E[committed tokens]/slot-step
    spec_draft_bits: int = 0      # self-draft precision (0 = serving bits)
    # measured wall clock from the engine tracer (EngineConfig(trace=),
    # repro.profile) — all zero when tracing was off, leaving the mix
    # bit-identical to the untraced model
    measured_step_s: float = 0.0     # mean decode-iteration wall time
    measured_prefill_s: float = 0.0  # total admission wall time
    measured_d2h_s: float = 0.0      # total device→host fetch wall time

    @property
    def expected_tokens_per_step(self) -> float:
        """Tokens one slot commits per decode iteration: the measured
        speculative yield when the engine recorded one, the analytic
        ``spec_tokens_per_step`` curve as fallback, 1.0 without
        speculation."""
        if self.spec_k <= 0:
            return 1.0
        if self.spec_tokens > 0:
            return min(float(self.spec_tokens), self.spec_k + 1.0)
        return spec_tokens_per_step(self.spec_k, self.spec_acceptance)

    @property
    def requests(self) -> int:
        return sum(e.count for e in self.episodes)

    @property
    def prefill_tokens(self) -> int:
        return sum(e.prompt_len * e.count for e in self.episodes)

    @property
    def decode_tokens(self) -> int:
        return sum(max(e.gen_len - 1, 0) * e.count for e in self.episodes)

    @property
    def mean_active_slots(self) -> float:
        """Decode-iteration-weighted mean slot-pool occupancy (0 when no
        histogram was recorded).  Zero-active iterations (a chunked decode
        scan outliving its slots) count toward the denominator — the mean
        is exactly the tokens the engine got per decode iteration paid."""
        total = sum(self.active_hist.values())
        if not total:
            return 0.0
        return sum(int(k) * c for k, c in self.active_hist.items()) / total

    @property
    def effective_batch(self) -> int:
        """The decode batch the Plane-B replay should run at: the measured
        mean occupancy when a histogram was recorded, else the slot-pool
        size (an upper bound), else single-stream."""
        m = self.mean_active_slots
        if m > 0:
            return max(1, round(m))
        return max(1, self.max_batch)


def mix_from_stats(stats: dict) -> EpisodeMix:
    """Build the episode mix from ``ServingEngine.stats()``.

    Requires the per-request ``prompt_lens``/``gen_lens`` lists the engine
    records for finished requests and a positive ``max_batch`` slot-pool
    size; identical (prompt, gen) pairs collapse into one weighted
    episode."""
    if not stats.get("finished"):
        raise ValueError("engine stats carry no finished requests")
    plens = stats.get("prompt_lens")
    glens = stats.get("gen_lens")
    if not plens or not glens or len(plens) != len(glens):
        raise ValueError("stats missing per-request prompt_lens/gen_lens")
    max_batch = int(stats.get("max_batch", 0))
    if max_batch <= 0:
        # a 0-slot pool cannot have served the finished requests — the
        # stats are inconsistent/truncated, not a degenerate-but-valid mix
        raise ValueError(
            "stats carry no slot-pool size (max_batch <= 0); the engine "
            "that served this mix must report its pool via stats()")
    counts: dict[tuple[int, int], int] = {}
    for p, g in zip(plens, glens):
        counts[(int(p), int(g))] = counts.get((int(p), int(g)), 0) + 1
    episodes = [Episode(p, g, c) for (p, g), c in sorted(counts.items())]
    hist = {int(k): int(v)
            for k, v in (stats.get("active_slots_hist") or {}).items()}
    return EpisodeMix(episodes,
                      prefill_chunk=int(stats.get("prefill_chunk", 0)),
                      max_batch=max_batch,
                      active_hist=hist,
                      max_stall_tokens=int(stats.get("max_stall_tokens", 0)),
                      weight_bits=int(stats.get("weight_bits", 16)),
                      kv_bits=int(stats.get("kv_bits", 16)),
                      # spec keys exist only when the engine ran with
                      # spec_k > 0 (stats dormancy contract); rate/yield
                      # may be None when nothing was drafted yet
                      spec_k=int(stats.get("spec_k", 0) or 0),
                      spec_acceptance=float(stats.get("spec_acceptance")
                                            or 0.0),
                      spec_tokens=float(stats.get("spec_tokens_per_step")
                                        or 0.0),
                      spec_draft_bits=int(stats.get("spec_draft_bits", 0)
                                          or 0),
                      # trace keys exist only when the engine ran with
                      # EngineConfig(trace=True) (same dormancy contract)
                      measured_step_s=float(stats.get("trace_decode_step_s")
                                            or 0.0),
                      measured_prefill_s=float(stats.get("trace_prefill_s")
                                               or 0.0),
                      measured_d2h_s=float(stats.get("trace_d2h_s") or 0.0))


def _resolve(cfg) -> ModelConfig:
    return get_config(cfg) if isinstance(cfg, str) else cfg


def workload_for(cfg, episode: Episode,
                 mix: Optional[EpisodeMix] = None) -> Workload:
    """Plane-B workload for one episode of a (full-size) model config; a
    ``mix`` carries the measured serving precision into the byte terms."""
    return Workload.from_config(
        _resolve(cfg), seq_len=episode.prompt_len,
        weight_bits=mix.weight_bits if mix else 16,
        kv_bits=mix.kv_bits if mix else 16)


# ---------------------------------------------------------------------------
# replay: measured mix → per-architecture generation metrics
# ---------------------------------------------------------------------------

def cosim_mix(cfg, mix: EpisodeMix, n_chiplets: int,
              archs: Sequence[str] = ARCHS, *,
              calib: Calib = CALIB, batch: Optional[int] = None) -> dict:
    """Replay a measured episode mix through every architecture.

    ``batch`` is the decode batch each episode's steps run at; it defaults
    to the mix's measured ``effective_batch`` (mean active slots from the
    engine's histogram, falling back to the slot-pool size), so the
    replayed Plane-B throughput models the continuous-batching regime the
    engine actually drove — pass ``batch=1`` for the single-stream view.

    Returns ``{arch: {ttft_s, decode_step_s, tokens_per_s,
    energy_per_token_j, prefill_bytes, decode_bytes, decode_traffic_frac,
    batch}}`` with request-count-weighted means; ``tokens_per_s`` counts
    all ``batch`` concurrent streams (episodes overlap in the pool, so the
    wall-clock per episode shrinks by the batch)."""
    cfg = _resolve(cfg)
    if batch is None:
        batch = mix.effective_batch
    out: dict[str, dict] = {}
    for arch in archs:
        ttft = step = energy = toks = lat = pre_b = dec_b = 0.0
        n = 0
        for ep in mix.episodes:
            w = workload_for(cfg, ep, mix)
            g = simulate_generation(w, n_chiplets, ep.prompt_len, ep.gen_len,
                                    arch=arch, calib=calib, batch=batch)
            n += ep.count
            ttft += g.ttft_s * ep.count
            step += g.decode_step_s * ep.count
            energy += g.energy_j * ep.count
            toks += g.gen_len * ep.count
            lat += g.latency_s * ep.count
            pre_b += g.prefill_bytes * ep.count
            dec_b += g.decode_bytes * ep.count
        out[arch] = {
            "ttft_s": ttft / n,
            "decode_step_s": step / n,
            "tokens_per_s": toks * batch / max(lat, 1e-30),
            "energy_per_token_j": energy / max(toks, 1),
            "prefill_bytes": pre_b,
            "decode_bytes": dec_b,
            "decode_traffic_frac": dec_b / max(pre_b + dec_b, 1e-30),
            "batch": batch,
        }
    return out


def cosim_from_engine(engine, cfg=None, n_chiplets: int = 64,
                      archs: Sequence[str] = ARCHS, *,
                      calib: Calib = CALIB,
                      batch: Optional[int] = None) -> dict:
    """End-to-end bridge: measured engine run → Plane-B evaluation.

    ``cfg`` defaults to the engine's own (usually reduced) config; pass the
    full-size config to project the measured schedule onto the real model
    dims.  Decode runs batched at the engine's measured slot-pool
    occupancy unless ``batch`` overrides it."""
    mix = mix_from_stats(engine.stats())
    cfg = _resolve(cfg) if cfg is not None else engine.cfg
    spec = {}
    if mix.spec_k:
        spec = {"spec_k": mix.spec_k,
                "spec_acceptance": mix.spec_acceptance,
                "spec_tokens_per_step": mix.expected_tokens_per_step,
                "spec_draft_bits": mix.spec_draft_bits}
    measured = {}
    if mix.measured_step_s > 0:
        # the engine ran with the tracer on: carry the measured step
        # times next to the measured mix, so every simulated
        # decode_step_s has its Plane-A wall-clock counterpart in the
        # same record (keys absent when tracing was off — dormancy)
        measured = {"measured_step_s": mix.measured_step_s,
                    "measured_prefill_s": mix.measured_prefill_s,
                    "measured_d2h_s": mix.measured_d2h_s}
    return {"mix": {"requests": mix.requests,
                    "prefill_tokens": mix.prefill_tokens,
                    "decode_tokens": mix.decode_tokens,
                    "prefill_chunk": mix.prefill_chunk,
                    "max_batch": mix.max_batch,
                    "weight_bits": mix.weight_bits,
                    "kv_bits": mix.kv_bits,
                    "max_stall_tokens": mix.max_stall_tokens,
                    "mean_active_slots": mix.mean_active_slots,
                    "effective_batch": mix.effective_batch,
                    "active_slots_hist": dict(mix.active_hist),
                    **spec,
                    **measured,
                    "episodes": [dataclasses.asdict(e) for e in mix.episodes]},
            "archs": cosim_mix(cfg, mix, n_chiplets, archs, calib=calib,
                               batch=batch)}


# ---------------------------------------------------------------------------
# design: generation traffic → MOO/placement objective
# ---------------------------------------------------------------------------

def _scale_phase(p: Phase, scale: float, repeat: int) -> Phase:
    """Copy of ``p`` with every compute/traffic term scaled and the repeat
    replaced (``scale=1.0`` is exact — multiplying by 1.0 changes no
    float).  Iterates the dataclass fields so a term added to ``Phase``
    later is scaled too instead of silently reset."""
    scaled = {f.name: getattr(p, f.name) * scale
              for f in dataclasses.fields(p)
              if f.name not in ("name", "repeat")}
    return dataclasses.replace(p, repeat=repeat, **scaled)


def _interleave_chunks(mix: EpisodeMix, prompt_len: int) -> int:
    """Chunked-prefill interleave factor for one episode: how many
    bounded bursts its prompt ingest is split into.

    The engine's chunked-prefill scheduler never stalls decode for more
    than its measured ``max_stall_tokens`` burst (falling back to the
    configured ``prefill_chunk`` budget), so a ``prompt_len`` ingest
    reaches the fabric as ``ceil(prompt_len / bound)`` chunk executions
    interleaved with decode steps — same total bytes, chunk-sized
    per-execution link loads.  The NoI time-average (eqs 14-15) then
    weights prefill at the granularity the interconnect actually sees."""
    bound = mix.max_stall_tokens or mix.prefill_chunk
    if bound <= 0 or prompt_len <= bound:
        return 1
    return -(-prompt_len // bound)


def generation_phases(cfg, mix: EpisodeMix, *, samples: int = 1,
                      batch: Optional[int] = None) -> list[Phase]:
    """Phase list of a whole generation episode mix, for NoI evaluation.

    Prefill phases keep their per-layer repeats, split into the mix's
    chunked-prefill interleave granularity (``_interleave_chunks``: the
    measured stall bound caps each burst, repeats scale up so total bytes
    are unchanged).  Decode phases (evaluated at ``samples`` KV positions
    per episode) get their repeats scaled by the number of decode steps
    they represent and run at the mix's measured decode batch: each
    timestamp is one token's 1/batch share of a batched step, so the
    weight streams are batch-amortised exactly as the engine amortises
    them.  ``evaluate_noi``'s repeat-weighted time-average (eqs 14-15)
    then sees prefill and decode in their measured proportions —
    decode-heavy mixes dominate the objective exactly as they dominate
    the real fabric."""
    cfg = _resolve(cfg)
    if batch is None:
        batch = mix.effective_batch
    phases: list[Phase] = []
    for ep in mix.episodes:
        w = workload_for(cfg, ep, mix)
        n_chunks = _interleave_chunks(mix, ep.prompt_len)
        for p in prefill_phases(w):
            phases.append(_scale_phase(p, 1.0 / n_chunks,
                                       p.repeat * n_chunks * ep.count))
        steps = max(ep.gen_len - 1, 0)
        if not steps:
            continue
        positions = _decode_positions(ep.prompt_len, ep.gen_len, samples)
        # partition the decode steps across the sampled positions exactly,
        # so the repeat-weighted decode/prefill ratio matches the mix
        base, rem = divmod(steps, len(positions))
        for i, pos in enumerate(positions):
            per_pos = base + (1 if i < rem else 0)
            if mix.spec_k > 0:
                # speculative serving: each committed token carries a
                # 1/(batch * E[tokens/step]) share of one draft+verify
                # step — the weight stream amortises over both the batch
                # and the accepted draft run
                step_phases = spec_decode_step_phases(
                    w, pos, batch, spec_k=mix.spec_k,
                    draft_w=_draft_workload(w, mix))
                share = 1.0 / (batch * mix.expected_tokens_per_step)
            else:
                step_phases = decode_step_phases(w, pos, batch)
                share = 1.0 / batch
            for p in step_phases:
                phases.append(_scale_phase(p, share,
                                           p.repeat * per_pos * ep.count))
    return phases


def _draft_workload(w: Workload, mix: EpisodeMix) -> Workload:
    """Draft-pass workload of a self-speculating mix: the target dims at
    the measured draft precision (``spec_draft_bits=0`` means the draft
    ran at serving precision — the workload itself).  Draft-*model*
    speculation replays at the same dims (conservative upper bound); pass
    an explicit ``draft_w`` to ``spec_decode_step_phases`` directly for
    the small-model accounting."""
    if mix.spec_draft_bits in (4, 8):
        return dataclasses.replace(w, weight_bits=mix.spec_draft_bits)
    return w


def generation_objective(cfg, mix: EpisodeMix, n_chiplets: int,
                         *, samples: int = 1,
                         mesh_ev: Optional[NoIEval] = None,
                         batch: Optional[int] = None,
                         ) -> tuple[Callable, NoIEval, list[Phase]]:
    """(objective_fn, mesh_ev, phases): the paper's 2-objective NoI metric
    (μ, σ normalised to the placement-unaware 2-D mesh) over the measured
    generation traffic — batched decode, chunk-interleaved prefill.
    Drop-in for `core/moo` solvers."""
    phases = generation_phases(cfg, mix, samples=samples, batch=batch)
    mesh_ev = mesh_ev or mesh_baseline_eval(n_chiplets, phases)

    def objective(p):
        ev = evaluate_noi(p, phases)
        return (ev.mu / mesh_ev.mu, ev.sigma / mesh_ev.sigma)

    return objective, mesh_ev, phases


# ---------------------------------------------------------------------------
# resilience: fault-tolerance-aware NoI objective (worst-case degradation)
# ---------------------------------------------------------------------------

def scenario_mu(p, phases: list[Phase], scenario=None,
                mesh_mu: float = 1.0) -> float:
    """μ of a placement under one fault scenario, normalised by the mesh
    baseline; inf when the degraded fabric cannot route (an explicit
    sentinel the MOO archive rejects — never NaN)."""
    ev = evaluate_noi(p, phases, scenario=scenario)
    if ev.disconnected:
        return float("inf")
    return ev.mu / mesh_mu


def fabric_time(p, phases: list[Phase], scenario=None) -> float:
    """Repeat-weighted NoI service time of a phase list: Σ repeat ×
    bottleneck-link serialisation (``noi_phase_time`` — the max-loaded
    link is what the simulators\' phase latencies build on, so this is
    the fabric-side latency proxy, where μ is a fabric-health mean that
    one link failure barely moves).  inf when the scenario disconnects a
    required flow — never NaN."""
    ev = evaluate_noi(p, phases, scenario=scenario)
    if ev.disconnected:
        return float("inf")
    return float(sum(ph.repeat * noi_phase_time(u, ev.link_bw_scale)
                     for ph, u in zip(phases, ev.per_phase_link_bytes)))


def degradation_under_faults(p, phases: list[Phase], scenarios) -> dict:
    """Score a placement\'s fabric service time over a fault-scenario list.

    Returns ``{nominal_t, expected_t, worst_t, worst_label,
    n_disconnected, n_scenarios}`` (seconds, unnormalised).  Disconnecting
    scenarios make ``expected_t``/``worst_t`` inf and are counted —
    callers decide whether a disconnectable design is admissible."""
    nominal_t = fabric_time(p, phases)
    ts, n_disc, worst_label = [], 0, ""
    worst = -float("inf")
    for sc in scenarios:
        t = fabric_time(p, phases, sc)
        ts.append(t)
        if t == float("inf"):
            n_disc += 1
        if t > worst:
            worst = t
            worst_label = getattr(sc, "label", "")
    if not ts:
        ts, worst = [nominal_t], nominal_t
    return {"nominal_t": nominal_t,
            "expected_t": float(sum(ts) / len(ts)),
            "worst_t": float(worst),
            "worst_label": worst_label,
            "n_disconnected": n_disc,
            "n_scenarios": len(scenarios)}


def resilience_objective(cfg, mix: EpisodeMix, n_chiplets: int, *,
                         fault_model=None, n_scenarios: int = 8,
                         samples: int = 1,
                         batch: Optional[int] = None,
                         endurance_weighted: bool = False,
                         ) -> tuple[Callable, float, list[Phase]]:
    """(objective_fn, seed_time, phases): fault-tolerance-aware NoI metric.

    The two objectives trade *expected* against *worst-case* fabric
    service time over a deterministic per-design k-failure scenario set
    (nominal is always scenario 0, so fault-free latency keeps pulling
    the expected term): ``(mean T_norm, max T_norm)``, both normalised by
    the dataflow-aware seed placement\'s nominal time (``seed_time``).
    Service time — not μ — is the degradation metric because the
    simulators\' phase latencies serialise on the *bottleneck* link: a
    failure that dumps a hot link\'s traffic onto one surviving path
    inflates it sharply, while the μ mean barely moves.  A design any
    sampled scenario disconnects scores inf and is rejected by the MOO
    archive — surviving the k-failure set is a hard constraint, the
    residual slowdown is what the search trades against nominal speed.

    ``fault_model`` defaults to single-link failures
    (``FaultModel(k_links=1)``); ``endurance_weighted`` biases which links
    fail by the wear the measured traffic accumulates
    (``faults.endurance_link_weights`` — ReRAM-incident links fail more).
    Scenario sampling is a pure function of (link set, model seed), so
    re-evaluating a placement is reproducible and archive-stable."""
    from repro.core.faults import FaultModel, endurance_link_weights
    from repro.core.placement import initial_placement

    fault_model = fault_model or FaultModel(k_links=1)
    phases = generation_phases(cfg, mix, samples=samples, batch=batch)
    seed_time = fabric_time(initial_placement(n_chiplets), phases)

    def objective(p):
        weights = (endurance_link_weights(p, phases)
                   if endurance_weighted else None)
        scenarios = fault_model.sample_scenarios(p, n_scenarios,
                                                 link_weights=weights)
        ts = [fabric_time(p, phases)] + [fabric_time(p, phases, sc)
                                         for sc in scenarios]
        if any(t == float("inf") for t in ts):
            return (float("inf"), float("inf"))
        return (sum(ts) / len(ts) / seed_time, max(ts) / seed_time)

    return objective, seed_time, phases


def _lost_dram_frac(p, scenario) -> float:
    """Share of the slot-pool KV orphaned by a scenario: dead DRAM role
    members over the DRAM role size (0 for link-only faults)."""
    if scenario is None or not scenario.failed_chiplets:
        return 0.0
    drams = p.roles().get("DRAM", [])
    if not drams:
        return 0.0
    dead = sum(1 for c in drams if c in scenario.failed_chiplets)
    return dead / len(drams)


def _pool_depth(mix: EpisodeMix) -> tuple[Episode, int]:
    """(dominant episode, mid-generation KV depth) — the pool state a
    recovery event re-materialises.  Recovery can strike at any decode
    iteration, so each slot is priced at its episode's expected depth
    (prompt + half the generated tokens), request-count weighted."""
    ep = max(mix.episodes, key=lambda e: e.count)
    tot = sum((e.prompt_len + max(e.gen_len - 1, 0) // 2) * e.count
              for e in mix.episodes)
    return ep, max(1, round(tot / max(mix.requests, 1)))


def recovery_time(p, cfg, mix: EpisodeMix, scenario=None, *,
                  batch: Optional[int] = None) -> float:
    """One-time fabric service time of recovering from ``scenario``:
    KV-shard migration off the failed chiplet(s) plus the checkpoint
    restore read (``traffic.recovery_phases``), routed and serialised on
    the *degraded* fabric (failed chiplets' traffic redistributes over
    surviving role members; disconnection ⇒ inf).  0 for the nominal
    fabric — nothing to recover from."""
    from repro.core.traffic import recovery_phases

    if scenario is None or scenario.is_nominal:
        return 0.0
    cfg = _resolve(cfg)
    if batch is None:
        batch = mix.effective_batch
    ep, depth = _pool_depth(mix)
    w = workload_for(cfg, ep, mix)
    phases = recovery_phases(w, depth, batch,
                             lost_frac=_lost_dram_frac(p, scenario))
    return fabric_time(p, phases, scenario)


def mttr_resilience_objective(cfg, mix: EpisodeMix, n_chiplets: int, *,
                              fault_model=None, n_scenarios: int = 8,
                              samples: int = 1,
                              batch: Optional[int] = None,
                              ckpt_every: int = 32,
                              mttr_weight: float = 1.0,
                              ) -> tuple[Callable, float, list[Phase]]:
    """MTTR-aware extension of :func:`resilience_objective`.

    Steady-state service now carries the amortised checkpoint write-back
    stream (``traffic.checkpoint_phases`` at ``ckpt_every`` — crash
    safety is not free even when nothing fails), and the worst-case
    objective prices the *recovery* a scenario forces on top of its
    degraded service: ``(mean T_service, max (T_service + mttr_weight ×
    T_recovery))``, both normalised by the seed placement's nominal
    service time.  ``fault_model`` defaults to single-chiplet losses
    (``FaultModel(k_links=0, k_chiplets=1)`` — the KV-orphaning event);
    a scenario that disconnects service *or* recovery scores inf, so
    surviving the loss **and** being able to re-shard off it are both
    hard constraints the search trades against nominal speed.
    ``ckpt_every <= 0`` drops the write-back stream (recovery still
    priced — the checkpoint lives off-fabric)."""
    from repro.core.faults import FaultModel
    from repro.core.placement import initial_placement
    from repro.core.traffic import checkpoint_phases

    fault_model = fault_model or FaultModel(k_links=0, k_chiplets=1)
    if batch is None:
        batch = mix.effective_batch
    phases = generation_phases(cfg, mix, samples=samples, batch=batch)
    if ckpt_every > 0:
        ep, depth = _pool_depth(mix)
        w = workload_for(_resolve(cfg), ep, mix)
        # same per-token 1/batch amortisation as the decode phases: the
        # write-back repeats once per generated token's share of a step
        for p in checkpoint_phases(w, depth, batch, every=ckpt_every):
            phases.append(_scale_phase(p, 1.0 / batch,
                                       p.repeat * max(mix.decode_tokens, 1)))
    seed_time = fabric_time(initial_placement(n_chiplets), phases)

    def objective(p):
        scenarios = fault_model.sample_scenarios(p, n_scenarios)
        t_nom = fabric_time(p, phases)
        service, totals = [t_nom], [t_nom]
        for sc in scenarios:
            t = fabric_time(p, phases, sc)
            r = recovery_time(p, cfg, mix, sc, batch=batch)
            service.append(t)
            totals.append(t + mttr_weight * r)
        if any(t == float("inf") for t in totals):
            return (float("inf"), float("inf"))
        return (sum(service) / len(service) / seed_time,
                max(totals) / seed_time)

    return objective, seed_time, phases


def seeded_noi_search(objective: Callable, n_chiplets: int, *,
                      iterations: int = 3, ls_steps: int = 12,
                      seed: int = 0):
    """MOO-STAGE over any (μ, σ) NoI objective, seeded (like
    `examples/noi_design.py`) with a local search from the dataflow-aware
    initial placement.  The one search recipe every NoI comparison runs,
    so search budgets stay identical across objectives.  Returns the
    MooStageResult."""
    import random

    from repro.core.moo import local_search, moo_stage
    from repro.core.placement import initial_placement

    res = moo_stage(n_chiplets, objective, (2.0, 2.0),
                    iterations=iterations, ls_steps=ls_steps, seed=seed)
    local_search(initial_placement(n_chiplets), objective, res.archive,
                 random.Random(seed), max_steps=ls_steps)
    return res


def optimize_generation_noi(cfg, mix: EpisodeMix, n_chiplets: int, *,
                            iterations: int = 3, ls_steps: int = 12,
                            seed: int = 0, samples: int = 1,
                            batch: Optional[int] = None):
    """Decode-aware NoI design search: `seeded_noi_search` over the
    generation traffic.  Returns (MooStageResult, mesh_ev)."""
    objective, mesh_ev, _ = generation_objective(cfg, mix, n_chiplets,
                                                 samples=samples, batch=batch)
    res = seeded_noi_search(objective, n_chiplets, iterations=iterations,
                            ls_steps=ls_steps, seed=seed)
    return res, mesh_ev
