"""Pallas decode-attention kernel (interpret mode) vs the pure-jnp oracle:
GQA folding, sliding window, per-slot lengths, empty slots, bf16, and the
``impl="flash"`` routing through ops/attention/decode_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.kernels.flash_attention.decode import flash_decode_fwd
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import transformer as T


def _pool(key, B, Skv, Hq, Hkv, hd, lengths, dtype=jnp.float32):
    """Random (q, k, v, q_pos, kv_pos) for a slotted pool with per-slot
    lengths: slot i holds tokens 0..lengths[i]-1, the query sits at
    position lengths[i]-1, and entries beyond the length are empty (-1)."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    L = np.asarray(lengths, np.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    kv_pos = jnp.where(kv_pos < L[:, None], kv_pos, -1)
    q_pos = jnp.asarray(L[:, None] - 1, jnp.int32)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [0, 16])
def test_decode_kernel_matches_ref(Hq, Hkv, window):
    B, Skv, hd = 3, 64, 32
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(0), B, Skv, Hq, Hkv,
                                   hd, lengths=[3, 31, 64])
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                           window=window, interpret=True)
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                        kv_valid=kv_pos >= 0, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_kernel_softcap():
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(1), 2, 32, 4, 2, 16,
                                   lengths=[7, 30])
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos, softcap=30.0,
                           interpret=True)
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                        kv_valid=kv_pos >= 0, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_kernel_multiblock_sweep():
    """Skv spanning several K/V blocks exercises the online-softmax carry."""
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(2), 2, 512, 4, 2, 16,
                                   lengths=[200, 512])
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos, block_k=128,
                           interpret=True)
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                        kv_valid=kv_pos >= 0, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("Hq,Hkv,window", [(4, 4, 0), (4, 2, 0), (8, 1, 0),
                                           (4, 2, 16)])
def test_decode_kernel_bf16_matrix(Hq, Hkv, window):
    """Acceptance: ≤ 1e-2 max abs error in bf16 across GQA/window/empty."""
    B, Skv, hd = 3, 64, 32
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(3), B, Skv, Hq, Hkv,
                                   hd, lengths=[5, 33, 64],
                                   dtype=jnp.bfloat16)
    kv_pos = kv_pos.at[0].set(-1)          # slot 0 fully empty
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                           window=window, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), q_pos=q_pos, kv_pos=kv_pos,
                        kv_valid=kv_pos >= 0, causal=True, window=window)
    assert out.dtype == jnp.bfloat16
    err = float(jnp.abs(out.astype(jnp.float32) - ref).max())
    assert err <= 1e-2, err


def test_decode_kernel_empty_slot_yields_zeros():
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(4), 2, 32, 4, 4, 16,
                                   lengths=[10, 20])
    kv_pos = kv_pos.at[1].set(-1)
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                           interpret=True)
    assert bool(jnp.isfinite(out).all())
    assert bool((out[1] == 0.0).all())
    # the non-empty slot is unaffected
    ref = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                        kv_valid=kv_pos >= 0, causal=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               atol=2e-5)


def test_decode_kernel_ring_buffer_order():
    """Ring caches store positions out of order — the kernel masks by the
    position *values*, so a rolled pool must give identical output."""
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(5), 1, 32, 4, 2, 16,
                                   lengths=[32])
    roll = 11
    k2 = jnp.roll(k, roll, axis=1)
    v2 = jnp.roll(v, roll, axis=1)
    kv_pos2 = jnp.roll(kv_pos, roll, axis=1)
    out = flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                           window=8, interpret=True)
    out2 = flash_decode_fwd(q, k2, v2, q_pos=q_pos, kv_pos=kv_pos2,
                            window=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=2e-5)


def test_ops_decode_honours_arbitrary_kv_valid():
    """A caller-supplied kv_valid that is NOT kv_pos>=0 must be honoured by
    the kernel route (folded into kv_pos), matching ref exactly."""
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(8), 2, 32, 4, 2, 16,
                                   lengths=[20, 32])
    valid = (kv_pos % 3 != 0) & (kv_pos >= 0)      # arbitrary extra mask
    out = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=valid,
                    causal=True, impl="flash")
    ref = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=valid,
                    causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_ops_routes_flash_decode():
    """impl='flash' with Sq==1 + explicit positions must route to the decode
    kernel (and agree with ref); cross-style causal=False must not."""
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(6), 2, 32, 4, 2, 16,
                                   lengths=[9, 25])
    out = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                    kv_valid=kv_pos >= 0, causal=True, impl="flash")
    ref = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                    kv_valid=kv_pos >= 0, causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    # causal=False (cross decode) falls back to ref without error
    out_x = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=False,
                      impl="flash")
    assert out_x.shape == out.shape


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-9b"])
def test_decode_step_flash_matches_ref(arch):
    """Full model decode_step: flash vs ref logits (gemma2 covers the
    local/ring + softcap path, qwen the GQA global path)."""
    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    B, S = 2, 32
    cache_r = T.init_cache(cfg, B, S, dtype=jnp.bfloat16)
    prompt = jnp.asarray([[5, 9, 2, 7], [1, 2, 3, 4]], jnp.int32)
    logits, pcache = T.prefill(params, cfg, {"tokens": prompt}, kv_cap=S)
    cache = jax.tree_util.tree_map(
        lambda pool, one: one.astype(pool.dtype), cache_r, pcache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray([4, 4], jnp.int32)
    for _ in range(3):
        lr, cache_ref = T.decode_step(params, cfg, cache, tok, pos, impl="ref")
        lf, cache_fl = T.decode_step(params, cfg, cache, tok, pos,
                                     impl="flash")
        err = float(jnp.abs(lr.astype(jnp.float32)
                            - lf.astype(jnp.float32)).max())
        assert err <= 1e-2, err
        cache, tok, pos = cache_ref, jnp.argmax(lr, -1).astype(jnp.int32), pos + 1
