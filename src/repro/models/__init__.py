from repro.models.transformer import (  # noqa: F401
    init_params,
    init_cache,
    count_params,
    loss_fn,
    prefill,
    decode_step,
)
