"""Multi-device integration tests — each runs in a subprocess with forced
host devices so the main pytest process keeps seeing 1 CPU device."""
import json
import subprocess
import sys

import pytest

from conftest import REPO, subprocess_env


def _run(code: str, n_devices: int, timeout=600):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=subprocess_env(n_devices), cwd=REPO,
                       timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The same train step on a 2×2 mesh and on one device must produce the
    same loss (sharding is semantics-preserving)."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.config import get_config, reduce_config, ShapeSpec
from repro.launch.mesh import small_mesh
from repro.launch.steps import build_cell
from repro.models import transformer as T
from repro.training.optimizer import adamw_init

cfg = reduce_config(get_config("gemma2-9b"))
shape = ShapeSpec("t", "train", 32, 4)
mesh = small_mesh(2, 2)
jfn, specs, plan = build_cell(cfg, shape, mesh, donate=False)
params = T.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
with mesh:
    _, _, m_sharded = jfn(params, opt, batch)

from repro.launch.steps import make_train_step
fn = make_train_step(cfg)
_, _, m_single = jax.jit(fn)(params, opt, batch)
d = abs(float(m_sharded["loss"]) - float(m_single["loss"]))
assert d < 5e-2, d
print("OK", float(m_sharded["loss"]), float(m_single["loss"]))
""", 4)
    assert "OK" in out


def test_elastic_remesh_8_to_4():
    """Train 3 steps on 8 devices, re-mesh to 4, continue — loss stream
    must keep descending and state must re-shard without error."""
    out = _run("""
import jax
from repro.config import get_config, reduce_config, ShapeSpec
from repro.launch.mesh import small_mesh
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optimizer import OptConfig

cfg = reduce_config(get_config("qwen2.5-3b"))
shape = ShapeSpec("t", "train", 16, 8)
t = Trainer(cfg, shape, small_mesh(4, 2),
            opt_cfg=OptConfig(lr=5e-3, warmup_steps=0, total_steps=50),
            tcfg=TrainerConfig())
t.run(3)
l3 = t.metrics_log[-1]["loss"]
t.remesh(small_mesh(2, 2))     # elastic shrink: 8 -> 4 devices
t.run(3)
l6 = t.metrics_log[-1]["loss"]
assert t.step == 6
print("OK", l3, l6)
""", 8)
    assert "OK" in out


def test_elastic_remesh_matches_unremeshed():
    """Bitwise-ish: remeshing mid-run must not change the math — compare
    against an uninterrupted run on the original mesh."""
    out = _run("""
import jax
from repro.config import get_config, reduce_config, ShapeSpec
from repro.launch.mesh import small_mesh
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optimizer import OptConfig

cfg = reduce_config(get_config("qwen2.5-3b"))
shape = ShapeSpec("t", "train", 16, 8)
opt = OptConfig(lr=5e-3, warmup_steps=0, total_steps=50)

a = Trainer(cfg, shape, small_mesh(4, 2), opt_cfg=opt, tcfg=TrainerConfig())
a.run(2); a.remesh(small_mesh(2, 2)); a.run(2)

b = Trainer(cfg, shape, small_mesh(4, 2), opt_cfg=opt, tcfg=TrainerConfig())
b.run(4)

la = [m["loss"] for m in a.metrics_log]
lb = [m["loss"] for m in b.metrics_log]
diffs = [abs(x - y) for x, y in zip(la, lb)]
assert max(diffs) < 1e-3, (la, lb)
print("OK", diffs)
""", 8)
    assert "OK" in out


def test_overlap_collective_matmul():
    out = _run("""
import jax, jax.numpy as jnp
from repro.parallel.overlap import allgather_matmul, reduce_scatter_matmul
from repro.launch.mesh import small_mesh
mesh = small_mesh(1, 4)
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
x = jax.random.normal(k1, (64, 32))
w = jax.random.normal(k2, (32, 48))
err = float(jnp.abs(allgather_matmul(x, w, mesh) - x @ w).max())
assert err < 1e-4, err
x2 = jax.random.normal(k1, (64, 128))
w2 = jax.random.normal(k2, (128, 48))
err2 = float(jnp.abs(reduce_scatter_matmul(x2, w2, mesh) - x2 @ w2).max())
assert err2 < 1e-4, err2
# HLO really contains collective-permute (ring), not all-gather
hlo = jax.jit(lambda a, b: allgather_matmul(a, b, mesh)).lower(x, w).compile().as_text()
assert "collective-permute" in hlo
print("OK", err, err2)
""", 4)
    assert "OK" in out


def test_grad_compression_pod_axis():
    """int8-compressed DP gradients still train (loss decreases) on a
    2-pod-like mesh."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.config import get_config, reduce_config, ShapeSpec
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.training.optimizer import OptConfig, adamw_init, adamw_update
from repro.training.compression import compress_decompress
from repro.data.pipeline import DataConfig, LMDataPipeline

cfg = reduce_config(get_config("qwen2.5-3b"))
params = T.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
ocfg = OptConfig(lr=5e-3, warmup_steps=0, total_steps=60)
pipe = LMDataPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8))

def loss_f(p, batch):
    return T.loss_fn(p, cfg, batch)

err = None
losses = []
for step in range(15):
    batch = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(step).items()}
    (l, _), g = jax.jit(jax.value_and_grad(loss_f, has_aux=True))(params, batch)
    g, err = compress_decompress(g, err)   # int8 + error feedback
    params, opt, _ = adamw_update(g, opt, params, ocfg)
    losses.append(float(l))
assert sum(losses[-3:]) < sum(losses[:3]) - 0.05, losses
print("OK", losses[0], losses[-1])
""", 2)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_one_cell_end_to_end(tmp_path):
    """launch/dryrun.py lowers+compiles one real cell on the 256-device
    production mesh (the cheapest assigned cell: mamba2-130m train_4k)."""
    import subprocess
    env = subprocess_env(1)  # dryrun sets its own XLA_FLAGS internally
    env.pop("XLA_FLAGS", None)
    # write the cell into the test tmp dir — a stray single-cell
    # experiments/dryrun/ would trip test_hetero's matrix-completeness check
    env["REPRO_DRYRUN_DIR"] = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "train_4k", "--mesh", "single", "--force"],
        capture_output=True, text=True, env=env,
        cwd=str(REPO) + "/src", timeout=1800)
    assert "OK" in r.stdout, (r.stdout, r.stderr)


def test_sharded_slot_pool_serving_matches_single_device():
    """ServingEngine with a (data, model) mesh shards the KV slot pool and
    runs the fused decode step under the decode plan — outputs must match
    the unsharded engine exactly (greedy)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, ServingEngine

cfg = reduce_config(get_config("qwen2.5-3b"))
params = T.init_params(cfg, jax.random.PRNGKey(0), param_dtype=jnp.float32)
mesh = Mesh(np.asarray(jax.devices()).reshape(1, 2), ("data", "model"))

def run(mesh=None):
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, kv_len=48, max_new_tokens=5),
                        mesh=mesh)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, size=4 + i))
    eng.run_until_drained()
    return [r.output for r in sorted(eng.finished, key=lambda r: r.uid)]

a = run(None)
b = run(mesh)
assert a == b, (a, b)
print("OK", a[0])
""", 2)
    assert "OK" in out
