"""Compute/communication overlap: ring collective-matmul via shard_map.

The paper hides NoI traffic under compute by pipelining the ReRAM macro
and overlapping MHA with FF (§4.2).  The TPU-native analogue is the
*collective matmul*: a bulk ``all_gather(x)`` followed by the matmul
serialises wire time; instead each device matmuls the shard it currently
holds while ``ppermute``-ing shards around the ring, so the DMA of shard
i+1 is hidden under the dot of shard i (XLA schedules ppermute sends
asynchronously).  Ring steps are a *static* python loop — G is a mesh
constant — so the HLO contains exactly G dots and G-1 collective-permutes
and the scheduler can software-pipeline them.

Two patterns, matching the paper's two FF streaming directions:
- ``allgather_matmul``   — up-projection: gather sequence-sharded
  activations into the weight-stationary plane ("MC → ReRAM-macro head");
- ``reduce_scatter_matmul`` — down-projection: partial sums ring-reduced
  back out ("ReRAM-macro tail → MC").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """y = all_gather(x, axis) @ w, ring-overlapped.

    x: (m, k) sharded on dim 0 over ``axis``; w: (k, n) replicated.
    Returns y = x_full @ w, replicated over ``axis`` (all-gather
    semantics: every device ends with every row's output).
    """
    G = mesh.shape[axis]

    def body(x_blk, w_full):
        idx = jax.lax.axis_index(axis)
        m_l, n = x_blk.shape[0], w_full.shape[1]
        out = jnp.zeros((G, m_l, n), x_blk.dtype)
        blk = x_blk
        for i in range(G):
            src = (idx + i) % G              # global block id currently held
            y = blk @ w_full                 # compute this shard's rows
            out = jax.lax.dynamic_update_slice(out, y[None], (src, 0, 0))
            if i < G - 1:                    # move shards while dot i+1 runs
                blk = jax.lax.ppermute(
                    blk, axis, [(j, (j - 1) % G) for j in range(G)])
        return out.reshape(G * m_l, n)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )(x, w)


def reduce_scatter_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """y = reduce_scatter(x @ w) — contraction split over ``axis``.

    x: (m, k) sharded on dim 1 (k) over ``axis``; w: (k, n) sharded on
    dim 0 (k).  Each device computes partial sums x_loc @ w_loc and the
    ring reduce-scatter accumulates them so device d ends with output
    rows [d·m/G, (d+1)·m/G) fully summed — each partial dot overlapping
    the previous accumulator hop.
    """
    G = mesh.shape[axis]

    def body(x_blk, w_blk):
        # x_blk: (m, k/G), w_blk: (k/G, n)
        idx = jax.lax.axis_index(axis)
        m = x_blk.shape[0]
        m_l = m // G
        k_l = x_blk.shape[1]
        n = w_blk.shape[1]
        acc = jnp.zeros((m_l, n), jnp.float32)
        for i in range(G):
            c = (idx + 1 + i) % G            # row-chunk computed this step
            rows = jax.lax.dynamic_slice(x_blk, (c * m_l, 0), (m_l, k_l))
            acc = acc + (rows @ w_blk).astype(jnp.float32)
            if i < G - 1:                    # hand the accumulator upstream
                acc = jax.lax.ppermute(
                    acc, axis, [(j, (j - 1) % G) for j in range(G)])
        return acc.astype(x_blk.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None),
        check_rep=False,
    )(x, w)


# -- oracles for tests ---------------------------------------------------------

def allgather_matmul_ref(x, w):
    return x @ w


def reduce_scatter_matmul_ref(x, w):
    return x @ w
