"""Llama-3.2-Vision-90B backbone — cross-attention image layers every 5th.
[hf:meta-llama/Llama-3.2-90B-Vision; unverified]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, n_frontend_tokens, d_model); the ViT
tower is not part of the runnable graph.
"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    pattern=("global", "global", "global", "global", "cross"),
    frontend="vision_stub",
    n_frontend_tokens=1024,
    rope_theta=500_000.0,
    act="silu",
    glu=True,
    source="hf:meta-llama/Llama-3.2-11B-Vision (scaled per assignment)",
    notes="gated cross-attn layers (tanh gates); image KV static at decode",
))
