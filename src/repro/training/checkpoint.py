"""Atomic, resumable checkpointing for params / optimizer / data state.

Fault-tolerance contract (assignment deliverable-2 axis):

- **Atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` into
  ``step_<n>`` and update the ``LATEST`` pointer file last — a host dying
  mid-save can never corrupt the latest restorable state.
- **Bitwise resume**: params + both Adam moments + step counter + data
  state round-trip exactly (fp32 npz) — verified by
  ``tests/test_training.py::test_checkpoint_resume_bitwise``.
- **Preemption**: ``PreemptionHandler`` converts SIGTERM (the TPU-pod
  eviction signal) into a save-at-next-step-boundary request.
- **Elastic**: checkpoints are stored *unsharded* (gathered); restore
  re-shards onto whatever mesh the new job brings up, so a 512-chip job
  can resume on 256 chips (tested 8→4 fake devices).
- **Retention**: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# pytree <-> flat dict-of-arrays
# ---------------------------------------------------------------------------

def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, model "
                f"expects {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------

def save_checkpoint(ckpt_dir: str, step: int, *, params, opt_state=None,
                    data_state: Optional[dict] = None,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomic save; returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    meta = {"step": step, "data_state": data_state or {}, "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # LATEST pointer written last — the commit point
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(ckpt_dir: str, *, params_template, opt_template=None,
                       step: Optional[int] = None,
                       shardings=None, opt_shardings=None):
    """Restore (params, opt_state, meta).  ``shardings`` (optional pytrees of
    NamedSharding) re-shard onto the *current* mesh — the elastic-resume
    path: the checkpoint itself is mesh-agnostic."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")

    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten(params_template, dict(z))
    if shardings is not None:
        params = jax.device_put(params, shardings)

    opt_state = None
    opt_path = os.path.join(path, "opt_state.npz")
    if opt_template is not None and os.path.exists(opt_path):
        with np.load(opt_path) as z:
            opt_state = _unflatten(opt_template, dict(z))
        if opt_shardings is not None:
            opt_state = jax.device_put(opt_state, opt_shardings)

    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d)))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM → save-at-next-step-boundary.  The training loop polls
    ``should_save`` once per step; the signal handler itself only flips a
    flag (async-signal-safe)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._installed = []
        for s in signals:
            try:
                prev = signal.signal(s, self._on_signal)
                self._installed.append((s, prev))
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def should_save(self) -> bool:
        return self._flag.is_set()

    def reset(self):
        self._flag.clear()

    def uninstall(self):
        for s, prev in self._installed:
            signal.signal(s, prev)
        self._installed = []
