"""Whisper-large-v3 backbone — encoder-decoder, conv frontend stubbed.
[arXiv:2212.04356; unverified]

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings of shape (batch, frames, d_model);
the conv1d downsampler is not part of the runnable graph.
"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    cross_attn_decoder=True,
    frontend="audio_stub",
    n_frontend_tokens=1500,   # encoder length for decode-time cross caches
    use_rope=False,
    max_abs_positions=65_536,   # sinusoidal table sized for assigned shapes
    norm="layernorm",
    act="gelu",
    glu=False,
    qkv_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    source="arXiv:2212.04356",
    notes="enc-dec; long_500k skipped (decoder ctx 448 undefined at 524k)",
))
