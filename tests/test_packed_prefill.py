"""Packed ragged prefill + chunked-prefill scheduler.

Three levels:

- kernel: the segmented flash kernel (interpret mode) vs the segment-masked
  oracle and vs per-segment sequential attention — MHA/GQA/MQA, sliding
  window, softcap;
- model: ``prefill_packed`` vs per-prompt ``prefill`` — logit and per-slot
  KV-cache equivalence, plus length-exact padded prefill for the stateful
  layer kinds (ring-buffer local attention, SSM, RG-LRU);
- engine: packed+chunked admission vs the PR-1 sequential path —
  token-for-token drains across bucketed and non-bucketed layer kinds, the
  bounded decode-stall invariant, one compile per chunk shape, and the
  deep-queue FIFO regression.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import transformer as T
from repro.models.attention import apply_attention, init_attention
from repro.serving.engine import EngineConfig, ServingEngine


def _qkv(key, B, S, Hq, Hkv, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, Hq, hd), dtype)
    k = jax.random.normal(k2, (B, S, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, S, Hkv, hd), dtype)
    return q, k, v


def _segments(S, lens):
    seg = np.full((1, S), -1, np.int32)
    off = 0
    for i, l in enumerate(lens):
        seg[0, off:off + l] = i
        off += l
    assert off <= S
    return jnp.asarray(seg)


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [0, 7])
def test_segmented_kernel_matches_ref(Hq, Hkv, window):
    S, lens = 64, [20, 25, 10]                  # + 9 pad tokens
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, S, Hq, Hkv, 16)
    seg = _segments(S, lens)
    out = attention(q, k, v, segments=seg, causal=True, window=window,
                    impl="pallas_interpret")
    ref = attention(q, k, v, segments=seg, causal=True, window=window,
                    impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [0, 5])
def test_segmented_kernel_matches_per_segment_oracle(window):
    """No cross-prompt attention: every packed segment must equal attention
    run on that segment alone."""
    S, lens = 64, [17, 30, 8]
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, S, 4, 2, 16)
    seg = _segments(S, lens)
    out = attention(q, k, v, segments=seg, causal=True, window=window,
                    softcap=10.0, impl="pallas_interpret")
    off = 0
    for l in lens:
        solo = attention_ref(q[:, off:off + l], k[:, off:off + l],
                             v[:, off:off + l], causal=True, window=window,
                             softcap=10.0)
        np.testing.assert_allclose(np.asarray(out[:, off:off + l]),
                                   np.asarray(solo), atol=2e-5)
        off += l


def test_segmented_kernel_pad_isolation():
    """Changing pad-region q/k/v must not change any real segment output."""
    S, lens = 32, [10, 9]
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, S, 4, 4, 16)
    seg = _segments(S, lens)
    out1 = attention(q, k, v, segments=seg, causal=True, impl="pallas_interpret")
    q2 = q.at[:, 19:].set(99.0)
    k2 = k.at[:, 19:].set(-99.0)
    v2 = v.at[:, 19:].set(7.0)
    out2 = attention(q2, k2, v2, segments=seg, causal=True,
                     impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out1[:, :19]),
                                  np.asarray(out2[:, :19]))


# ---------------------------------------------------------------------------
# model level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-9b"])
def test_prefill_packed_matches_sequential(arch):
    """Packed multi-prompt prefill == per-prompt prefill: logits within bf16
    tolerance and KV cache entries exact per slot (gemma2 covers the
    local/ring + softcap path, qwen the GQA global path)."""
    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    lens = [5, 9, 3]
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in lens]
    C = 32
    toks = np.zeros((1, C), np.int32)
    pos = np.zeros((1, C), np.int32)
    offs, off = [], 0
    for i, p in enumerate(prompts):
        toks[0, off:off + len(p)] = p
        pos[0, off:off + len(p)] = np.arange(len(p))
        offs.append(off)
        off += len(p)
    seg = _segments(C, lens)
    gidx = jnp.asarray([offs[i] + lens[i] - 1 for i in range(len(lens))],
                       jnp.int32)
    logits_p, cache_p = T.prefill_packed(
        params, cfg, jnp.asarray(toks), jnp.asarray(pos), seg, gidx)
    for i, p in enumerate(prompts):
        logits_s, cache_s = T.prefill(params, cfg,
                                      {"tokens": jnp.asarray(p[None])})
        np.testing.assert_allclose(np.asarray(logits_p[i]),
                                   np.asarray(logits_s[0]), atol=1e-2)
        flat_p = jax.tree_util.tree_leaves(cache_p)
        flat_s = jax.tree_util.tree_leaves(cache_s)
        for lp, ls in zip(flat_p, flat_s):
            a = np.asarray(lp[:, :, offs[i]:offs[i] + lens[i]], np.float32)
            b = np.asarray(ls[:, :, :lens[i]], np.float32)
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-130m",
                                  "recurrentgemma-9b"])
def test_padded_prefill_state_exact(arch):
    """Right-padded prefill with ``length=`` must produce exactly the
    unpadded cache state for every stateful layer kind: ring-buffer local
    attention, SSM conv+state, RG-LRU conv+h."""
    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(1),
                           param_dtype=jnp.float32)
    rng = np.random.default_rng(1)
    plen, pad = 21, 32
    prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
    toks = np.zeros((1, pad), np.int32)
    toks[0, :plen] = prompt
    lp, cache_pad = T.prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                              kv_cap=pad, length=jnp.int32(plen))
    le, cache_ex = T.prefill(params, cfg,
                             {"tokens": jnp.asarray(prompt[None])},
                             kv_cap=pad)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(le), atol=1e-2)
    flat_p = jax.tree_util.tree_flatten_with_path(cache_pad)[0]
    flat_e = jax.tree_util.tree_flatten_with_path(cache_ex)[0]
    for (kp, a), (_, b) in zip(flat_p, flat_e):
        name = str(getattr(kp[-1], "key", ""))
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if name == "conv":                          # raw input gather
            np.testing.assert_array_equal(a, b)
        elif name in ("state", "h"):                # SSM / RG-LRU state:
            # scan tree shape differs between padded and exact lengths —
            # mathematically identical, ulp-level fp differences
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-3)
        elif name == "pos":
            # same set of real positions; global-cache pad entries carry
            # their stream index and are invalidated at engine insert
            a = np.where(a >= plen, -1, a)
            b = np.where(b >= plen, -1, b)
            np.testing.assert_array_equal(np.sort(a, -1), np.sort(b, -1))
    # attention caches: compare k/v entries position-by-position
    def ring_kv(cache):
        out = {}
        for (kp, leaf) in jax.tree_util.tree_flatten_with_path(cache)[0]:
            out["/".join(str(getattr(p, "key", p)) for p in kp)] = \
                np.asarray(leaf, np.float32)
        return out
    rp, re_ = ring_kv(cache_pad), ring_kv(cache_ex)
    for key in rp:
        if key.endswith("/pos"):
            base = key[:-4]
            pos_p, pos_e = rp[key], re_[key]
            for nm in ("k", "v", "ckv", "kr"):
                kk = f"{base}/{nm}"
                if kk not in rp or rp[kk].shape != re_[kk].shape:
                    continue
                for p_ in range(plen):
                    ia = np.argwhere(pos_p == p_)
                    ib = np.argwhere(pos_e == p_)
                    if len(ia) == 0 and len(ib) == 0:
                        continue
                    assert len(ia) == len(ib)
                    for a_idx, b_idx in zip(ia, ib):
                        np.testing.assert_array_equal(
                            rp[kk][tuple(a_idx)], re_[kk][tuple(b_idx)])


def test_cross_attention_decode_routes_flash():
    """Cross-attention decode no longer silently downgrades to ref: the
    masked decode-kernel path (q_pos >= every kv_pos) must match the
    non-causal reference."""
    cfg = reduce_config(get_config("qwen2.5-3b"))
    p = init_attention(jax.random.PRNGKey(0), cfg, cross=True,
                       dtype=jnp.float32)
    B, S_src = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    cache = {
        "k": jax.random.normal(jax.random.PRNGKey(2),
                               (B, S_src, cfg.n_kv_heads, cfg.head_dim)),
        "v": jax.random.normal(jax.random.PRNGKey(3),
                               (B, S_src, cfg.n_kv_heads, cfg.v_head_dim)),
    }
    pos = jnp.full((B, 1), 7, jnp.int32)
    out_f, _ = apply_attention(p, x, cfg=cfg, kind="cross", mode="decode",
                               pos=pos, cache=cache, impl="flash")
    out_r, _ = apply_attention(p, x, cfg=cfg, kind="cross", mode="decode",
                               pos=pos, cache=cache, impl="ref")
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_r),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# engine level
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1),
                           param_dtype=jnp.float32)
    return cfg, params


def _drain(cfg, params, lens, *, seed=3, **kw):
    defaults = dict(max_batch=2, kv_len=96, max_new_tokens=4, impl="ref")
    defaults.update(kw)
    eng = ServingEngine(cfg, params, EngineConfig(**defaults))
    rng = np.random.default_rng(seed)
    for plen in lens:
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    return [r.output for r in sorted(eng.finished, key=lambda r: r.uid)], eng


@pytest.mark.parametrize("arch,impl", [
    ("qwen2.5-3b", "ref"),        # GQA global, bucketed kind
    ("gemma2-9b", "ref"),         # sliding-window local + global
    ("gemma2-9b", "flash"),       # through the Pallas kernels
    ("mamba2-130m", "ref"),       # non-packable: padded per-request path
])
def test_packed_engine_matches_sequential(arch, impl):
    """Packed+chunked admission must reproduce the PR-1 sequential
    admission token-for-token (greedy), including prompts longer than the
    chunk (40, 60 > 16)."""
    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    lens = (40, 5, 60, 12, 3)
    seq, _ = _drain(cfg, params, lens, kv_len=128, packed=False)
    pk, eng = _drain(cfg, params, lens, kv_len=128, packed=True,
                     prefill_chunk=16)
    assert seq == pk


def test_chunked_prefill_bounded_decode_stall(qwen):
    """A long prompt admitted mid-decode may stall the pool by at most
    ~2 chunk budgets (one packed stream + one continuation call); the
    sequential path stalls for the whole padded prompt."""
    cfg, params = qwen
    C = 16
    lens = (5, 6, 80, 7, 8)
    _, seq = _drain(cfg, params, lens, kv_len=128, max_new_tokens=8,
                    packed=False)
    _, pk = _drain(cfg, params, lens, kv_len=128, max_new_tokens=8,
                   packed=True, prefill_chunk=C)
    assert pk.max_stall_tokens <= 2 * C
    assert seq.max_stall_tokens >= 80        # full prompt in one admission
    assert pk._jit_chunk_step._cache_size() == 1


def test_packed_no_retrace_across_mixed_lengths(qwen):
    """One compiled packed-prefill graph serves a burst of mixed prompt
    lengths (no compile-per-distinct-length), and the fused decode step
    still compiles exactly once."""
    cfg, params = qwen
    lens = (3, 5, 8, 10, 12, 4, 21, 33)
    _, eng = _drain(cfg, params, lens, max_batch=3, kv_len=64,
                    packed=True, prefill_chunk=32)
    assert eng._jit_packed_prefill._cache_size() == 1
    assert eng._jit_chunk_step._cache_size() <= 1
    assert eng._jit_step._cache_size() == 1
    assert eng._jit_prefill_insert._cache_size() == 0   # packable arch


def test_deep_queue_admission_fifo(qwen):
    """Deep queue of mixed lengths (with zero-budget requests sprinkled
    in): every request finishes, admission preserves FIFO order, and the
    engine drains without quadratic queue rescans."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=64, max_new_tokens=2, impl="ref",
        prefill_chunk=32))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(120):
        plen = int(rng.integers(1, 30))
        budget = 0 if i % 17 == 5 else None
        reqs.append(eng.submit(rng.integers(0, cfg.vocab_size, size=plen),
                               max_new_tokens=budget))
    done = eng.run_until_drained()
    assert len(done) == 120
    assert all(r.done for r in reqs)
    zero = [r for r in reqs if r.max_new_tokens == 0]
    assert all(r.output == [] for r in zero)
    # FIFO: non-zero-budget requests get their first token in uid order
    firsts = [r.t_first_token for r in reqs if r.max_new_tokens is None]
    assert firsts == sorted(firsts)


def test_overlong_prompt_rejected_at_submit_spares_neighbours(qwen):
    """An over-long prompt is rejected at submit time (it never reaches the
    packed stream), and the requests around it drain untouched."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, kv_len=32, max_new_tokens=2, impl="ref",
        prefill_chunk=16))
    rng = np.random.default_rng(0)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, size=5))
    with pytest.raises(ValueError, match="kv_len"):
        eng.submit(rng.integers(0, cfg.vocab_size, size=40))   # >= kv_len
    r3 = eng.submit(rng.integers(0, cfg.vocab_size, size=4))
    eng.run_until_drained()
    assert r1.done and r3.done
    assert len(r1.output) == 2 and len(r3.output) == 2


def test_no_decode_while_pool_is_prefill_only(qwen):
    """While the only occupied slots are mid-prefill there is nothing to
    decode: the fused step must not burn decode iterations on a dead pool."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, kv_len=128, max_new_tokens=2, impl="ref",
        prefill_chunk=16, decode_chunk=8))
    rng = np.random.default_rng(0)
    req = eng.submit(rng.integers(0, cfg.vocab_size, size=70))
    for _ in range(3):                  # first chunk + 2 continuations
        eng.step()
        assert eng.decode_steps == 0
    eng.run_until_drained()
    assert req.done and len(req.output) == 2


def test_packed_admission_single_call_per_burst(qwen):
    """A burst that fits the packed stream and the free slots is admitted
    in ONE jitted call + one d2h fetch (the admission bottleneck is gone)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=64, max_new_tokens=4, impl="ref",
        prefill_chunk=32))
    rng = np.random.default_rng(2)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, size=7))
    base = eng.host_transfers
    eng._admit_packed()
    assert eng.prefill_calls == 1
    assert eng.host_transfers - base == 1
    assert sum(r is not None for r in eng.slot_req) == 4
    eng.run_until_drained()
    assert len(eng.finished) == 4
