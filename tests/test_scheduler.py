"""Scheduler policy in isolation: no JAX, no engine — the policies see
only fake request records (the fields the Scheduler protocol permits:
uid, priority, t_enqueue, t_first_token, output) and a hand-advanced
clock, exactly the seam the engine drives them through.
"""
import dataclasses

import pytest

from repro.serving.scheduler import (FifoScheduler, Scheduler, SloClass,
                                     SloScheduler)


@dataclasses.dataclass
class FakeReq:
    """The Request-shaped view a scheduler is allowed to read."""
    uid: int
    priority: int = 0
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    output: list = dataclasses.field(default_factory=list)


def _queue(*specs):
    """specs: (uid, priority, t_enqueue)"""
    return [FakeReq(uid=u, priority=p, t_enqueue=t) for u, p, t in specs]


def _drain_order(sched, queue, now):
    """Run the engine's selection loop: select → pop, until empty."""
    q = list(queue)
    order = []
    while q:
        idx = sched.select(q, now)
        if idx is None:
            break
        order.append(q.pop(idx).uid)
    return order


# ---------------------------------------------------------------------------
# protocol + FIFO
# ---------------------------------------------------------------------------

def test_both_policies_satisfy_the_protocol():
    assert isinstance(FifoScheduler(), Scheduler)
    assert isinstance(SloScheduler(), Scheduler)


def test_fifo_selects_head_and_never_gates():
    s = FifoScheduler()
    q = _queue((0, 0, 0.0), (1, 5, 0.0), (2, 9, 0.0))
    assert s.select(q, now=1.0) == 0        # strict arrival order,
    assert _drain_order(s, q, 1.0) == [0, 1, 2]  # priority ignored
    assert s.select([], now=1.0) is None
    decoding = [FakeReq(uid=7, t_first_token=0.5, output=[1, 2])]
    for _ in range(32):
        assert s.allow_prefill(decoding, now=99.0)
    s.observe_prefill(1.0)                  # no-op, must not throw


# ---------------------------------------------------------------------------
# SLO selection: priority ordering, slack tie-break, aging
# ---------------------------------------------------------------------------

def test_slo_orders_by_priority_then_fifo():
    s = SloScheduler()
    q = _queue((0, 0, 0.0), (1, 2, 0.1), (2, 1, 0.2), (3, 2, 0.3))
    assert _drain_order(s, q, now=1.0) == [1, 3, 2, 0]


def test_slo_equal_priority_is_fifo():
    s = SloScheduler()
    q = _queue((0, 1, 0.0), (1, 1, 0.1), (2, 1, 0.2))
    assert _drain_order(s, q, now=1.0) == [0, 1, 2]


def test_slo_ttft_slack_breaks_priority_ties():
    """Within a priority level the most-overdue request (tightest TTFT
    slack) goes first, even if it arrived later."""
    s = SloScheduler(classes={1: SloClass(ttft_ms=100.0),
                              2: SloClass(ttft_ms=5000.0)})
    # uid 0 arrived first but its class allows 5 s; uid 1 allows 100 ms.
    # Map both to the same priority level via the classes dict keys:
    q = [FakeReq(uid=0, priority=2, t_enqueue=0.00),
         FakeReq(uid=1, priority=2, t_enqueue=0.01)]
    # same class → same slack offset → FIFO
    assert _drain_order(s, q, now=1.0) == [0, 1]
    q = [FakeReq(uid=0, priority=2, t_enqueue=0.00),   # slack 5 - 1 = 4 s
         FakeReq(uid=1, priority=1, t_enqueue=0.01)]   # slack ≈ -0.9 s
    s2 = SloScheduler(classes={1: SloClass(ttft_ms=100.0),
                               2: SloClass(ttft_ms=5000.0)})
    # priority still dominates: 2 > 1 even though 1 is more overdue
    assert _drain_order(s2, q, now=1.0) == [0, 1]


def test_aging_prevents_starvation_of_low_priority():
    """A starving low-priority request eventually outranks fresh
    high-priority arrivals: one effective level per aging_s waited."""
    s = SloScheduler(aging_s=1.0)
    old_lo = FakeReq(uid=0, priority=0, t_enqueue=0.0)
    new_hi = FakeReq(uid=1, priority=2, t_enqueue=9.9)
    # at t=1: lo has aged +1 level (eff 1) < 2 → hi wins
    assert s.select([old_lo, new_hi], now=1.0) == 1
    # at t=10: lo has aged +10 levels (eff 10) > 2 → lo finally wins
    assert s.select([old_lo, new_hi], now=10.0) == 0


def test_no_aging_means_indefinite_starvation():
    """Contrast case: aging_s=0 lets high-priority traffic starve the
    low class forever — the knob is what buys starvation-freeness."""
    s = SloScheduler(aging_s=0.0)
    old_lo = FakeReq(uid=0, priority=0, t_enqueue=0.0)
    new_hi = FakeReq(uid=1, priority=2, t_enqueue=1e6)
    assert s.select([old_lo, new_hi], now=1e6 + 1.0) == 1


# ---------------------------------------------------------------------------
# SLO preemption gating: TPOT slack vs the measured prefill stall
# ---------------------------------------------------------------------------

def _decoding(tpot_due_in_s, *, now, priority=1, tpot_ms=50.0):
    """One decoding request whose next token is due in tpot_due_in_s."""
    n_out = 4
    tpot_s = tpot_ms / 1e3
    t_first = now + tpot_due_in_s - n_out * tpot_s
    return [FakeReq(uid=0, priority=priority, t_first_token=t_first,
                    output=[0] * n_out)]


def test_prefill_allowed_when_slack_absorbs_stall():
    s = SloScheduler(classes={1: SloClass(tpot_ms=50.0)})
    s.observe_prefill(0.010)                 # measured stall: 10 ms
    now = 100.0
    assert s.allow_prefill(_decoding(0.040, now=now), now)  # 40 ms ≥ 10 ms


def test_prefill_deferred_when_slack_too_thin():
    s = SloScheduler(classes={1: SloClass(tpot_ms=50.0)})
    s.observe_prefill(0.030)                 # stall 30 ms
    now = 100.0
    assert not s.allow_prefill(_decoding(0.005, now=now), now)  # 5 < 30


def test_no_tpot_target_never_gates():
    """Decoding slots without a TPOT target have infinite slack."""
    s = SloScheduler()                       # default class: no targets
    s.observe_prefill(10.0)
    now = 100.0
    assert s.allow_prefill(_decoding(0.001, now=now), now)


def test_deferral_is_bounded():
    """Under persistent negative slack prefill still runs after
    max_defer gated iterations — admission is throttled, never starved."""
    s = SloScheduler(classes={1: SloClass(tpot_ms=50.0)}, max_defer=3)
    s.observe_prefill(0.5)                   # huge stall estimate
    now = 100.0
    dec = _decoding(0.001, now=now)
    decisions = [s.allow_prefill(dec, now) for _ in range(8)]
    # gated, gated, forced, gated, gated, forced, ...
    assert decisions[:6] == [False, False, True, False, False, True]


def test_allow_resets_the_deferral_counter():
    s = SloScheduler(classes={1: SloClass(tpot_ms=50.0)}, max_defer=3)
    s.observe_prefill(0.020)
    now = 100.0
    assert not s.allow_prefill(_decoding(0.001, now=now), now)  # defer 1
    assert s.allow_prefill(_decoding(0.100, now=now), now)      # slack ok
    # counter reset: the next thin-slack run needs max_defer again
    dec = _decoding(0.001, now=now)
    assert [s.allow_prefill(dec, now) for _ in range(3)] == \
        [False, False, True]


def test_observe_prefill_tracks_ewma():
    s = SloScheduler(ewma=0.5)
    s.observe_prefill(0.100)
    assert s._stall_est_s == pytest.approx(0.100)
    s.observe_prefill(0.020)
    assert s._stall_est_s == pytest.approx(0.060)   # 0.1 + 0.5*(0.02-0.1)
    s.observe_prefill(0.020)
    assert s._stall_est_s == pytest.approx(0.040)


def test_constructor_validation():
    with pytest.raises(ValueError):
        SloScheduler(max_defer=0)
    with pytest.raises(ValueError):
        SloScheduler(ewma=0.0)
    with pytest.raises(ValueError):
        SloScheduler(ewma=1.5)


# ---------------------------------------------------------------------------
# end-to-end policy behaviour against a fake executor (no JAX): the
# engine's selection loop under overload
# ---------------------------------------------------------------------------

def test_slo_beats_fifo_on_high_priority_wait_under_overload():
    """Drive both policies through the same queue-selection loop a
    backlogged engine runs (one admission per 'iteration') and check the
    high-priority class waits less under SLO scheduling."""
    def run(sched):
        # 12 queued requests, every 4th is high-priority, arrivals 10 ms
        # apart; one admission every 30 ms of virtual time
        q = [FakeReq(uid=i, priority=1 if i % 4 == 0 else 0,
                     t_enqueue=0.010 * i) for i in range(12)]
        waits_hi, waits_lo = [], []
        now = 0.12
        while q:
            idx = sched.select(q, now)
            req = q.pop(idx)
            (waits_hi if req.priority else waits_lo).append(
                now - req.t_enqueue)
            now += 0.030
        return max(waits_hi), max(waits_lo)

    fifo_hi, _ = run(FifoScheduler())
    slo_hi, slo_lo = run(SloScheduler())
    assert slo_hi < fifo_hi          # hi class jumps the backlog
    assert slo_lo > 0.0              # lo class still finishes (drained)
