PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify bench-serving report

test:               ## tier-1 test suite
	$(PY) -m pytest -x -q

bench-serving:      ## full serving decode benchmark -> experiments/BENCH_serving.json
	$(PY) -m benchmarks.perf_serving

verify:             ## CI gate: tier-1 tests + serving bench in smoke mode
	$(PY) -m pytest -x -q
	$(PY) -m benchmarks.perf_serving --smoke

report:             ## render benchmark/dry-run tables
	$(PY) -m benchmarks.report
