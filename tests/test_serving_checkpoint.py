"""Crash-safe serving: bit-exact snapshot/restore, journal replay,
corruption fallback, retry, and quantised slot-pool serialisation."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving import checkpoint as sc
from repro.serving.engine import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    return cfg, params


def _ecfg(**kw):
    defaults = dict(max_batch=3, kv_len=48, max_new_tokens=6, impl="ref",
                    prefill_chunk=8)
    defaults.update(kw)
    return EngineConfig(**defaults)


# prompt 2 is longer than the chunk budget -> chunked-prefill state
_PROMPT_LENS = (8, 5, 19, 11, 6)


def _prompts(cfg, lens=_PROMPT_LENS):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=n) for n in lens]


def _outputs(engine):
    out = {}
    for r in engine.finished:
        assert r.uid not in out
        out[r.uid] = list(r.output)
    return out


def _reference(cfg, params, ecfg, prompts, kill_at):
    eng = ServingEngine(cfg, params, ecfg)
    for p in prompts[:4]:
        eng.submit(p.copy())
    for _ in range(kill_at):
        eng.step()
    eng.submit(prompts[4].copy())
    eng.run_until_drained()
    return _outputs(eng)


def _crash_and_restore(cfg, params, ecfg, prompts, kill_at, ckpt_dir,
                       lost_steps=2):
    eng = ServingEngine(cfg, params, ecfg)
    ck = sc.EngineCheckpointer(eng, ckpt_dir)
    for p in prompts[:4]:
        ck.submit(p.copy())
    for _ in range(kill_at):
        eng.step()
    ck.save()
    ck.submit(prompts[4].copy())          # journal-only: post-snapshot
    for _ in range(lost_steps):           # work the crash throws away
        eng.step()
    del eng                               # the crash
    eng2 = ServingEngine.restore(cfg, params, ckpt_dir)
    eng2.run_until_drained()
    return eng2


@pytest.mark.parametrize("kill_at,temperature", [
    (0, 0.0),     # post-admission, pre-snapshot journal burst
    (1, 0.0),     # mid-prefill-chunk (19-token prompt, chunk=8)
    (3, 0.0),     # mid-decode
    (3, 0.8),     # mid-decode under temperature sampling (PRNG state)
])
def test_kill_restore_bit_exact(small_model, tmp_path, kill_at,
                                temperature):
    cfg, params = small_model
    ecfg = _ecfg(temperature=temperature, seed=0)
    prompts = _prompts(cfg)
    ref = _reference(cfg, params, ecfg, prompts, kill_at)
    eng2 = _crash_and_restore(cfg, params, ecfg, prompts, kill_at,
                              str(tmp_path))
    assert _outputs(eng2) == ref          # bit-exact, nothing lost/dup
    s = eng2.stats()
    assert s["restores"] == 1
    assert s["replayed_requests"] == 1
    assert s["checkpoints_written"] == 1


def test_mid_prefill_snapshot_carries_chunk_progress(small_model,
                                                     tmp_path):
    cfg, params = small_model
    ecfg = _ecfg()
    prompts = _prompts(cfg)
    eng = ServingEngine(cfg, params, ecfg)
    for p in prompts[:4]:
        eng.submit(p.copy())
    for _ in range(10):                   # reach the adversarial kill
        eng.step()                        # point: a 19-token prompt is
        if eng._prefilling:               # mid-chunk (chunk=8)
            break
    assert eng._prefilling
    sc.save_engine(eng, str(tmp_path))
    eng2 = sc.restore_engine(cfg, params, str(tmp_path))
    assert eng2._prefilling == eng._prefilling
    eng.run_until_drained()
    eng2.run_until_drained()
    assert _outputs(eng2) == _outputs(eng)


def test_journal_replay_desync_raises(small_model, tmp_path):
    cfg, params = small_model
    prompts = _prompts(cfg)
    eng = ServingEngine(cfg, params, _ecfg())
    ck = sc.EngineCheckpointer(eng, str(tmp_path))
    ck.submit(prompts[0].copy())
    ck.save()
    # a gap in the journal uids cannot replay to the recorded uid
    with open(os.path.join(str(tmp_path), sc.JOURNAL), "a") as f:
        f.write(json.dumps({"uid": eng._uid + 1,
                            "prompt": [1, 2, 3],
                            "max_new_tokens": 4}) + "\n")
    with pytest.raises(RuntimeError, match="journal replay desync"):
        sc.restore_engine(cfg, params, str(tmp_path))


def test_torn_journal_tail_dropped(small_model, tmp_path):
    cfg, params = small_model
    prompts = _prompts(cfg)
    eng = ServingEngine(cfg, params, _ecfg())
    ck = sc.EngineCheckpointer(eng, str(tmp_path))
    ck.submit(prompts[0].copy())
    ck.save()
    ck.submit(prompts[1].copy())
    with open(os.path.join(str(tmp_path), sc.JOURNAL), "a") as f:
        f.write('{"uid": 99, "prompt": [1,')   # crash mid-append
    eng2 = sc.restore_engine(cfg, params, str(tmp_path))
    assert eng2.replayed_requests == 1         # the complete line survived
    eng2.run_until_drained()
    assert len(_outputs(eng2)) == 2


def test_corrupt_newest_falls_back_to_previous(small_model, tmp_path):
    cfg, params = small_model
    prompts = _prompts(cfg)
    eng = ServingEngine(cfg, params, _ecfg())
    for p in prompts[:2]:
        eng.submit(p.copy())
    sc.save_engine(eng, str(tmp_path))
    eng.step()
    newest = sc.save_engine(eng, str(tmp_path))
    # tamper one leaf of the newest arrays blob (valid npz, wrong bits)
    # -> the integrity digest must reject it
    blob = os.path.join(newest, "arrays.npz")
    arrays = sc.load_arrays(blob)
    key = sorted(arrays)[0]
    tampered = np.array(arrays[key])
    tampered.view(np.uint8).reshape(-1)[0] ^= 0xFF
    arrays[key] = tampered
    sc.save_arrays(blob, arrays)
    arrays, meta, name = sc.load_newest_intact(str(tmp_path))
    assert name == "snap_00000000"
    eng2 = sc.restore_engine(cfg, params, str(tmp_path))
    assert eng2.restores == 1
    eng2.run_until_drained()
    assert len(_outputs(eng2)) == 2

    # all snapshots corrupt -> explicit FileNotFoundError
    oldest = os.path.join(str(tmp_path), name, "meta.json")
    with open(oldest, "r+") as f:
        meta = json.load(f)
        meta["digest"] = "0" * 64
        f.seek(0)
        json.dump(meta, f)
        f.truncate()
    with pytest.raises(FileNotFoundError, match="no intact snapshot"):
        sc.load_newest_intact(str(tmp_path))


def test_save_retries_transient_failures(small_model, tmp_path,
                                         monkeypatch):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg())
    eng.submit(_prompts(cfg)[0].copy())
    real = sc.atomic_save_dir
    fails = {"n": 2}
    sleeps = []

    def flaky(*a, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient store hiccup")
        return real(*a, **kw)

    monkeypatch.setattr(sc, "atomic_save_dir", flaky)
    path = sc.save_engine(eng, str(tmp_path), retries=3, backoff_s=0.05,
                          sleep=sleeps.append)
    assert os.path.isdir(path)
    assert sleeps == [0.05, 0.1]          # exponential backoff, no waiting
    assert eng.checkpoints_written == 1

    # exhausted retries re-raise and roll the counter back
    fails["n"] = 10
    with pytest.raises(OSError):
        sc.save_engine(eng, str(tmp_path), retries=1, backoff_s=0.01,
                       sleep=sleeps.append)
    assert eng.checkpoints_written == 1


def test_config_mismatch_rejected_policy_tolerated(small_model, tmp_path):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg())
    eng.submit(_prompts(cfg)[0].copy())
    sc.save_engine(eng, str(tmp_path))
    with pytest.raises(ValueError, match="config mismatch on 'kv_len'"):
        sc.restore_engine(cfg, params, str(tmp_path),
                          ecfg=_ecfg(kv_len=64))
    # operational policy knobs are free to change across a restart
    eng2 = sc.restore_engine(cfg, params, str(tmp_path),
                             ecfg=_ecfg(deadline_ms=50.0, max_queue=7))
    assert eng2.ecfg.max_queue == 7
    other = dataclasses.replace(cfg, name="other-model")
    with pytest.raises(ValueError, match="snapshot is of model"):
        sc.restore_engine(other, params, str(tmp_path))


def test_empty_dir_raises(small_model, tmp_path):
    cfg, params = small_model
    with pytest.raises(FileNotFoundError):
        sc.restore_engine(cfg, params, str(tmp_path))


def test_keep_bounds_snapshots_latest_survives(small_model, tmp_path):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg())
    eng.submit(_prompts(cfg)[0].copy())
    for _ in range(4):
        sc.save_engine(eng, str(tmp_path), keep=2)
    names = sc.list_snapshots(str(tmp_path), sc.SNAP_PREFIX)
    assert names == ["snap_00000002", "snap_00000003"]
    assert sc.read_latest(str(tmp_path)) == "snap_00000003"
    assert eng.checkpoints_written == 4


# ---------------------------------------------------------------------------
# quantised slot-pool serialisation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [8, 4])
def test_quantised_pool_kill_restore_bit_exact(small_model, tmp_path,
                                               kv_bits):
    """int8/int4 code+scale pools snapshot and resume bit-exactly (GQA
    engine path)."""
    cfg, params = small_model
    ecfg = _ecfg(kv_bits=kv_bits)
    prompts = _prompts(cfg)
    ref = _reference(cfg, params, ecfg, prompts, kill_at=2)
    eng2 = _crash_and_restore(cfg, params, ecfg, prompts, 2,
                              str(tmp_path))
    assert _outputs(eng2) == ref
    leaves = sc.flatten_tree({"cache": eng2.cache})
    kinds = {k.split("/")[-1]: np.asarray(v).dtype
             for k, v in leaves.items()}
    assert kinds["k_q"] == np.int8 and kinds["v_q"] == np.int8
    assert kinds["k_s"] == np.float32 and kinds["v_s"] == np.float32


def _mqa(cfg):
    return dataclasses.replace(cfg, n_kv_heads=1)


@pytest.mark.parametrize("arch,mutate,kv_bits", [
    ("gpt-j", None, 8),                    # MHA
    ("gemma2-9b", None, 8),                # GQA, global+local windows
    ("qwen2.5-3b", _mqa, 8),               # MQA (one shared KV head)
    ("qwen2.5-3b", None, 4),               # int4 packed codes
    ("deepseek-v2-236b", None, 8),         # MLA: latent cache stays fp
    ("bart-large", None, 8),               # enc-dec: cross-KV stays fp
])
def test_slot_pool_serialisation_roundtrip(tmp_path, arch, mutate,
                                           kv_bits):
    """Every attention variant's slot pool round-trips through the
    dtype-safe npz with leaf dtypes intact — including the documented fp
    exceptions (MLA latents, cross-attention KV are never quantised)."""
    cfg = reduce_config(get_config(arch))
    if mutate:
        cfg = mutate(cfg)
    cache = T.init_cache(cfg, batch=2, kv_len=16, kv_bits=kv_bits)
    # realistic content: nonzero codes/scales/rows, not just zeros
    cache = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(np.random.default_rng(0)
                                  .integers(1, 5, x.shape), x.dtype),
        cache)
    flat = sc.flatten_tree(cache)
    path = os.path.join(str(tmp_path), "pool.npz")
    sc.save_arrays(path, flat)
    back = sc.unflatten_tree(cache, sc.load_arrays(path), cast=False)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), kp

    leaf_names = {k.split("/")[-1] for k in flat}
    if arch == "deepseek-v2-236b":
        # MLA is the documented fp exception: latent cache, no quant planes
        assert {"ckv", "kr"} <= leaf_names
        assert not ({"k_q", "v_q"} & leaf_names)
    else:
        assert {"k_q", "k_s", "v_q", "v_s"} <= leaf_names  # quant planes
    if cfg.cross_attn_decoder:
        # the fp exception: cross-KV leaves are bf16, never int8
        cross = {k: np.asarray(v).dtype for k, v in flat.items()
                 if "/cross/" in k}
        assert cross and all(d != np.int8 for d in cross.values())


def test_mla_pool_serialises_fp_latents(tmp_path):
    """MLA caches (ckv/kr latents) are the documented fp exception: no
    quant planes exist, and the latents round-trip bit-exactly."""
    cfg = reduce_config(get_config("deepseek-v2-236b"))
    cache = T.init_cache(cfg, batch=2, kv_len=16, kv_bits=8)
    flat = sc.flatten_tree(cache)
    names = {k.split("/")[-1] for k in flat}
    assert {"ckv", "kr", "pos"} <= names
    assert not ({"k_q", "v_q"} & names)
    path = os.path.join(str(tmp_path), "mla.npz")
    sc.save_arrays(path, flat)
    back = sc.load_arrays(path)
    for k, a in flat.items():
        assert back[k].dtype == np.asarray(a).dtype


def test_counters_surface_in_stats(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg())
    s = eng.stats()
    assert s["checkpoints_written"] == 0
    assert s["restores"] == 0
    assert s["replayed_requests"] == 0


class _TickClock:
    """Deterministic engine clock: every call advances a fixed tick, so
    two runs that execute the same code path read identical timestamps."""

    def __init__(self, t: float = 100.0, dt: float = 0.01):
        self.t, self.dt = t, dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


def test_slo_scheduler_state_survives_kill_restore(small_model, tmp_path):
    """Adaptive SLO state (EWMA stall cost, deferral counter) is part of
    the snapshot: the revived engine makes the same preemption decisions
    — and therefore finishes requests in the same order — as the run
    that never crashed.  A tick clock makes both timelines exact."""
    from repro.serving.scheduler import SloClass, SloScheduler

    cfg, params = small_model
    classes = {0: SloClass(), 1: SloClass(ttft_ms=50.0, tpot_ms=5.0)}

    def make_sched():
        return SloScheduler(classes, aging_s=0.5, max_defer=3)

    prompts = _prompts(cfg)
    prios = (0, 1, 0, 1, 0)
    kill_at = 3

    ref = ServingEngine(cfg, params, _ecfg(clock=_TickClock()),
                        scheduler=make_sched())
    for p, pr in zip(prompts, prios):
        ref.submit(p.copy(), priority=pr)
    ref.run_until_drained()
    ref_out = _outputs(ref)
    ref_order = [r.uid for r in ref.finished]

    ecfg = _ecfg(clock=_TickClock())
    eng = ServingEngine(cfg, params, ecfg, scheduler=make_sched())
    ck = sc.EngineCheckpointer(eng, str(tmp_path))
    for p, pr in zip(prompts, prios):
        ck.submit(p.copy(), priority=pr)
    for _ in range(kill_at):
        eng.step()
    ck.save()
    state = eng.scheduler.state_dict()
    assert state["stall_est_s"] > 0.0     # admission bursts were observed
    t_resume = ecfg.clock.t
    for _ in range(2):                    # work the crash throws away
        eng.step()
    del eng

    eng2 = sc.restore_engine(cfg, params, str(tmp_path),
                             ecfg=_ecfg(clock=_TickClock(t=t_resume)),
                             scheduler=make_sched())
    assert eng2.scheduler.state_dict() == state   # EWMA + defers revived
    eng2.run_until_drained()
    assert _outputs(eng2) == ref_out      # bit-exact continuation
    assert [r.uid for r in eng2.finished] == ref_order


def test_restore_without_scheduler_state_starts_cold(small_model,
                                                     tmp_path):
    """Pre-PR-9 snapshots carry no ``scheduler`` block; restore leaves
    the fresh policy at its cold defaults rather than failing."""
    from repro.serving.scheduler import SloScheduler

    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg())
    for p in _prompts(cfg)[:2]:
        eng.submit(p.copy())
    eng.step()
    # simulate an old snapshot: strip the scheduler block from the meta
    # (recomputing the integrity digest so the snapshot stays intact)
    snap = sc.save_engine(eng, str(tmp_path))
    arrays = sc.load_arrays(os.path.join(snap, "arrays.npz"))
    path = os.path.join(snap, "meta.json")
    with open(path) as f:
        meta = json.load(f)
    meta.pop("scheduler", None)
    meta["digest"] = sc._meta_digest(arrays, meta)
    with open(path, "w") as f:
        json.dump(meta, f)
    eng2 = sc.restore_engine(cfg, params, str(tmp_path),
                             scheduler=SloScheduler())
    assert eng2.scheduler._stall_est_s == 0.0
    assert eng2.scheduler._defers == 0
    eng2.run_until_drained()
