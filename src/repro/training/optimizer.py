"""AdamW with decoupled weight decay, global-norm clipping, and warmup-cosine
schedule — pure JAX, optimizer state shards exactly like the parameters
(ZeRO-style: FSDP'd params ⇒ FSDP'd moments for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {"gnorm": gnorm, "lr": lr}
