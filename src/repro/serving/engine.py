"""Batched serving engine: slotted KV cache, continuous batching, packed
ragged prefill and chunked prefill.

The paper's evaluation is *inference*; this is the inference runtime for
Plane A.  Design follows the production pattern (vLLM/TGI-style, expressed
in JAX with static shapes).  Each engine iteration runs three phases:

1. **admission** — *all* queued requests that fit are packed back-to-back
   into one ragged ``(1, C)`` token stream (``C = prefill_chunk``) and
   prefilled in a **single** jitted call: the segmented flash kernel masks
   cross-prompt attention, and one donated multi-slot scatter inserts every
   segment's KV into its slot.  A burst of arrivals therefore costs one
   device call, not one per request — time-to-first-token no longer scales
   linearly with queue depth.  Prompts longer than ``C`` contribute their
   first ``≤ C`` tokens and leave the slot in the *prefilling* state;
2. **chunked-prefill continuation** — every prefilling slot advances by at
   most one ``C``-token chunk per iteration (one batched jitted call over
   the pool; chunk K/V is written at explicit positions and attends to the
   whole cache, so later chunks see earlier chunks).  A long prompt can
   never stall the decode pool for more than one chunk budget;
3. **decode** — one jitted, cache-donated step over the full slot pool:
   decode → sample (greedy and temperature, PRNG threaded on device) →
   position/budget/EOS bookkeeping; the only device→host traffic per
   iteration is one packed ``(K, 3, max_batch)`` int32 of
   ``(next_token, done, anomaly)``.  Mid-prefill and dead slots carry
   ``pos = -1`` so their decode writes are dropped, never corrupting a
   half-filled row.

Hardening (defaults off → bit-identical to the plain engine): per-request
deadlines (``deadline_ms`` — expired requests are evicted and marked
``FAILED_DEADLINE``), bounded-queue overload shedding (``max_queue`` —
excess submits return with the retriable ``REJECTED`` status), NaN/inf
logit quarantine (an anomalous slot is frozen and retried
``anomaly_retries`` times before only that request fails — the batch
survives), and explicit ``run_until_drained`` failure semantics
(``EngineStallError`` + ``FAILED_MAX_ITERS``, never a silent partial
drain).  Every submitted request ends in a terminal state.

Every prefill shape is static: the packed stream is always ``(1, C)``, the
continuation always ``(max_batch, C)``, and non-packable architectures
(SSM / recurrent / MoE stacks, whose state or expert-capacity would couple
packed prompts) prefill per-request right-padded to a multiple of ``C``
with ``length``-exact state handling — no compile-per-distinct-prompt-length
anywhere.

``packed=False`` preserves the PR-1 sequential admission path (one
bucket-padded batch-1 prefill+insert call per request) and ``fused=False``
the original host-looped decode step — both kept as measurement baselines
for ``benchmarks/perf_serving.py``.

The engine is mesh-aware: pass ``mesh=`` to shard the slot pool (and run
the decode step) over a pod with the decode-mode plan from
``repro.parallel.sharding``; the packed prefill call runs under the
sequence-sharded serving prefill plan.  On CPU tests everything runs on
one device with the same code path.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.parallel.api import activate_plan


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # KV slot pool size
    kv_len: int = 256             # per-slot KV depth
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 → greedy
    eos_token: int = -1           # -1 → never stops early
    impl: str = "ref"             # attention impl ("flash" → Pallas kernels)
    seed: int = 0
    fused: bool = True            # zero-host-sync decode step (False = seed path)
    packed: bool = True           # packed ragged prefill + chunked prefill
    #   (False = PR-1 sequential admission: one batch-1 prefill per request)
    prefill_chunk: int = 0        # packed-stream / chunk budget in tokens
    #   (0 → min(128, kv_len)); also the padding quantum for non-packable
    #   architectures, so every prefill shape is static
    decode_chunk: int = 1         # device decode iterations per step() —
    #   >1 runs a lax.scan of decode→sample on device (multi-step
    #   scheduling): host sync cost is amortised over the chunk, at the
    #   price of admitting new requests only at chunk boundaries
    weight_bits: int = 0          # 0 = native fp; 8/4 = weight-only
    #   quantisation (per-channel int8 / packed int4, repro.quant) of the
    #   dense projections — the fp path is bit-identical to weight_bits=0
    weight_group: int = 0         # rows of K per scale group (0 = per-channel)
    kv_bits: int = 0              # 0 = fp pool; 8/4 = quantised slot-pool KV
    #   cache (per-(token, head) scales, quantise-on-commit / dequantise-
    #   on-read; the jitted step never materialises an fp cache)
    deadline_ms: float = 0.0      # per-request TTL from submit (0 = none):
    #   expired requests are evicted (queued or mid-decode) and marked
    #   FAILED_DEADLINE instead of decoding forever
    max_queue: int = 0            # bounded-queue admission (0 = unbounded):
    #   submits beyond the bound are shed with the retriable REJECTED
    #   status instead of growing the backlog without bound
    anomaly_retries: int = 1      # NaN/inf-logit quarantine: a slot whose
    #   logits go non-finite is frozen (no token, no pos/budget advance)
    #   and retried this many times before only that request is failed —
    #   the rest of the batch keeps decoding
    clock: Callable[[], float] = time.monotonic
    #   the engine's time source for request timestamps and deadline
    #   arithmetic — injectable so deadline/eviction tests advance a fake
    #   clock instead of sleeping.  Every stats() latency is a difference
    #   of clock readings, so any monotonic float-seconds source works.


class EngineStallError(RuntimeError):
    """``run_until_drained`` exhausted ``max_iters`` with requests still in
    flight.  Every stranded request has been marked ``FAILED_MAX_ITERS``
    (terminal) before this is raised — nothing is silently dropped."""


# Request terminal states (Request.status).  A submitted request always
# ends in exactly one of the terminal states below — queue/slot limbo is
# never silent.
QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
FAILED_DEADLINE = "failed_deadline"    # missed its EngineConfig.deadline_ms
FAILED_ANOMALY = "failed_anomaly"      # non-finite logits past the retries
FAILED_MAX_ITERS = "failed_max_iters"  # stranded at max_iters exhaustion
REJECTED = "rejected"                  # shed at submit (bounded queue) —
#                                        retriable: resubmit later
TERMINAL = (DONE, FAILED_DEADLINE, FAILED_ANOMALY, FAILED_MAX_ITERS,
            REJECTED)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                       # (prompt_len,) int32
    max_new_tokens: Optional[int] = None
    # -- filled by the engine -------------------------------------------------
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = QUEUED
    deadline: float = float("inf")           # absolute wall-clock bound
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL


# prompt-length buckets for the sequential (packed=False) baseline path:
# one prefill compile per bucket, not per length
_MIN_BUCKET = 8


def _bucket_len(plen: int, kv_len: int) -> int:
    b = _MIN_BUCKET
    while b < plen:
        b *= 2
    return min(b, kv_len)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: Optional[EngineConfig] = None,
                 *, mesh=None):
        # NOTE: default built per-instance — a dataclass default argument
        # would be one shared mutable EngineConfig across all engines.
        self.cfg, self.params = cfg, params
        self.ecfg = ecfg = ecfg if ecfg is not None else EngineConfig()
        if ecfg.weight_bits not in (0, 4, 8):
            raise ValueError(f"weight_bits must be 0, 4 or 8, got {ecfg.weight_bits}")
        if ecfg.kv_bits not in (0, 4, 8):
            raise ValueError(f"kv_bits must be 0, 4 or 8, got {ecfg.kv_bits}")
        if ecfg.weight_bits:
            from repro.quant.core import quantize_params
            self.params = quantize_params(params, ecfg.weight_bits,
                                          group=ecfg.weight_group)
        B, S = ecfg.max_batch, ecfg.kv_len
        self.cache = T.init_cache(cfg, B, S, dtype=jnp.bfloat16,
                                  kv_bits=ecfg.kv_bits)
        self.slot_req: list[Optional[Request]] = [None] * B
        # indexed FIFO admission queue: popleft is O(1) however deep the
        # backlog (the old list.pop(0) rescan was O(n) per admission)
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self.failed: list[Request] = []      # terminal failures (deadline /
        #                                      anomaly / max_iters)
        self.rejected: list[Request] = []    # shed at submit (retriable)
        self._slot_anomalies = [0] * B       # consecutive non-finite-logit
        #                                      steps per slot (quarantine)
        self._uid = 0

        # host-transfer / prefill accounting (benchmarks/perf_serving.py)
        self.host_transfers = 0
        self.host_bytes = 0
        self.decode_steps = 0
        self.prefill_tokens = 0           # prompt tokens pushed through prefill
        self.prefill_time = 0.0           # host wall time spent in admission
        self.prefill_calls = 0
        self.max_stall_tokens = 0         # max prefill tokens between decodes
        self._stall_tokens = 0
        # crash-safety accounting (repro.serving.checkpoint)
        self.checkpoints_written = 0      # snapshots committed for this engine
        self.restores = 0                 # times this engine state was revived
        self.replayed_requests = 0        # journal-tail requests resubmitted
        # per-decode-iteration active-slot histogram {n_active: count} — the
        # measured slot-pool utilisation the Plane-B co-simulation batches
        # its decode steps with (repro.core.cosim.mix_from_stats)
        self.active_slot_hist: collections.Counter = collections.Counter()

        # packed-stream / chunk budget (also the padding quantum)
        self._chunk = min(ecfg.prefill_chunk or min(128, S), S)

        # pow2-bucketing (sequential baseline) is exact only when cache
        # index == token position for every self-attention cache.  The
        # packed path instead relies on length-exact prefill state for
        # every layer kind, so it never needs this distinction.
        self._bucketed = all(k in ("global", "cross") for k in cfg.layer_kinds)

        # multi-prompt packing / chunked continuation need (a) attention-only
        # stacks — SSM/recurrent state would integrate across prompt
        # boundaries — and (b) no MoE: packed prompts would compete for
        # expert capacity, breaking packed==sequential equivalence
        self._packable = (all(k in ("global", "local") for k in cfg.layer_kinds)
                          and not cfg.n_experts
                          and not cfg.cross_attn_decoder
                          and not cfg.n_encoder_layers)
        # slot → (next_prompt_pos, budget) for mid-prefill long prompts
        self._prefilling: dict[int, tuple[int, int]] = {}

        # optional decode-mode sharding plan for the slot pool
        self._plan = None
        self._prefill_plan = None
        if mesh is not None:
            from repro.parallel.sharding import (
                cache_shardings, serving_decode_plan, serving_prefill_plan)
            self._plan, ctx = serving_decode_plan(cfg, mesh, max_batch=B,
                                                  kv_len=S)
            self._prefill_plan, _ = serving_prefill_plan(
                cfg, mesh, prefill_chunk=self._chunk)
            shardings = cache_shardings(
                jax.eval_shape(lambda: self.cache), ctx)
            self.cache = jax.device_put(self.cache, shardings)

        # -- fused path: device-resident per-slot state ----------------------
        self._state = {
            "tokens": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "budget": jnp.zeros((B,), jnp.int32),
            "live": jnp.zeros((B,), bool),
            "key": jax.random.PRNGKey(ecfg.seed),
        }
        self._jit_step = jax.jit(self._fused_step_fn, donate_argnums=(1, 2))
        self._jit_prefill_insert = jax.jit(self._prefill_insert_fn,
                                           donate_argnums=(1, 2))
        self._jit_packed_prefill = jax.jit(self._packed_prefill_fn,
                                           donate_argnums=(1, 2))
        self._jit_chunk_step = jax.jit(self._chunk_step_fn,
                                       donate_argnums=(1, 2))

        # -- seed-compat path (fused=False) ----------------------------------
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._jit_decode = jax.jit(self._decode_fn)
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    def _now(self) -> float:
        """Engine time (``EngineConfig.clock`` — monotonic seconds)."""
        return self.ecfg.clock()

    # -- device→host choke point ---------------------------------------------
    def _fetch(self, x) -> np.ndarray:
        """The engine's single device→host transfer point (explicit, so
        tests can fence everything else with a d2h transfer guard)."""
        arr = jax.device_get(x)
        arr = np.asarray(arr)
        self.host_transfers += 1
        self.host_bytes += arr.nbytes
        return arr

    # -- jitted cores: fused path ---------------------------------------------
    def _sample_dev(self, logits, key):
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits / self.ecfg.temperature,
                                     axis=-1)
        return nxt.astype(jnp.int32), key

    def _fused_step_fn(self, params, cache, state):
        """decode → sample → bookkeeping, all on device.  Runs
        ``decode_chunk`` iterations (lax.scan for >1) and returns the new
        (cache, state) plus a packed (K, 3, B) int32 of (next_token | -1,
        done, anomaly) — the only array the host reads back per step.

        A slot whose logits come back non-finite is *frozen*: no token
        committed, pos/budget untouched, still live — the identical step
        re-runs next iteration (the KV write at the same pos is
        idempotent), so a transient fault costs one retry and a persistent
        one is quarantined by the host without touching the other slots
        (decode is batch-parallel, no cross-slot mixing).  With finite
        logits ``ok == live`` and every value below reduces to the
        anomaly-free step bit-identically."""
        def one(carry, _):
            cache, state = carry
            live = state["live"]
            # dead / mid-prefill slots write at pos -1 → dropped, so a
            # half-prefilled row is never corrupted by the decode sweep
            pos_w = jnp.where(live, state["pos"], -1)
            logits, cache = T.decode_step(params, self.cfg, cache,
                                          state["tokens"], pos_w,
                                          impl=self.ecfg.impl)
            nxt, key = self._sample_dev(logits, state["key"])
            bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
            ok = live & ~bad
            pos_new = jnp.where(ok, state["pos"] + 1, state["pos"])
            budget_new = jnp.where(ok, state["budget"] - 1, state["budget"])
            done = (budget_new <= 0) | (pos_new >= self.ecfg.kv_len)
            if self.ecfg.eos_token >= 0:
                done = done | (nxt == self.ecfg.eos_token)
            done = ok & done
            packed = jnp.stack([jnp.where(ok, nxt, -1),
                                done.astype(jnp.int32),
                                (live & bad).astype(jnp.int32)])
            state = {
                "tokens": jnp.where(ok, nxt, state["tokens"]),
                "pos": pos_new,
                "budget": budget_new,
                "live": live & ~done,
                "key": key,
            }
            return (cache, state), packed

        with activate_plan(self._plan):
            chunk = max(1, self.ecfg.decode_chunk)
            if chunk == 1:
                (cache, state), packed = one((cache, state), None)
                packed = packed[None]
            else:
                (cache, state), packed = jax.lax.scan(
                    one, (cache, state), None, length=chunk)
        return cache, state, packed

    def _prefill_insert_fn(self, params, cache, state, tokens, slot, length,
                           budget):
        """prompt forward pass → first-token sample → slot insert → state
        update, one jitted cache-donated call per admission (sequential
        baseline + non-packable architectures)."""
        with activate_plan(self._plan):
            logits, pcache = T.prefill(params, self.cfg, {"tokens": tokens},
                                       impl=self.ecfg.impl,
                                       kv_cap=self.ecfg.kv_len, length=length,
                                       kv_bits=self.ecfg.kv_bits)
            nxt, key = self._sample_dev(logits, state["key"])
            tok = nxt[0]
            cache = self._insert_fn(cache, pcache, slot, length)
            state = {
                "tokens": state["tokens"].at[slot].set(tok),
                "pos": state["pos"].at[slot].set(length),
                "budget": state["budget"].at[slot].set(budget - 1),
                "live": state["live"].at[slot].set(budget > 1),
                "key": key,
            }
        return cache, state, tok

    def _insert_fn(self, cache, pcache, slot, length):
        """Insert a batch-1 prefill cache into slot ``slot`` of the pool
        with one ``dynamic_update_slice`` per leaf (batch axis is axis 1 of
        every stacked leaf).  ``pos`` entries at cache indices >= ``length``
        are invalidated so right-padding never leaves attendable entries
        (exact-length prefill makes it a no-op; ring caches only hold
        positions < length)."""
        def ins(path, pool, one):
            one = one.astype(pool.dtype)
            if str(getattr(path[-1], "key", "")) == "pos":
                idx = jnp.arange(one.shape[-1], dtype=jnp.int32)
                one = jnp.where(idx[None, None, :] < length, one, -1)
            start = (0, slot) + (0,) * (one.ndim - 2)
            return jax.lax.dynamic_update_slice(pool, one, start)

        return jax.tree_util.tree_map_with_path(ins, cache, pcache)

    def _packed_prefill_fn(self, params, cache, state, tokens, positions,
                           seg, gather_idx, seg_off, seg_len, final, budget,
                           active):
        """One ragged prefill for every admitted segment: packed forward
        pass (segment-masked attention) → per-segment first-token sample →
        one multi-slot scatter insert → state update.  Segment id == target
        slot index; ``active`` masks unused slots, ``final`` the segments
        whose prompt completed in this stream (non-final = first chunk of a
        long prompt, which only inserts KV)."""
        with activate_plan(self._prefill_plan):
            logits, pcache = T.prefill_packed(
                params, self.cfg, tokens, positions, seg, gather_idx,
                impl=self.ecfg.impl, kv_bits=self.ecfg.kv_bits)
        with activate_plan(self._plan):
            nxt, key = self._sample_dev(logits, state["key"])
            cache = self._packed_insert(cache, pcache["stack"], seg,
                                        positions, seg_len, active)
            fin = active & final
            state = {
                "tokens": jnp.where(fin, nxt, state["tokens"]),
                "pos": jnp.where(fin, seg_len, state["pos"]),
                "budget": jnp.where(fin, budget - 1, state["budget"]),
                "live": jnp.where(fin, budget > 1, state["live"]),
                "key": key,
            }
        return cache, state, jnp.where(fin, nxt, -1)

    def _packed_insert(self, cache, pstack, seg, positions, seg_len, active):
        """Scatter each packed segment into its KV slot — one scatter per
        cache leaf for the whole admission burst (replaces the per-request
        ``dynamic_update_slice`` loop).  Validity is governed entirely by
        the ``pos`` leaves, so those rows are rebuilt per slot (ring slot
        ``s`` of a cap-``c`` cache holds position ``p ≡ s (mod c)``,
        ``p ∈ [len-c, len)`` — identity layout for global caches), while
        k/v/latent leaves scatter the C packed tokens straight to their
        (slot, ring index) targets — O(C) work, independent of pool size."""
        B = self.ecfg.max_batch
        tgt = jnp.where(active, jnp.arange(B), B)       # B = dropped
        seg1 = seg[0]                                    # (C,) slot id, -1 pad
        pos1 = positions[0]                              # (C,) within-seg pos

        from repro.models.attention import ring_positions

        def ins(path, pool, packed):
            cap = pool.shape[2]
            if str(getattr(path[-1], "key", "")) == "pos":
                p = ring_positions(seg_len[:, None], cap)   # (B, cap)
                valid = (p >= 0) & active[:, None]
                rows = jnp.broadcast_to(
                    jnp.where(valid, p, -1)[None], (pool.shape[0], B, cap))
                return pool.at[:, tgt].set(rows, mode="drop")
            # only the last `cap` tokens of a segment survive its ring —
            # dropping the rest keeps scatter targets unique
            keep = (seg1 >= 0) & (pos1 >= jnp.take(seg_len, jnp.clip(seg1, 0),
                                                   mode="clip") - cap)
            row = jnp.where(keep, seg1, B)
            ring = jnp.where(keep, pos1 % cap, cap)
            return pool.at[:, row, ring].set(
                packed[:, 0].astype(pool.dtype), mode="drop")

        new_stack = [jax.tree_util.tree_map_with_path(ins, pool, packed)
                     for pool, packed in zip(cache["stack"], pstack)]
        return {"stack": new_stack}

    def _chunk_step_fn(self, params, cache, state, tokens, pos, take_idx,
                       final, budget):
        """One chunked-prefill continuation over the pool: write each
        prefilling row's next chunk into its cache at explicit positions,
        attend to the whole cache, and activate rows whose prompt completed
        (sample their first token)."""
        with activate_plan(self._plan):
            logits, cache = T.chunk_prefill_step(
                params, self.cfg, cache, tokens, pos, take_idx,
                impl=self.ecfg.impl)
            nxt, key = self._sample_dev(logits, state["key"])
            pos_end = jnp.max(jnp.where(pos >= 0, pos + 1, 0), axis=1)
            state = {
                "tokens": jnp.where(final, nxt, state["tokens"]),
                "pos": jnp.where(final, pos_end, state["pos"]),
                "budget": jnp.where(final, budget - 1, state["budget"]),
                "live": jnp.where(final, budget > 1, state["live"]),
                "key": key,
            }
        return cache, state, jnp.where(final, nxt, -1)

    # -- jitted cores: seed-compat path ---------------------------------------
    def _decode_fn(self, params, cache, tokens, pos):
        logits, cache = T.decode_step(params, self.cfg, cache, tokens, pos,
                                      impl=self.ecfg.impl)
        return logits, cache

    def _prefill_fn(self, params, tokens, length):
        # single-request prefill padded to a bucketed length (static shape)
        logits, cache = T.prefill(params, self.cfg, {"tokens": tokens},
                                  impl=self.ecfg.impl, kv_cap=self.ecfg.kv_len,
                                  length=length, kv_bits=self.ecfg.kv_bits)
        return logits, cache

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> Request:
        """Validate and enqueue one request.

        Malformed inputs (empty / over-long prompts, non-integer dtype,
        wrong ndim, negative budget) raise ``ValueError`` here — at submit
        time, not deep inside a jitted step.  When the bounded queue
        (``EngineConfig.max_queue``) is full the request is shed: returned
        with the retriable ``REJECTED`` status instead of enqueued."""
        arr = np.asarray(prompt)
        if arr.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got ndim={arr.ndim}")
        if arr.size == 0:
            raise ValueError("prompt must hold at least one token")
        if arr.dtype.kind not in "iu":
            raise ValueError(
                f"prompt must be integer token ids, got dtype={arr.dtype}")
        if arr.size + 1 >= self.ecfg.kv_len:
            raise ValueError(
                f"prompt ({arr.size}) ≥ kv_len ({self.ecfg.kv_len}): no room "
                f"for even one generated token in the KV budget")
        if max_new_tokens is not None and max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens must be >= 0, got {max_new_tokens}")
        now = self._now()
        req = Request(uid=self._uid, prompt=arr.astype(np.int32),
                      max_new_tokens=max_new_tokens, t_enqueue=now)
        if self.ecfg.deadline_ms > 0:
            req.deadline = now + self.ecfg.deadline_ms / 1e3
        self._uid += 1
        if self.ecfg.max_queue > 0 and len(self.queue) >= self.ecfg.max_queue:
            req.status = REJECTED
            req.t_done = now
            self.rejected.append(req)
            return req
        self.queue.append(req)
        return req

    def step(self) -> int:
        """One engine iteration: deadline eviction + admission (packed
        prefill) + chunked prefill continuation + one decode step over the
        slot pool.  Returns the number of occupied slots."""
        if self.ecfg.deadline_ms > 0:
            self._evict_expired()
        if self.ecfg.fused:
            return self._step_fused()
        return self._step_host()

    # -- failure plumbing ------------------------------------------------------
    def _fail(self, req: Request, status: str, now: Optional[float] = None):
        """Move a request to a terminal failure state (never ``finished``)."""
        req.status = status
        req.t_done = now if now is not None else self._now()
        self.failed.append(req)

    def _kill_slot(self, i: int):
        """Free slot ``i`` and silence its device row so the decode sweep
        never advances a dead request again."""
        self.slot_req[i] = None
        self._prefilling.pop(i, None)
        self._slot_anomalies[i] = 0
        if self.ecfg.fused:
            self._state["live"] = self._state["live"].at[i].set(False)
        elif hasattr(self, "_slot_pos"):
            self._slot_budget[i] = 0

    def _evict_expired(self):
        """Fail every queued or in-flight request past its deadline —
        expired work is dropped before it spends another admission or
        decode step (the slot frees for a request that can still make it)."""
        now = self._now()
        if self.queue:
            kept = collections.deque()
            for req in self.queue:
                if now > req.deadline:
                    self._fail(req, FAILED_DEADLINE, now)
                else:
                    kept.append(req)
            self.queue = kept
        for i, req in enumerate(self.slot_req):
            if req is not None and now > req.deadline:
                self._fail(req, FAILED_DEADLINE, now)
                self._kill_slot(i)

    def _step_fused(self) -> int:
        t0 = time.perf_counter()
        if self.ecfg.packed:
            self._admit_packed()
        else:
            self._admit_fused()
        self.prefill_time += time.perf_counter() - t0
        occupied = sum(r is not None for r in self.slot_req)
        if occupied == len(self._prefilling):
            # no live slot: nothing to decode (and nothing being stalled —
            # mid-prefill-only iterations just advance their chunks)
            self._stall_tokens = 0
            return occupied
        self.cache, self._state, packed = self._jit_step(
            self.params, self.cache, self._state)
        arr = self._fetch(packed)                 # ONE d2h transfer
        self.decode_steps += arr.shape[0]
        self.max_stall_tokens = max(self.max_stall_tokens, self._stall_tokens)
        self._stall_tokens = 0
        now = self._now()
        for it in range(arr.shape[0]):            # decode_chunk iterations
            # zero-active iterations (slots all finished mid-chunk) are real
            # device work — recording them keeps Σhist == decode_steps and
            # lets the occupancy mean discount the dead tail of a chunk
            self.active_slot_hist[int((arr[it, 0] >= 0).sum())] += 1
            for i, req in enumerate(self.slot_req):
                if req is None or i in self._prefilling:
                    continue
                if arr[it, 2, i]:                 # non-finite logits: the
                    # device froze the slot (no token, no pos advance) and
                    # will retry the identical step; quarantine after the
                    # configured retries — only this request fails, the
                    # rest of the batch keeps decoding
                    self._slot_anomalies[i] += 1
                    if self._slot_anomalies[i] > self.ecfg.anomaly_retries:
                        self._fail(req, FAILED_ANOMALY, now)
                        self._kill_slot(i)
                    continue
                if arr[it, 0, i] < 0:
                    continue
                self._slot_anomalies[i] = 0       # clean step: retry budget
                #                                   resets (transient fault)
                tok = int(arr[it, 0, i])
                if not req.output:
                    req.t_first_token = now
                req.output.append(tok)
                if arr[it, 1, i]:
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    self.slot_req[i] = None  # slot freed → continuous batching
        return sum(r is not None for r in self.slot_req)

    def _step_host(self) -> int:
        """Original per-token host round-trip step (measurement baseline)."""
        t0 = time.perf_counter()
        self._admit_host()
        self.prefill_time += time.perf_counter() - t0
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return 0
        self.active_slot_hist[len(live)] += 1
        tokens = jnp.asarray(self._last_token)
        pos = jnp.asarray(self._slot_pos)
        logits, self.cache = self._jit_decode(self.params, self.cache,
                                              tokens, pos)
        self.decode_steps += 1
        self.max_stall_tokens = max(self.max_stall_tokens, self._stall_tokens)
        self._stall_tokens = 0
        nxt = self._sample(logits)
        now = self._now()
        for i in live:
            req = self.slot_req[i]
            tok = int(nxt[i])
            if not req.output:
                req.t_first_token = now
            req.output.append(tok)
            self._last_token[i] = tok
            self._slot_pos[i] += 1
            self._slot_budget[i] -= 1
            hit_eos = (self.ecfg.eos_token >= 0 and tok == self.ecfg.eos_token)
            if self._slot_budget[i] <= 0 or hit_eos or \
                    self._slot_pos[i] >= self.ecfg.kv_len:
                req.done = True
                req.status = DONE
                req.t_done = now
                self.finished.append(req)
                self.slot_req[i] = None      # slot freed → continuous batching
        return sum(r is not None for r in self.slot_req)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        """Step until every request reaches a terminal state.

        Exhausting ``max_iters`` is an explicit failure, never a silent
        partial drain: every request still queued or in a slot is marked
        ``FAILED_MAX_ITERS`` (terminal, listed in ``self.failed``) and
        ``EngineStallError`` is raised."""
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)):
            self.step()
            it += 1
            if it > max_iters:
                now = self._now()
                stranded = list(self.queue) + [r for r in self.slot_req
                                               if r is not None]
                for req in self.queue:
                    self._fail(req, FAILED_MAX_ITERS, now)
                self.queue.clear()
                for i, req in enumerate(self.slot_req):
                    if req is not None:
                        self._fail(req, FAILED_MAX_ITERS, now)
                        self._kill_slot(i)
                raise EngineStallError(
                    f"engine did not drain in {max_iters} iterations; "
                    f"{len(stranded)} request(s) marked "
                    f"{FAILED_MAX_ITERS}")
        return self.finished

    # -- admission: packed ragged prefill + chunked continuation ---------------
    def _pop_admissible(self) -> Optional[tuple]:
        """Pop the next admissible queued request (FIFO).  Requests asking
        for 0 tokens finish immediately; over-long prompts raise."""
        while self.queue:
            req = self.queue.popleft()
            # a request may ask for fewer tokens than the engine default —
            # including 0 (`or` would silently swap in the default)
            budget = req.max_new_tokens if req.max_new_tokens is not None \
                else self.ecfg.max_new_tokens
            if budget <= 0:
                req.done = True
                req.status = DONE
                req.t_first_token = req.t_done = self._now()
                self.finished.append(req)
                continue
            plen = len(req.prompt)
            if plen + 1 >= self.ecfg.kv_len:
                raise ValueError(f"prompt ({plen}) ≥ kv_len ({self.ecfg.kv_len})")
            return req, plen, budget
        return None

    def _pad_len(self, plen: int) -> int:
        """Smallest chunk multiple >= plen (capped at kv_len) — the static
        shape set for per-request prefill."""
        C = self._chunk
        return min(-(-max(plen, 1) // C) * C, self.ecfg.kv_len)

    def _admit_packed(self):
        B, C = self.ecfg.max_batch, self._chunk
        if self._prefilling:
            self._continue_chunks()
        free = [i for i in range(B) if self.slot_req[i] is None]
        if not free or not self.queue:
            return
        if not self._packable:
            self._admit_padded(free)
            return

        segs = []                      # (req, slot, off, take, final, budget)
        used = 0
        try:
            while free and used < C:
                nxt = self._pop_admissible()
                if nxt is None:
                    break
                req, plen, budget = nxt
                if plen > C - used and used > 0:
                    # whole prompt doesn't fit the remaining stream: don't
                    # fragment it — a tail-sized first chunk would buy
                    # little and cost an extra continuation call; re-queue
                    # at the head (FIFO preserved) and admit next iteration
                    self.queue.appendleft(req)
                    break
                take = min(plen, C - used)
                slot = free.pop(0)
                segs.append((req, slot, used, take, take == plen, budget))
                used += take
        except ValueError:
            # an over-long prompt mid-burst must not strand the requests
            # already popped into this stream — put them back (FIFO) first
            for req, *_ in reversed(segs):
                self.queue.appendleft(req)
            raise
        if not segs:
            return

        toks = np.zeros((1, C), np.int32)
        seg = np.full((1, C), -1, np.int32)
        pos = np.zeros((1, C), np.int32)
        gather = np.zeros((B,), np.int32)
        off_v = np.zeros((B,), np.int32)
        len_v = np.zeros((B,), np.int32)
        fin_v = np.zeros((B,), bool)
        bud_v = np.ones((B,), np.int32)
        act_v = np.zeros((B,), bool)
        for req, slot, off, take, final, budget in segs:
            toks[0, off:off + take] = req.prompt[:take]
            seg[0, off:off + take] = slot
            pos[0, off:off + take] = np.arange(take)
            gather[slot] = off + take - 1
            off_v[slot], len_v[slot] = off, take
            fin_v[slot], bud_v[slot], act_v[slot] = final, budget, True

        self.cache, self._state, first = self._jit_packed_prefill(
            self.params, self.cache, self._state, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(seg), jnp.asarray(gather),
            jnp.asarray(off_v), jnp.asarray(len_v), jnp.asarray(fin_v),
            jnp.asarray(bud_v), jnp.asarray(act_v))
        arr = self._fetch(first)                  # one d2h per admission burst
        self.prefill_tokens += used
        self.prefill_calls += 1
        self._stall_tokens += used
        now = self._now()
        for req, slot, off, take, final, budget in segs:
            if final:
                tok = int(arr[slot])
                req.output = [tok]
                req.t_first_token = now
                if budget == 1:     # the prefill sample was the whole budget
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    continue
                req.status = ACTIVE
                self.slot_req[slot] = req
            else:                   # long prompt: first chunk only
                req.status = ACTIVE
                self.slot_req[slot] = req
                self._prefilling[slot] = (take, budget)

    def _continue_chunks(self):
        """Advance every mid-prefill slot by one <= C-token chunk (one
        batched jitted call), activating rows whose prompt completed."""
        B, C = self.ecfg.max_batch, self._chunk
        toks = np.zeros((B, C), np.int32)
        pos = np.full((B, C), -1, np.int32)
        take_idx = np.zeros((B,), np.int32)
        fin_v = np.zeros((B,), bool)
        bud_v = np.ones((B,), np.int32)
        plan = []                                  # (slot, start, c, budget)
        for slot, (start, budget) in self._prefilling.items():
            req = self.slot_req[slot]
            plen = len(req.prompt)
            c = min(plen - start, C)
            toks[slot, :c] = req.prompt[start:start + c]
            pos[slot, :c] = start + np.arange(c)
            take_idx[slot] = c - 1
            fin_v[slot] = start + c == plen
            bud_v[slot] = budget
            plan.append((slot, start, c, budget))

        self.cache, self._state, first = self._jit_chunk_step(
            self.params, self.cache, self._state, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(take_idx), jnp.asarray(fin_v),
            jnp.asarray(bud_v))
        arr = self._fetch(first)
        total = sum(c for _, _, c, _ in plan)
        self.prefill_tokens += total
        self.prefill_calls += 1
        self._stall_tokens += C                    # one batched chunk call
        now = self._now()
        for slot, start, c, budget in plan:
            req = self.slot_req[slot]
            if start + c == len(req.prompt):       # prompt complete
                del self._prefilling[slot]
                tok = int(arr[slot])
                req.output = [tok]
                req.t_first_token = now
                if budget == 1:
                    req.done = True
                    req.status = DONE
                    req.t_done = now
                    self.finished.append(req)
                    self.slot_req[slot] = None
            else:
                self._prefilling[slot] = (start + c, budget)

    def _admit_one(self, req, slot: int, plen: int, budget: int, pad: int):
        """One right-padded batch-1 prefill+insert call and its bookkeeping
        (shared by the chunk-padded and pow2-bucketed sequential paths)."""
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        self.cache, self._state, first = self._jit_prefill_insert(
            self.params, self.cache, self._state, jnp.asarray(toks),
            jnp.int32(slot), jnp.int32(plen), jnp.int32(budget))
        tok = int(self._fetch(first))
        self.prefill_tokens += plen
        self.prefill_calls += 1
        self._stall_tokens += pad
        req.output = [tok]
        req.t_first_token = self._now()
        if budget == 1:             # the prefill sample was the whole budget
            req.done = True
            req.status = DONE
            req.t_done = req.t_first_token
            self.finished.append(req)
        else:
            req.status = ACTIVE
            self.slot_req[slot] = req

    def _admit_padded(self, free):
        """Per-request admission for non-packable architectures: prompts
        right-padded to a chunk multiple with length-exact prefill state —
        static shapes, no compile-per-distinct-length."""
        while free and self.queue:
            nxt = self._pop_admissible()
            if nxt is None:
                break
            req, plen, budget = nxt
            self._admit_one(req, free.pop(0), plen, budget,
                            self._pad_len(plen))

    # -- admission: sequential baselines ---------------------------------------
    def _next_request(self, slot: int) -> Optional[tuple]:
        """Pop the next admissible queued request and its padded prompt, or
        None (sequential baseline paths)."""
        if self.slot_req[slot] is not None:
            return None
        nxt = self._pop_admissible()
        if nxt is None:
            return None
        req, plen, budget = nxt
        pad = _bucket_len(plen, self.ecfg.kv_len) if self._bucketed else plen
        toks = np.zeros((1, pad), np.int32)
        toks[0, :plen] = req.prompt
        return req, toks, plen, budget

    def _admit_fused(self):
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            self._admit_one(req, slot, plen, budget, toks.shape[1])

    def _admit_host(self):
        if not hasattr(self, "_slot_pos"):
            B = self.ecfg.max_batch
            self._slot_pos = np.zeros(B, np.int32)
            self._slot_budget = np.zeros(B, np.int32)
            self._last_token = np.zeros(B, np.int32)
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            logits, pcache = self._jit_prefill(
                self.params, jnp.asarray(toks), jnp.int32(plen))
            self.cache = self._jit_insert(self.cache, pcache, jnp.int32(slot),
                                          jnp.int32(plen))
            first = self._sample(logits)
            self.prefill_tokens += plen
            self.prefill_calls += 1
            self._stall_tokens += toks.shape[1]
            req.output = [int(first[0])]
            req.t_first_token = self._now()
            if budget == 1:         # the prefill sample was the whole budget
                req.done = True
                req.status = DONE
                req.t_done = req.t_first_token
                self.finished.append(req)
                continue
            req.status = ACTIVE
            self.slot_req[slot] = req
            self._slot_pos[slot] = plen
            self._slot_budget[slot] = budget - 1
            self._last_token[slot] = int(first[0])

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.ecfg.temperature <= 0.0:
            return self._fetch(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return self._fetch(jax.random.categorical(
            sub, logits / self.ecfg.temperature, axis=-1))

    # -- crash safety ---------------------------------------------------------
    @classmethod
    def restore(cls, cfg: ModelConfig, params, ckpt_dir: str, *,
                ecfg: Optional[EngineConfig] = None, mesh=None,
                replay: bool = True) -> "ServingEngine":
        """Revive an engine from its newest intact snapshot in
        ``ckpt_dir`` (written by ``repro.serving.checkpoint``), resuming
        mid-decode bit-identically and replaying journal-tail requests
        admitted after the snapshot.  See
        :func:`repro.serving.checkpoint.restore_engine`."""
        from repro.serving.checkpoint import restore_engine
        return restore_engine(cfg, params, ckpt_dir, ecfg=ecfg, mesh=mesh,
                              replay=replay)

    # -- stats ---------------------------------------------------------------
    def _failure_stats(self) -> dict:
        by_status: collections.Counter = collections.Counter(
            r.status for r in self.failed)
        return {
            "failed": len(self.failed),
            "rejected": len(self.rejected),
            "failed_deadline": by_status.get(FAILED_DEADLINE, 0),
            "failed_anomaly": by_status.get(FAILED_ANOMALY, 0),
            "failed_max_iters": by_status.get(FAILED_MAX_ITERS, 0),
            # crash-safety counters (repro.serving.checkpoint): snapshots
            # committed, revivals of this engine state, journal-tail
            # requests resubmitted during restore
            "checkpoints_written": self.checkpoints_written,
            "restores": self.restores,
            "replayed_requests": self.replayed_requests,
        }

    def stats(self) -> dict:
        done = self.finished
        if not done:
            return {"finished": 0, **self._failure_stats()}
        lat = [r.t_done - r.t_enqueue for r in done]
        ttft = [r.t_first_token - r.t_enqueue for r in done]
        toks = sum(len(r.output) for r in done)
        span = max(r.t_done for r in done) - min(r.t_enqueue for r in done)
        return {
            "finished": len(done),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "mean_latency_s": float(np.mean(lat)),
            "mean_ttft_s": float(np.mean(ttft)),
            "decode_steps": self.decode_steps,
            "host_transfers": self.host_transfers,
            "host_bytes": self.host_bytes,
            "host_bytes_per_token": self.host_bytes / max(toks, 1),
            "prefill_tokens": self.prefill_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_time_s": self.prefill_time,
            "prefill_tokens_per_s": self.prefill_tokens / max(self.prefill_time, 1e-9),
            "max_stall_tokens": self.max_stall_tokens,
            # per-request episode shape + schedule, consumed by the Plane-B
            # co-simulation bridge (repro.core.cosim.mix_from_stats)
            "prompt_lens": [len(r.prompt) for r in done],
            "gen_lens": [len(r.output) for r in done],
            "prefill_chunk": self._chunk,
            "max_batch": self.ecfg.max_batch,
            # measured serving precision (16 = native fp16-class), consumed
            # by the Plane-B bridge so quantisation propagates into the
            # traffic model (repro.core.cosim.mix_from_stats)
            "weight_bits": self.ecfg.weight_bits or 16,
            "kv_bits": self.ecfg.kv_bits or 16,
            # {n_active_slots: decode iterations at that occupancy} — the
            # measured continuous-batching utilisation of the slot pool
            "active_slots_hist": dict(sorted(self.active_slot_hist.items())),
            **self._failure_stats(),
        }
