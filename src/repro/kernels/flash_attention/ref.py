"""Pure-jnp oracle for flash attention (and the CPU / dry-run exec path).

Supports GQA/MQA, causal + sliding-window masks, gemma-style logit softcap,
explicit position vectors (ring-buffer KV caches), packed-segment masking
(ragged prefill: a query never attends across a prompt boundary), and
q-chunking so the O(Sq x Skv) score matrix never materialises for long
sequences — the same "never leave fast memory" property the paper gets from
fusing score+softmax on the SM chiplets (§3.2 step 4), expressed at the XLA
level.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.common import NEG_INF


def _mask(q_pos, kv_pos, kv_valid, causal, window, q_seg=None, kv_seg=None):
    """(B, Sq, Skv) bool — True = attend."""
    m = jnp.ones((q_pos.shape[0], q_pos.shape[1], kv_pos.shape[1]), bool)
    if causal:
        m &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        m &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    if q_seg is not None:
        # pad rows (id -1) are fully masked -> exact zero outputs
        m &= (q_seg[:, :, None] == kv_seg[:, None, :]) & \
             (q_seg[:, :, None] >= 0)
    return m


def _attend_block(q, k, v, mask, scale, softcap):
    """q (B,Sq,Hkv,rep,hd) k/v (B,Skv,Hkv,hd) mask (B,Sq,Skv) -> (B,Sq,Hkv,rep,hdv)."""
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (no valid kv) must produce zeros, not NaN
    any_valid = mask.any(axis=-1)[:, None, None, :, None]
    w = jnp.where(any_valid, w, 0.0)
    return jnp.einsum("bhrqk,bkhd->bqhrd", w.astype(v.dtype), v)


def attention_ref(
    q: jax.Array,            # (B, Sq, Hq, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)
    v: jax.Array,            # (B, Skv, Hkv, hdv)
    *,
    q_pos: Optional[jax.Array] = None,    # (B, Sq) int32
    kv_pos: Optional[jax.Array] = None,   # (B, Skv) int32
    kv_valid: Optional[jax.Array] = None,  # (B, Skv) bool
    q_seg: Optional[jax.Array] = None,    # (B, Sq) int32 packed prompt ids
    kv_seg: Optional[jax.Array] = None,   # (B, Skv) int32
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    if (q_seg is None) != (kv_seg is None):
        raise ValueError("q_seg and kv_seg must be passed together")

    qr = q.reshape(B, Sq, Hkv, rep, hd)

    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        nc = Sq // q_chunk
        qc = qr.reshape(B, nc, q_chunk, Hkv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
        pc = q_pos.reshape(B, nc, q_chunk).transpose(1, 0, 2)
        sc = (jnp.zeros((nc, B, q_chunk), jnp.int32) if q_seg is None
              else q_seg.reshape(B, nc, q_chunk).transpose(1, 0, 2))

        def one(args):
            qi, pi, si = args
            m = _mask(pi, kv_pos, kv_valid, causal, window,
                      None if q_seg is None else si, kv_seg)
            return _attend_block(qi, k, v, m, scale, softcap)

        # remat each q-chunk: without this the chunk loop saves every
        # chunk's (bq × Skv) probabilities for backward — the full score
        # matrix resident during each layer's bwd, even under layer-level
        # remat (measured: ~2.2 GiB/layer on llama-vision train_4k)
        out = jax.lax.map(jax.checkpoint(one), (qc, pc, sc))  # (nc, B, qc, ...)
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, v.shape[-1])
        return out

    m = _mask(q_pos, kv_pos, kv_valid, causal, window, q_seg, kv_seg)
    out = _attend_block(qr, k, v, m, scale, softcap)
    return out.reshape(B, Sq, Hq, v.shape[-1])
