"""TPU-adaptation plane (core/hetero): SFC device ordering hop costs,
mapping search, and dry-run result completeness."""
import glob
import json
import os

import numpy as np
import pytest

from conftest import REPO
from repro.core.hetero import (MappingKnobs, compare_device_orders,
                               mapping_search, ring_hop_cost)


def test_boustrophedon_model_rings_nearest_neighbour():
    """Logical model-axis rings walked boustrophedon are nearest-neighbour
    on the torus except the wrap hop — mean ≤ 2 hops."""
    r = ring_hop_cost("boustrophedon", 16, 16, axis="model")
    assert r["mean_hops"] <= 2.0
    assert r["max_hops"] <= 16


def test_sfc_order_beats_morton_for_rings():
    bous = ring_hop_cost("boustrophedon", 16, 16, axis="model")
    mort = ring_hop_cost("morton", 16, 16, axis="model")
    assert bous["total_hops"] <= mort["total_hops"]


def test_compare_device_orders_covers_all_curves():
    rows = compare_device_orders()
    curves = {r["curve"] for r in rows}
    assert {"hilbert", "boustrophedon", "morton", "onion",
            "rowmajor"} <= curves
    for r in rows:
        assert r["mean_hops"] >= 1.0  # a ring step crosses ≥ 1 link


def test_mapping_search_returns_pareto():
    """Greedy knob search returns a mutually non-dominated front and never
    returns a dominated start."""
    def fake_eval(k: MappingKnobs):
        # synthetic objective: seq_shard helps collectives, accum helps
        # memory, remat helps memory but costs compute
        step = 1.0 - 0.2 * k.seq_shard + 0.05 * (k.remat_policy == "dots")
        coll = 1.0 - 0.3 * k.seq_shard + 0.1 * (k.heads_policy == "seq")
        mem = 1.0 / k.accum + (0.5 if k.remat_policy == "none" else 0.2)
        return (step, coll, mem)

    res = mapping_search(fake_eval, budget=20)
    assert res
    from repro.core.moo import dominates
    objs = [r.objectives for r in res]
    for i, a in enumerate(objs):
        for j, b in enumerate(objs):
            if i != j:
                assert not dominates(a, b)


DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")


# 16 GiB/chip capacity limits documented in EXPERIMENTS.md §Dry-run:
# fp32-Adam state for 236B/90B models approaches or exceeds per-chip HBM
# at these pod sizes; the cells compile and are reported with fits=✗.
CAPACITY_LIMITED = {
    ("deepseek-v2-236b", "train_4k", "single"),   # 14.7 GiB state+grads alone
    ("deepseek-v2-236b", "train_4k", "multi"),    # 17.0 GiB live (6 % over)
    ("llama-3.2-vision-90b", "train_4k", "single"),  # 17.3 GiB live (8 % over)
}


@pytest.mark.skipif(not os.path.isdir(DRYRUN_DIR),
                    reason="dry-run results not generated yet")
def test_dryrun_matrix_complete_and_green():
    """Deliverable (e): all 40 cells × 2 meshes present; every cell either
    ok (fits v5e HBM), a documented skip, or a documented capacity limit."""
    from repro.config import ASSIGNED_ARCHS, SHAPES, get_config

    recs = {}
    for f in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r

    missing, bad = [], []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    missing.append((arch, shape, mesh))
                    continue
                if r["status"] == "ok":
                    if not r["memory"]["fits_v5e"] and \
                            (arch, shape, mesh) not in CAPACITY_LIMITED:
                        bad.append((arch, shape, mesh, "does not fit"))
                    rf = r["roofline"]
                    for t in ("compute_s", "memory_s", "collective_s"):
                        assert rf[t] >= 0
                elif r["status"] == "skipped":
                    cfg = get_config(arch)
                    ok, why = cfg.supports(SHAPES[shape])
                    assert not ok, (arch, shape, "skip not justified")
                else:
                    bad.append((arch, shape, mesh, r.get("error", "")[:80]))
    assert not missing, f"missing cells: {missing}"
    assert not bad, f"bad cells: {bad}"
    # the capacity-limited list must not silently grow
    over = {k for k, r in recs.items()
            if r["status"] == "ok" and not r["memory"]["fits_v5e"]}
    assert over <= CAPACITY_LIMITED, over


@pytest.mark.skipif(not os.path.isdir(DRYRUN_DIR),
                    reason="dry-run results not generated yet")
def test_dryrun_multi_pod_shards_pod_axis():
    """Multi-pod cells must use 512 devices and show a cross-pod term."""
    n = 0
    for f in glob.glob(os.path.join(DRYRUN_DIR, "*__multi.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        assert r["n_devices"] == 512, f
        n += 1
    assert n >= 30
