"""Optimized-HLO text analyzer for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
ignoring trip counts — useless for scan-over-layers models.  This module
re-derives per-device costs from the post-SPMD optimized HLO text:

- ``dot`` FLOPs from operand/output shapes (symbol table per computation),
- collective wire-bytes per device (ring-model factors, replica-group size
  parsed from both iota ``[G,S]<=[N]`` and explicit ``{{...}}`` forms),
- while-loop trip counts parsed from the loop-condition comparison constant,
  applied multiplicatively through the call graph (fusion/call/while),
- an HBM-traffic estimate (dot + fusion operand/result bytes).

Everything here is pure text processing — no jax imports — so it is unit
testable against hand-written HLO.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# out type is either a tuple "(s32[], bf16[..]{..}, /*index=5*/ ...)" — which
# may contain '=' inside /*index=N*/ comments but never a ')' before its own
# close — or a single non-space token.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_shape(s: str):
    """'bf16[4,128]{1,0}' -> (bytes_total, dtype, dims). Tuples -> summed."""
    total = 0
    dims_all = []
    dt = None
    for m in _SHAPE_RE.finditer(s):
        dtype, dimstr = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dimstr.split(",") if x] if dimstr else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if dt is None:
            dt = dtype
            dims_all = dims
    return total, dt, dims_all


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_shape: str
    body: str          # text after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list
    symbols: dict      # value name -> out_shape string


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_ops: list = dataclasses.field(default_factory=list)
    n_while: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self):
        return sum(self.collective_bytes.values())


def _split_computations(text: str) -> list[Computation]:
    comps = []
    cur = None
    entry = False
    for line in text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and "{" in line:
            cur = Computation(m.group(2), bool(m.group(1)), [], {})
            comps.append(cur)
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, shape, opcode, rest = om.groups()
            cur.ops.append(Op(name, opcode, shape, rest))
            cur.symbols[name] = shape
    return comps


_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _group_size(body: str, num_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(body)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(body)
    if m:
        return len(m.group(1).split(","))
    if "replica_groups={}" in body:
        return num_devices
    return num_devices


def _trip_count(comp: Computation) -> int:
    """Max integer constant in a while-condition computation (the loop bound
    in canonical `i < N` conditions produced by lax.scan/map)."""
    best = 1
    for op in comp.ops:
        if op.opcode == "constant":
            mm = re.match(r"(\d+)\)", op.body)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(op: Op, symbols: dict) -> float:
    out_bytes, out_dt, out_dims = _parse_shape(op.out_shape)
    operands = _OPERANDS_RE.findall(op.body.split(", lhs_contracting")[0])
    if not operands:
        return 0.0
    lhs_shape = symbols.get(operands[0])
    if lhs_shape is None:
        return 0.0
    _, _, lhs_dims = _parse_shape(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.body)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    return 2.0 * out_elems * contract


def _operand_names(op: Op) -> list[str]:
    head = op.body.split("), ")[0] if "), " in op.body else op.body
    return _OPERANDS_RE.findall(head)


def _operand_bytes(op: Op, symbols: dict) -> float:
    total = 0.0
    for name in _operand_names(op):
        s = symbols.get(name)
        if s:
            total += _parse_shape(s)[0]
    return total


def _param_slice_bytes(comp: Computation) -> dict[int, float]:
    """For a fused computation: parameter index -> HBM bytes actually read.

    A parameter whose only use is a (dynamic-)slice reads just the slice —
    the pattern scan bodies produce when indexing stacked per-layer
    buffers; counting the full buffer per iteration overstates HBM traffic
    by the layer count."""
    param_idx: dict[str, int] = {}
    uses: dict[str, list[Op]] = {}
    for o in comp.ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)\)", o.body)
            if m:
                param_idx[o.name] = int(m.group(1))
        else:
            for nm in _OPERANDS_RE.findall(o.body):
                uses.setdefault(nm, []).append(o)
    out: dict[int, float] = {}
    for pname, idx in param_idx.items():
        use = uses.get(pname, [])
        if use and all(u.opcode in ("dynamic-slice", "slice") for u in use):
            out[idx] = sum(_parse_shape(u.out_shape)[0] for u in use)
    return out


def _fusion_bytes(op: Op, symbols: dict, by_name: dict) -> float:
    """HBM traffic at a fusion boundary: output + per-operand reads, with
    slice-only operands counted at slice size."""
    out_b = _parse_shape(op.out_shape)[0]
    names = _operand_names(op)
    sub = None
    m = _CALL_ATTR_RE.search(op.body)
    if m:
        sub = by_name.get(m.group(1))
    slice_bytes = _param_slice_bytes(sub) if sub is not None else {}
    total = out_b
    for i, nm in enumerate(names):
        s = symbols.get(nm)
        if not s:
            continue
        full = _parse_shape(s)[0]
        total += min(full, slice_bytes.get(i, full))
    return total


def _collective_wire_bytes(op: Op, symbols: dict, num_devices: int) -> float:
    """Per-device bytes crossing links (ring model)."""
    g = _group_size(op.body, num_devices)
    if g <= 1:
        return 0.0
    out_bytes, _, _ = _parse_shape(op.out_shape)
    in_bytes = _operand_bytes(op, symbols)
    kind = op.opcode.replace("-start", "")
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return in_bytes * (g - 1) / g
    if kind == "all-to-all":
        return in_bytes * (g - 1) / g
    if kind == "collective-permute":
        return out_bytes
    return 0.0


def analyze_hlo_text(text: str, num_devices: int = 1) -> HloCost:
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}
    cost = HloCost()

    # while bodies -> trip counts: prefer the compiler's own
    # backend_config known_trip_count; fall back to parsing the condition
    body_trips: dict[str, int] = {}
    for c in comps:
        for op in c.ops:
            if op.opcode == "while":
                tm = _TRIP_CFG_RE.search(op.body)
                bm = None
                for attr in _CALL_ATTR_RE.finditer(op.body):
                    if attr.group(0).startswith("body="):
                        bm = attr
                        break
                bm = bm or _CALL_ATTR_RE.search(op.body)
                if tm:
                    trips = int(tm.group(1))
                else:
                    cm = _COND_ATTR_RE.search(op.body)
                    trips = (_trip_count(by_name[cm.group(1)])
                             if cm and cm.group(1) in by_name else 1)
                if bm:
                    body_trips[bm.group(1)] = trips
                    cost.trip_counts[bm.group(1)] = trips
                cost.n_while += 1

    memo: dict[str, tuple] = {}

    def comp_cost(name: str) -> tuple:
        if name in memo:
            return memo[name]
        c = by_name.get(name)
        if c is None:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        hbm = 0.0
        coll: dict[str, float] = defaultdict(float)
        for op in c.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, c.symbols)
                flops += f
                hbm += _operand_bytes(op, c.symbols) + _parse_shape(op.out_shape)[0]
            elif op.opcode == "fusion":
                hbm += _fusion_bytes(op, c.symbols, by_name)
            elif op.opcode in ("dynamic-slice", "slice"):
                hbm += 2 * _parse_shape(op.out_shape)[0]   # read + write slice
            elif op.opcode == "dynamic-update-slice":
                # reads the update operand, writes the slice region
                names = _operand_names(op)
                upd = (symbols_b := c.symbols).get(names[1]) if len(names) > 1 else None
                hbm += 2 * (_parse_shape(upd)[0] if upd else 0.0)
            elif op.opcode == "custom-call":
                hbm += _operand_bytes(op, c.symbols) + _parse_shape(op.out_shape)[0]
            elif op.opcode == "convolution":
                out_b, _, out_dims = _parse_shape(op.out_shape)
                ops_names = _OPERANDS_RE.findall(op.body.split(",")[0])
                rhs = c.symbols.get(ops_names[1]) if len(ops_names) > 1 else None
                k_elems = 1
                if rhs:
                    _, _, rd = _parse_shape(rhs)
                    for d in rd:
                        k_elems *= d
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                o_feat = out_dims[-1] if out_dims else 1
                flops += 2.0 * out_elems * (k_elems / max(o_feat, 1))
                hbm += _operand_bytes(op, c.symbols) + out_b
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                b = _collective_wire_bytes(op, c.symbols, num_devices)
                coll[base] += b
                cost.collective_ops.append(
                    (c.name, base, op.out_shape.strip(), b))
            # recurse into called computations
            for attr in _CALL_ATTR_RE.finditer(op.body):
                sub = attr.group(1)
                if sub == name or sub not in by_name:
                    continue
                mult = body_trips.get(sub, 1) if op.opcode == "while" else 1
                sf, sh, sc = comp_cost(sub)
                flops += sf * mult
                hbm += sh * mult
                for k, v in sc.items():
                    coll[k] += v * mult
        memo[name] = (flops, hbm, dict(coll))
        return memo[name]

    for c in comps:
        if c.is_entry:
            f, h, col = comp_cost(c.name)
            cost.flops = f
            cost.bytes_hbm = h
            cost.collective_bytes = col
            break
    return cost


_OPERAND_BYTES_RE = re.compile(r"^bytes accessed(\d+)\{\}$")
_UTILIZATION_RE = re.compile(r"^utilization(\d+)\{\}$")


def normalize_cost_analysis(ca) -> dict:
    """Normalise ``Compiled.cost_analysis()`` into a structured dict.

    XLA's estimate arrives as a flat property map whose shape varies by
    jax version and backend: ``None`` when the backend doesn't implement
    it, a one-element list on older jax, and per-operand keys spelled
    ``"bytes accessed0{}"`` / ``"bytes accessedout{}"``.  Returns::

        {"flops": float, "bytes": float, "transcendentals": float,
         "operand_bytes": {0: ..., 1: ...}, "output_bytes": float,
         "utilization": {0: ..., 1: ...}}

    Missing keys become 0.0 / empty maps — an empty module (or a backend
    with no cost model) yields the all-zero record, never a KeyError.
    jax-free on purpose: the parsing is testable without a compile.
    """
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    if not isinstance(ca, dict):
        ca = {}
    operand_bytes: dict[int, float] = {}
    utilization: dict[int, float] = {}
    for key, val in ca.items():
        m = _OPERAND_BYTES_RE.match(key)
        if m:
            operand_bytes[int(m.group(1))] = float(val)
            continue
        m = _UTILIZATION_RE.match(key)
        if m:
            utilization[int(m.group(1))] = float(val)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "operand_bytes": operand_bytes,
        "output_bytes": float(ca.get("bytes accessedout{}", 0.0)),
        "utilization": utilization,
    }


_CONVERT_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*f32(\[[\d,]*\])(?:\{[^}]*\})?\s+convert\(%?([\w.\-]+)\)")


def cpu_bf16_promotion_bytes(text: str, min_bytes: int = 1 << 26) -> float:
    """XLA:CPU float-normalization promotes loop-carried bf16 buffers to
    f32 work copies (bf16 compute is unsupported on CPU).  On TPU these
    buffers stay bf16 and the extra f32 copy does not exist.

    Two modes (caller picks by step kind):
    - ``strict=True`` (training): only converts in entry / while-body
      computations — backward-pass f32 gradient upcasts are REAL on TPU
      too, so fusion-internal converts must not be subtracted;
    - ``strict=False`` (prefill/decode): forward-only steps hold no
      legitimate large f32 state, so every large f32-convert-of-bf16
      (deduped by source) is a CPU promotion artifact.  Callers floor the
      corrected liveness at args+outputs.
    """
    return _promotion_bytes(text, min_bytes, strict=True)


def cpu_bf16_promotion_bytes_serving(text: str,
                                     min_bytes: int = 1 << 26) -> float:
    return _promotion_bytes(text, min_bytes, strict=False)


def _promotion_bytes(text: str, min_bytes: int, strict: bool) -> float:
    comps = _split_computations(text)
    loopish = {c.name for c in comps if c.is_entry}
    for c in comps:
        for op in c.ops:
            if op.opcode == "while":
                for m in _CALL_ATTR_RE.finditer(op.body):
                    loopish.add(m.group(1))
    seen_src: set = set()
    excess = 0.0
    for comp in comps:
        if strict and comp.name not in loopish:
            continue
        for op in comp.ops:
            if op.opcode != "convert":
                continue
            out_b, dt, _ = _parse_shape(op.out_shape)
            if dt != "f32" or out_b < min_bytes:
                continue
            srcs = _OPERANDS_RE.findall(op.body)
            if not srcs or srcs[0] in seen_src:
                continue
            src_shape = comp.symbols.get(srcs[0], "")
            if src_shape.startswith("bf16"):
                seen_src.add(srcs[0])
                excess += out_b
    return excess


def largest_tensors(text: str, top: int = 25) -> list[tuple[float, str, str]]:
    """(bytes, computation, op-line-head) for the biggest tensors in the
    module — quick memory-offender triage for the dry-run fix loop."""
    out = []
    for c in _split_computations(text):
        for op in c.ops:
            b, dt, dims = _parse_shape(op.out_shape)
            if b > 0:
                out.append((b, c.name, f"{op.name} = {op.out_shape} {op.opcode}"))
    out.sort(key=lambda t: -t[0])
    return out[:top]
