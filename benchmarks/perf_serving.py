"""Serving benchmark: decode fast path + packed/chunked prefill admission.

Two workloads, both through the same ``ServingEngine``:

**decode** (steady-state decode throughput, device→host traffic per token):

- ``seed``        — ``fused=False``: the original per-token host round trip
                    (host sampling fetch, Python slot loop, non-donated
                    cache → XLA copies the whole KV pool every token);
- ``fused``       — zero-host-sync jitted step with cache donation, one
                    packed ``(2, B)`` transfer per iteration, ref attention;
- ``fused_flash`` — same, routed through the Pallas decode-attention kernel
                    (interpret mode off-TPU, compiled on TPU).

**prefill** (admission-bound: long prompts, short generations — the
time-to-first-token critical path):

- ``seq``    — ``packed=False``: PR-1 sequential admission, one
               bucket-padded batch-1 prefill+insert call per request;
- ``packed`` — packed ragged prefill (all queued requests in one segmented
               call) + chunked prefill for prompts longer than the chunk.

Reported: prefill tokens/s (prompt tokens ÷ host wall time spent in
admission), mean TTFT over the drain, and the worst prefill-token stall
between consecutive decode steps (bounded by ~2 chunks for ``packed``).

Methodology: one warm-up drain performs every compile, then the reported
numbers are the best of ``repeat`` timed drains of the full serving loop —
measured identically for every path, so comparisons are apples-to-apples
engine throughput.  Results go to ``experiments/BENCH_serving.json``
(schema-checked — ``make bench-smoke``) and are rendered by
``benchmarks/report.py``.

    PYTHONPATH=src python -m benchmarks.perf_serving [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

_DECODE_KEYS = {"fused", "impl", "decode_chunk", "tokens", "decode_steps",
                "tokens_per_s", "step_ms", "host_bytes_per_token"}
_PREFILL_KEYS = {"packed", "impl", "prefill_chunk", "prefill_tokens",
                 "prefill_calls", "prefill_tokens_per_s", "mean_ttft_s",
                 "max_stall_tokens", "tokens_per_s"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_serving.json record shape (CI bit-rot gate)."""
    for key in ("bench", "arch", "backend", "smoke", "results", "prefill",
                "prefill_long", "speedup_fused_vs_seed",
                "speedup_packed_vs_seq_prefill"):
        assert key in rec, f"missing top-level key {key!r}"
    for name in ("seed", "fused", "fused_flash"):
        row = rec["results"][name]
        missing = _DECODE_KEYS - set(row)
        assert not missing, f"decode row {name!r} missing {missing}"
    for section in ("prefill", "prefill_long"):
        for name in ("seq", "packed"):
            row = rec[section][name]
            missing = _PREFILL_KEYS - set(row)
            assert not missing, f"{section} row {name!r} missing {missing}"


def _tokens(eng) -> int:
    live = [r for r in eng.slot_req if r is not None]
    return sum(len(r.output) for r in list(eng.finished) + live)


def run_engine(cfg, params, *, fused: bool, impl: str, max_batch: int,
               kv_len: int, max_new_tokens: int, prompt_len: int,
               requests: int, decode_chunk: int = 1, repeat: int = 3) -> dict:
    import numpy as np
    from repro.serving.engine import EngineConfig, ServingEngine

    from benchmarks.common import drain_best

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new_tokens,
        impl=impl, fused=fused, decode_chunk=decode_chunk))
    rng = np.random.default_rng(0)

    def drain():
        for _ in range(requests):
            eng.submit(rng.integers(0, cfg.vocab_size, size=prompt_len))
        tok0, byte0, step0 = _tokens(eng), eng.host_bytes, eng.decode_steps
        eng.run_until_drained()
        return (_tokens(eng) - tok0, eng.decode_steps - step0,
                eng.host_bytes - byte0)

    # warm-up (all compiles) + best-of-repeat steady-state drains —
    # shared methodology, timed by the calibration plane's micro-timer
    _, (toks, steps, bytes_), dt, _ = drain_best(
        drain, repeat=repeat, score=lambda r, dt: r[0] / dt)
    return {
        "fused": fused,
        "impl": impl,
        "decode_chunk": decode_chunk,
        "tokens": toks,
        "decode_steps": steps,
        "tokens_per_s": toks / max(dt, 1e-9),
        "step_ms": dt / max(steps, 1) * 1e3,
        "host_bytes_per_token": bytes_ / max(toks, 1),
    }


def run_prefill_workload(cfg, params, *, packed: bool, impl: str,
                         max_batch: int, kv_len: int, max_new_tokens: int,
                         prompt_lens, prefill_chunk: int = 0,
                         repeat: int = 3) -> dict:
    """Prefill-bound drain (long prompts, short generations): one engine,
    repeated timed drains (all compiles in the warm-up), per-drain counter
    deltas — same methodology as the decode workload."""
    import numpy as np
    from repro.serving.engine import EngineConfig, ServingEngine

    from benchmarks.common import drain_best

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new_tokens,
        impl=impl, fused=True, packed=packed, prefill_chunk=prefill_chunk))

    def drain():
        rng = np.random.default_rng(0)
        n0 = len(eng.finished)
        tok0, t0, call0 = eng.prefill_tokens, eng.prefill_time, eng.prefill_calls
        eng.max_stall_tokens = 0
        for plen in prompt_lens:
            eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
        eng.run_until_drained()
        done = eng.finished[n0:]
        return {
            "prefill_tokens": eng.prefill_tokens - tok0,
            "prefill_calls": eng.prefill_calls - call0,
            "prefill_tokens_per_s": (eng.prefill_tokens - tok0)
                                    / max(eng.prefill_time - t0, 1e-9),
            "mean_ttft_s": float(np.mean([r.t_first_token - r.t_enqueue
                                          for r in done])),
            "max_stall_tokens": eng.max_stall_tokens,
            "tokens_per_s": (sum(len(r.output) for r in done)
                             / max(max(r.t_done for r in done)
                                   - min(r.t_enqueue for r in done), 1e-9)),
        }

    # warm-up + best-of-repeat (scored by the engine's own prefill
    # counters — the drain's wall time is not the prefill-bound metric)
    _, best, _, _ = drain_best(
        drain, repeat=repeat, score=lambda r, dt: r["prefill_tokens_per_s"])
    return {
        "packed": packed,
        "impl": impl,
        "prefill_chunk": prefill_chunk,
        **best,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, still writes JSON)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=16,
                    help="device iterations per host sync on the fused path")
    ap.add_argument("--prefill-max-batch", type=int, default=8)
    ap.add_argument("--prefill-kv-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=96)
    ap.add_argument("--prefill-requests", type=int, default=48)
    ap.add_argument("--prefill-prompt-len", type=int, default=12,
                    help="prefill-bound workload prompt length")
    ap.add_argument("--prefill-new-tokens", type=int, default=4,
                    help="short generations: the drain stays prefill-bound")
    ap.add_argument("--prefill-long-len", type=int, default=100,
                    help="long-prompt (chunked) workload prompt length")
    ap.add_argument("--prefill-long-count", type=int, default=8,
                    help="long prompts appended to the mixed workload")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: experiments/BENCH_serving"
                         ".json, or BENCH_serving_smoke.json with --smoke "
                         "so CI never clobbers the recorded full run)")
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS,
            "BENCH_serving_smoke.json" if args.smoke else "BENCH_serving.json")
    if args.smoke:
        args.max_batch, args.kv_len = 2, 64
        args.max_new_tokens, args.prompt_len = 8, 8
        args.requests = 3
        args.prefill_max_batch, args.prefill_kv_len = 2, 64
        args.prefill_chunk = 32
        args.prefill_requests, args.prefill_prompt_len = 6, 8
        args.prefill_new_tokens = 2
        args.prefill_long_len, args.prefill_long_count = 40, 2

    import jax
    import jax.numpy as jnp
    from benchmarks.common import emit
    from repro.config import get_config, reduce_config

    from repro.models import transformer as T

    cfg = reduce_config(get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)

    shape = dict(max_batch=args.max_batch, kv_len=args.kv_len,
                 max_new_tokens=args.max_new_tokens,
                 prompt_len=args.prompt_len, requests=args.requests)
    results = {
        "seed": run_engine(cfg, params, fused=False, impl="ref", **shape),
        "fused": run_engine(cfg, params, fused=True, impl="ref",
                            decode_chunk=args.decode_chunk, **shape),
        "fused_flash": run_engine(cfg, params, fused=True, impl="flash",
                                  decode_chunk=args.decode_chunk, **shape),
    }

    # prefill-bound workloads: many prompts, short generations.  "prefill"
    # is the admission-bottleneck burst (every prompt fits the packed
    # stream); "prefill_long" mixes in prompts longer than the chunk, so
    # the packed path exercises chunked prefill (bounded decode stall)
    # while the sequential path stalls for a whole prompt per admission.
    pshape = dict(max_batch=args.prefill_max_batch,
                  kv_len=args.prefill_kv_len,
                  max_new_tokens=args.prefill_new_tokens, impl="ref",
                  repeat=5)
    burst = [args.prefill_prompt_len] * args.prefill_requests
    mixed = ([args.prefill_prompt_len]
             * (args.prefill_requests - args.prefill_long_count)
             + [args.prefill_long_len] * args.prefill_long_count)
    prefill = {
        "seq": run_prefill_workload(cfg, params, packed=False,
                                    prompt_lens=burst, **pshape),
        "packed": run_prefill_workload(cfg, params, packed=True,
                                       prefill_chunk=args.prefill_chunk,
                                       prompt_lens=burst, **pshape),
    }
    prefill_long = {
        "seq": run_prefill_workload(cfg, params, packed=False,
                                    prompt_lens=mixed, **pshape),
        "packed": run_prefill_workload(cfg, params, packed=True,
                                       prefill_chunk=args.prefill_chunk,
                                       prompt_lens=mixed, **pshape),
    }

    rec = {
        "bench": "serving",
        "arch": args.arch,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        **shape,
        "prefill_shape": {
            "max_batch": args.prefill_max_batch,
            "kv_len": args.prefill_kv_len, "chunk": args.prefill_chunk,
            "requests": args.prefill_requests,
            "prompt_len": args.prefill_prompt_len,
            "long_len": args.prefill_long_len,
            "long_count": args.prefill_long_count,
            "max_new_tokens": args.prefill_new_tokens,
        },
        "results": results,
        "prefill": prefill,
        "prefill_long": prefill_long,
        "speedup_fused_vs_seed": (results["fused"]["tokens_per_s"]
                                  / max(results["seed"]["tokens_per_s"],
                                        1e-9)),
        "speedup_packed_vs_seq_prefill": (
            prefill["packed"]["prefill_tokens_per_s"]
            / max(prefill["seq"]["prefill_tokens_per_s"], 1e-9)),
    }
    check_schema(rec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    rows = [{"path": k, **v} for k, v in results.items()]
    emit(rows, "serving_decode")
    rows = ([{"path": k, **v} for k, v in prefill.items()]
            + [{"path": f"long_{k}", **v} for k, v in prefill_long.items()])
    emit(rows, "serving_prefill")
    print(f"speedup fused/seed: {rec['speedup_fused_vs_seed']:.2f}x · "
          f"prefill packed/seq: {rec['speedup_packed_vs_seq_prefill']:.2f}x "
          f"(ttft {prefill['seq']['mean_ttft_s']*1e3:.1f} -> "
          f"{prefill['packed']['mean_ttft_s']*1e3:.1f} ms · long stall "
          f"{prefill_long['seq']['max_stall_tokens']} -> "
          f"{prefill_long['packed']['max_stall_tokens']} tok) -> {args.out}")


if __name__ == "__main__":
    main()
