"""Gemma-3-27B — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-*; unverified]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    post_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    act="gelu",
    glu=True,
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-27b-pt",
    notes="long_500k runs: local layers bounded-window KV; 1-in-6 global "
          "layers hold full 524k KV (seq-sharded), O(N) per decoded token",
))
