"""Arrival-process generators for request-level serving experiments.

A workload is a list of :class:`Arrival` records — (due time, prompt,
budget, priority) — consumed by ``ServingFrontend.play`` and the
capacity benchmark (``benchmarks/perf_capacity.py``).  Three arrival
processes are provided:

- :func:`poisson_arrivals` — memoryless open-loop traffic at a given
  offered load (requests/s), the standard capacity-curve driver;
- :func:`bursty_arrivals` — Poisson bursts of back-to-back arrivals
  (same mean rate, heavier tail) to probe scheduler behaviour under
  transient overload;
- :func:`trace_arrivals` — replay recorded arrival times verbatim.

Prompts come from :func:`synthetic_prompts`, which can share a common
prefix across requests (``shared_prefix``) the way production traffic
shares system prompts — the packed prefill re-processes it per request
today, so the shared fraction is also the headroom a future prefix
cache would reclaim.  Everything here is numpy-only and deterministic
under a seeded generator.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of a workload: due ``t`` seconds after play starts."""
    t: float
    prompt: np.ndarray
    max_new_tokens: Optional[int] = None
    priority: int = 0


def poisson_arrivals(rate_rps: float, n: int,
                     rng: np.random.Generator) -> list[float]:
    """``n`` arrival times of a Poisson process at ``rate_rps`` req/s
    (i.i.d. exponential inter-arrival gaps), ascending from t=0."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return list(np.cumsum(gaps))


def bursty_arrivals(rate_rps: float, n: int, rng: np.random.Generator,
                    *, burst: int = 4) -> list[float]:
    """``n`` arrival times in Poisson bursts: groups of ``burst``
    simultaneous arrivals whose group process runs at ``rate_rps /
    burst``, so the mean offered load matches :func:`poisson_arrivals`
    at the same rate while the instantaneous load is far spikier."""
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    n_groups = -(-n // burst)
    starts = poisson_arrivals(rate_rps / burst, n_groups, rng)
    times = [t for t in starts for _ in range(burst)]
    return times[:n]


def trace_arrivals(times: Sequence[float]) -> list[float]:
    """Validate and adopt recorded arrival times (seconds, ascending)."""
    out = [float(t) for t in times]
    if not out:
        raise ValueError("trace must hold at least one arrival")
    if any(t < 0 for t in out) or any(b < a for a, b in zip(out, out[1:])):
        raise ValueError("trace times must be non-negative and ascending")
    return out


def synthetic_prompts(n: int, rng: np.random.Generator, *,
                      min_len: int = 4, max_len: int = 24,
                      vocab: int = 256,
                      shared_prefix: int = 0) -> list[np.ndarray]:
    """``n`` random int32 prompts with lengths uniform in
    [min_len, max_len]; the first ``shared_prefix`` tokens are common to
    every prompt (system-prompt sharing)."""
    if not 0 < min_len <= max_len:
        raise ValueError(f"need 0 < min_len <= max_len, got "
                         f"[{min_len}, {max_len}]")
    if shared_prefix >= min_len:
        raise ValueError(f"shared_prefix ({shared_prefix}) must leave at "
                         f"least one unique token (min_len {min_len})")
    prefix = rng.integers(0, vocab, size=shared_prefix)
    out = []
    for _ in range(n):
        length = int(rng.integers(min_len, max_len + 1))
        body = rng.integers(0, vocab, size=length - shared_prefix)
        out.append(np.concatenate([prefix, body]).astype(np.int32))
    return out


def make_workload(n: int, rate_rps: float, *, seed: int = 0,
                  kind: str = "poisson", burst: int = 4,
                  trace: Optional[Sequence[float]] = None,
                  hi_fraction: float = 0.0, hi_priority: int = 1,
                  min_len: int = 4, max_len: int = 24, vocab: int = 256,
                  shared_prefix: int = 0,
                  max_new_tokens: Optional[int] = None) -> list[Arrival]:
    """Build a complete workload: arrival process x synthetic prompts x
    a two-class priority mix (a ``hi_fraction`` of requests at
    ``hi_priority``, the rest at 0 — interactive vs batch traffic)."""
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        times = poisson_arrivals(rate_rps, n, rng)
    elif kind == "bursty":
        times = bursty_arrivals(rate_rps, n, rng, burst=burst)
    elif kind == "trace":
        times = trace_arrivals(trace if trace is not None else [])
        if len(times) < n:
            raise ValueError(f"trace holds {len(times)} arrivals, need {n}")
        times = times[:n]
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")
    prompts = synthetic_prompts(n, rng, min_len=min_len, max_len=max_len,
                                vocab=vocab, shared_prefix=shared_prefix)
    hi = rng.random(n) < hi_fraction
    return [Arrival(t=times[i], prompt=prompts[i],
                    max_new_tokens=max_new_tokens,
                    priority=hi_priority if hi[i] else 0)
            for i in range(n)]
