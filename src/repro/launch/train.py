"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production entry point.  On real hardware it binds the full config to the
pod mesh; in the CPU container use ``--reduced --devices N`` to run a
shrunk config on N forced host devices (the same code path, smaller
numbers).  Fault-tolerance knobs (checkpoint dir/interval, retries,
straggler factor) map 1:1 onto TrainerConfig.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for CPU smoke runs")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing); 0 = real")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-axis size (0: auto)")
    ap.add_argument("--model-axis", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import dataclasses
    import jax
    from repro.config import SHAPES, ShapeSpec, get_config, reduce_config
    from repro.launch.mesh import make_production_mesh, small_mesh
    from repro.training.optimizer import OptConfig
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    shape = SHAPES[args.shape]
    if args.global_batch or args.seq_len:
        shape = ShapeSpec(
            shape.name, shape.kind,
            args.seq_len or shape.seq_len,
            args.global_batch or shape.global_batch)

    n_dev = len(jax.devices())
    if args.data_axis and args.model_axis:
        mesh = small_mesh(args.data_axis, args.model_axis)
    elif n_dev >= 256:
        mesh = make_production_mesh(multi_pod=(n_dev >= 512))
    else:
        model_ax = 1
        mesh = small_mesh(n_dev // model_ax, model_ax)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} batch={shape.global_batch} "
          f"seq={shape.seq_len}")

    trainer = Trainer(
        cfg, shape, mesh,
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps),
        tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every,
                           accum=args.accum, remat=args.remat),
        seed=args.seed)
    start = trainer.step
    for m in trainer.run(args.steps - start):
        if m["step"] % 10 == 0 or m["step"] == start:
            print(f"step {m['step']:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['gnorm']:.3f} lr={m['lr']:.2e} "
                  f"dt={m['dt']*1e3:.0f}ms", flush=True)
    if args.ckpt_dir:
        trainer.save()
    print(f"done: {trainer.step} steps, {trainer.slow_steps} slow steps")


if __name__ == "__main__":
    main()
