PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify bench-serving bench-cosim bench-smoke report

test:               ## tier-1 test suite
	$(PY) -m pytest -x -q

bench-serving:      ## full serving decode+prefill benchmark -> experiments/BENCH_serving.json
	$(PY) -m benchmarks.perf_serving

bench-cosim:        ## generation co-simulation sweep (zoo x architectures) -> experiments/BENCH_cosim.json
	$(PY) -m benchmarks.perf_cosim

bench-smoke:        ## tiny-config serving+cosim benchmarks; assert the JSON report schemas
	$(PY) -m benchmarks.perf_serving --smoke
	$(PY) -m benchmarks.perf_cosim --smoke

verify:             ## CI gate: tier-1 tests + bench smokes (schema-checked)
	$(PY) -m pytest -x -q
	$(MAKE) bench-smoke

report:             ## render benchmark/dry-run tables
	$(PY) -m benchmarks.report
