"""NoI evaluation: routing, link utilisation u_k, μ(λ), σ(λ) (eqs 11-15).

Routing is shortest-path (BFS) over the candidate link graph — the paper's
NoI routers are a hierarchical wormhole fabric; at the utilisation-
objective level only the path→link incidence q_ijk matters (eq. 11).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.chiplets import LINK
from repro.core.placement import Placement
from repro.core.traffic import Phase, phase_traffic_matrix


def _paths(p: Placement) -> dict:
    """All-pairs BFS parents: returns hop-path cache {src: parents array}."""
    adj: dict[int, list[int]] = {i: [] for i in range(p.n)}
    for a, b in p.links:
        adj[a].append(b)
        adj[b].append(a)
    out = {}
    for s in range(p.n):
        par = np.full(p.n, -1, np.int32)
        par[s] = s
        q = deque([s])
        while q:
            u = q.popleft()
            for v in adj[u]:
                if par[v] < 0:
                    par[v] = u
                    q.append(v)
        out[s] = par
    return out


@dataclasses.dataclass
class NoIEval:
    mu: float                 # eq. 14 (time-avg of eq. 12)
    sigma: float              # eq. 15 (time-avg of eq. 13)
    max_util: float
    total_byte_hops: float
    mean_hops: float
    per_phase_link_bytes: list


def evaluate_noi(p: Placement, phases: list[Phase],
                 roles_override: dict | None = None) -> NoIEval:
    if not p.connected():
        return NoIEval(np.inf, np.inf, np.inf, np.inf, np.inf, [])
    parents = _paths(p)
    links = sorted(p.links)
    link_idx = {l: i for i, l in enumerate(links)}
    roles = roles_override if roles_override is not None else p.roles()

    mus, sigmas, weights, per_phase = [], [], [], []
    total_byte_hops = 0.0
    total_hops = 0
    n_flows = 0
    max_util = 0.0

    for ph in phases:
        F = phase_traffic_matrix(ph, roles, p.n)
        # u = per-link bytes for ONE execution of the phase (one timestamp
        # of eq. 12/13).  Repeats weight the time-average (eqs 14-15) — a
        # phase that runs k times contributes k identical timestamps — and
        # scale the energy byte-hops, but NOT the per-execution link time.
        u = np.zeros(len(links))
        for (i, j), bytes_ in F.items():
            par = parents[i]
            if par[j] < 0:
                return NoIEval(np.inf, np.inf, np.inf, np.inf, np.inf, [])
            # walk j -> i collecting links (q_ijk in eq. 11)
            cur = j
            hops = 0
            while cur != i:
                prev = int(par[cur])
                u[link_idx[(min(cur, prev), max(cur, prev))]] += bytes_
                cur = prev
                hops += 1
            total_byte_hops += bytes_ * hops * ph.repeat
            total_hops += hops
            n_flows += 1
        mus.append(float(u.mean()))
        sigmas.append(float(u.std()))
        weights.append(float(ph.repeat))
        max_util = max(max_util, float(u.max()) if len(u) else 0.0)
        per_phase.append(u)

    wsum = sum(weights) or 1.0
    return NoIEval(
        mu=float(np.dot(mus, weights) / wsum),
        sigma=float(np.dot(sigmas, weights) / wsum),
        max_util=max_util, total_byte_hops=total_byte_hops,
        mean_hops=total_hops / max(n_flows, 1),
        per_phase_link_bytes=per_phase)


def noi_phase_time(link_bytes: np.ndarray) -> float:
    """Serialisation time of a phase on the NoI: the busiest link bounds
    throughput (wormhole, all flows concurrent)."""
    if len(link_bytes) == 0:
        return 0.0
    return float(link_bytes.max()) / LINK.bw


def noi_energy(eval_: NoIEval) -> float:
    """Link + router traversal energy for the whole workload (J)."""
    pj_per_bit = LINK.energy_pj_per_bit + LINK.router_pj_per_bit
    return eval_.total_byte_hops * 8 * pj_per_bit * 1e-12


def mesh_baseline_eval(n_chiplets: int, phases, n_samples: int = 5) -> NoIEval:
    """Reference 2-D mesh NoI (paper Fig-4 normaliser): full mesh links with
    *placement-unaware* (shuffled) chiplet assignment, averaged over a few
    draws — the "standard multi-hop regular topology" the paper argues
    against (§3.2)."""
    import random

    from repro.core.placement import random_placement

    evs = [evaluate_noi(random_placement(n_chiplets, random.Random(s)), phases)
           for s in range(n_samples)]
    mu = float(np.mean([e.mu for e in evs]))
    sigma = float(np.mean([e.sigma for e in evs]))
    return NoIEval(mu=mu, sigma=sigma,
                   max_util=float(np.mean([e.max_util for e in evs])),
                   total_byte_hops=float(np.mean([e.total_byte_hops for e in evs])),
                   mean_hops=float(np.mean([e.mean_hops for e in evs])),
                   per_phase_link_bytes=[])
