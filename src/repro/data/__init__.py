from repro.data.pipeline import DataConfig, DataState, LMDataPipeline  # noqa: F401
