"""Sharding-constraint injection for the model library.

Models are written as pure functions that mark *logical* tensor roles
(``residual``, ``act_ff``, ``expert_buf`` …) via :func:`constrain`.  A
:class:`Plan` — built per (arch × shape × mesh) by
:mod:`repro.parallel.sharding` — maps those roles to concrete
``PartitionSpec``s.  With no active plan every call is a no-op, so the same
model code runs unsharded on one CPU device (smoke tests) and fully sharded
on the 512-device dry-run mesh.

This is the software form of the paper's heterogeneous kernel→chiplet
mapping: the *role* of a tensor (dynamic attention operand vs. static
weight-stationary FFN operand) decides its placement, not the module that
computed it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Plan:
    """A named-role → PartitionSpec table bound to a mesh."""

    mesh: Mesh
    roles: dict[str, P]
    # param-path regex → PartitionSpec rules (used by sharding.py, kept here
    # so a Plan is a self-contained description of one mapping)
    param_rules: tuple[tuple[str, P], ...] = ()
    name: str = ""

    def spec(self, role: str) -> Optional[P]:
        return self.roles.get(role)

    def sharding(self, role: str) -> Optional[NamedSharding]:
        s = self.roles.get(role)
        return None if s is None else NamedSharding(self.mesh, s)


_tls = threading.local()


def current_plan() -> Optional[Plan]:
    return getattr(_tls, "plan", None)


@contextlib.contextmanager
def activate_plan(plan: Optional[Plan]):
    prev = current_plan()
    _tls.plan = plan
    try:
        yield plan
    finally:
        _tls.plan = prev


def constrain(x: jax.Array, role: str) -> jax.Array:
    """Attach the active plan's sharding for ``role`` (no-op without plan)."""
    plan = current_plan()
    if plan is None:
        return x
    spec = plan.spec(role)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))
