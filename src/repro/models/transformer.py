"""Model assembly: scan-grouped layer stacks, embeddings, loss / prefill /
decode drivers for every supported architecture family.

Depth is folded into ``jax.lax.scan`` groups (one scan per maximal run of
identical pattern periods) so HLO size and dry-run compile time are O(1)
in layer count — 100-layer configs compile as fast as 2-layer ones.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import modules as M
from repro.models.attention import (
    apply_attention, apply_mla, init_attention, init_mla, init_kv_cache)
from repro.models.moe import apply_moe, init_moe, router_aux_loss
from repro.quant.ops import qdense
from repro.models.rglru import apply_rglru, init_rglru, init_rglru_cache
from repro.models.ssm import apply_mamba, init_mamba, init_ssm_cache
from repro.parallel import constrain


# ---------------------------------------------------------------------------
# group derivation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupSpec:
    units: tuple[tuple[str, bool], ...]   # (layer_kind, use_moe)
    repeats: int


def build_groups(cfg: ModelConfig, *, encoder: bool = False) -> list[GroupSpec]:
    if encoder:
        kinds = cfg.encoder_layer_kinds
        moe = tuple(False for _ in kinds)
        period = len(cfg.encoder_pattern)
    else:
        kinds = cfg.layer_kinds
        moe = cfg.moe_layer_mask()
        period = len(cfg.pattern)
    units = tuple(zip(kinds, moe))
    n = len(units)
    groups: list[GroupSpec] = []
    full = n // period
    periods = [units[i * period:(i + 1) * period] for i in range(full)]
    i = 0
    while i < len(periods):
        j = i
        while j < len(periods) and periods[j] == periods[i]:
            j += 1
        groups.append(GroupSpec(periods[i], j - i))
        i = j
    rem = units[full * period:]
    if rem:
        groups.append(GroupSpec(rem, 1))
    return groups


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg, kind: str, use_moe: bool, *, causal: bool, dtype):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    if kind == "ssm":
        p["ln1"] = M.init_norm(ks[0], cfg)
        p["mamba"] = init_mamba(ks[1], cfg, dtype=dtype)
        return p
    if kind == "recurrent":
        p["ln1"] = M.init_norm(ks[0], cfg)
        p["rec"] = init_rglru(ks[1], cfg, dtype=dtype)
        p["ln2"] = M.init_norm(ks[2], cfg)
        p["mlp"] = M.init_mlp(ks[3], cfg)
        return p
    if kind == "cross":  # vlm gated cross-attention layer
        p["ln1"] = M.init_norm(ks[0], cfg)
        p["attn"] = init_attention(ks[1], cfg, cross=True, dtype=dtype)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["ln2"] = M.init_norm(ks[2], cfg)
        p["mlp"] = M.init_mlp(ks[3], cfg)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
        return p
    # global / local attention layer
    p["ln1"] = M.init_norm(ks[0], cfg)
    if cfg.is_mla:
        p["attn"] = init_mla(ks[1], cfg, dtype=dtype)
    else:
        p["attn"] = init_attention(ks[1], cfg, dtype=dtype)
    if cfg.parallel_block:
        p["mlp"] = M.init_mlp(ks[3], cfg)
        return p
    if cfg.post_norm:
        p["ln1_post"] = M.init_norm(ks[4], cfg)
    if cfg.cross_attn_decoder and causal:
        p["ln_cross"] = M.init_norm(ks[5], cfg)
        p["cross"] = init_attention(ks[6], cfg, cross=True, dtype=dtype)
    p["ln2"] = M.init_norm(ks[2], cfg)
    if use_moe:
        p["moe"] = init_moe(ks[3], cfg, dtype=dtype)
    else:
        p["mlp"] = M.init_mlp(ks[3], cfg)
    if cfg.post_norm:
        p["ln2_post"] = M.init_norm(ks[7], cfg)
    return p


def _init_block(key, cfg, spec: GroupSpec, *, causal: bool, dtype):
    ks = jax.random.split(key, len(spec.units))
    return {f"u{i}": _init_layer(ks[i], cfg, kind, use_moe, causal=causal, dtype=dtype)
            for i, (kind, use_moe) in enumerate(spec.units)}


def _init_stack(key, cfg, groups, *, causal: bool, dtype):
    gparams = []
    for gi, spec in enumerate(groups):
        gkey = jax.random.fold_in(key, gi)
        keys = jax.random.split(gkey, spec.repeats)
        blk = jax.vmap(lambda k: _init_block(k, cfg, spec, causal=causal, dtype=dtype))(keys)
        gparams.append(blk)
    return gparams


def init_params(cfg: ModelConfig, key, *, param_dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": {"tok": M.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), param_dtype)},
    }
    if not cfg.use_rope and cfg.family not in ("audio",) and cfg.max_abs_positions:
        params["embed"]["pos"] = M.embed_init(
            ks[1], (cfg.max_abs_positions, cfg.d_model), param_dtype)
    causal = cfg.family != "encoder"
    params["stack"] = _init_stack(ks[2], cfg, build_groups(cfg), causal=causal,
                                  dtype=param_dtype)
    params["final_norm"] = M.init_norm(ks[3], cfg)
    if cfg.n_encoder_layers:
        params["encoder"] = _init_stack(ks[4], cfg, build_groups(cfg, encoder=True),
                                        causal=False, dtype=param_dtype)
        params["encoder_norm"] = M.init_norm(ks[5], cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = M.dense_init(
            jax.random.fold_in(key, 99), (cfg.d_model, cfg.vocab_size), param_dtype)
    return params


# ---------------------------------------------------------------------------
# per-layer apply
# ---------------------------------------------------------------------------

def _apply_layer(p, x, *, cfg, kind, use_moe, mode, pos, cache, cross_src,
                 impl, causal, kv_cap=0, length=None, segments=None,
                 kv_bits=0):
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if kind == "ssm":
        h = M.apply_norm(p["ln1"], x)
        out, new_cache = apply_mamba(p["mamba"], h, cfg=cfg, mode=mode,
                                     cache=cache, length=length)
        x = constrain(x + out, "residual")
        return x, new_cache, aux
    if kind == "recurrent":
        h = M.apply_norm(p["ln1"], x)
        out, c = apply_rglru(p["rec"], h, cfg=cfg, mode=mode, cache=cache,
                             length=length)
        x = constrain(x + out, "residual")
        h = M.apply_norm(p["ln2"], x)
        x = constrain(x + M.apply_mlp(p["mlp"], h, cfg), "residual")
        return x, c, aux
    if kind == "cross":
        h = M.apply_norm(p["ln1"], x)
        out, c = apply_attention(p["attn"], h, cfg=cfg, kind="cross", mode=mode,
                                 pos=pos, cache=cache, cross_src=cross_src,
                                 impl=impl, causal=False)
        x = constrain(x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * out, "residual")
        h = M.apply_norm(p["ln2"], x)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * M.apply_mlp(p["mlp"], h, cfg)
        return constrain(x, "residual"), c, aux

    # global / local attention layer
    h = M.apply_norm(p["ln1"], x)
    if cfg.parallel_block:  # GPT-J eq. (9): parallel MHA + FF
        c_self = cache["attn"] if cache is not None else None
        out, c = apply_attention(p["attn"], h, cfg=cfg, kind=kind, mode=mode,
                                 pos=pos, cache=c_self, impl=impl, causal=causal,
                                 kv_cap=kv_cap, length=length, segments=segments,
                                 kv_bits=kv_bits)
        x = constrain(x + out + M.apply_mlp(p["mlp"], h, cfg), "residual")
        return x, ({"attn": c} if mode != "train" else None), aux

    if cfg.is_mla:
        c_self = cache["attn"] if cache is not None else None
        out, c = apply_mla(p["attn"], h, cfg=cfg, mode=mode, pos=pos,
                           cache=c_self, impl=impl, kv_cap=kv_cap,
                           length=length, segments=segments)
    else:
        c_self = cache["attn"] if cache is not None else None
        out, c = apply_attention(p["attn"], h, cfg=cfg, kind=kind, mode=mode,
                                 pos=pos, cache=c_self, impl=impl, causal=causal,
                                 kv_cap=kv_cap, length=length, segments=segments,
                                 kv_bits=kv_bits)
    if cfg.post_norm:
        out = M.apply_norm(p["ln1_post"], out)
    x = constrain(x + out, "residual")

    c_cross = None
    if "cross" in p:
        h = M.apply_norm(p["ln_cross"], x)
        c_cross_in = cache["cross"] if cache is not None else None
        out, c_cross = apply_attention(p["cross"], h, cfg=cfg, kind="cross",
                                       mode=mode, pos=pos, cache=c_cross_in,
                                       cross_src=cross_src, impl=impl, causal=False)
        x = constrain(x + out, "residual")

    h = M.apply_norm(p["ln2"], x)
    if use_moe:
        ff = apply_moe(p["moe"], h, cfg, mode=mode)
        if mode == "train":
            aux = router_aux_loss(p["moe"], h, cfg)
    else:
        ff = M.apply_mlp(p["mlp"], h, cfg)
    if cfg.post_norm:
        ff = M.apply_norm(p["ln2_post"], ff)
    x = constrain(x + ff, "residual")

    if mode == "train":
        blk_cache = None
    else:
        blk_cache = {"attn": c}
        if "cross" in p:
            blk_cache["cross"] = c_cross
    return x, blk_cache, aux


# ---------------------------------------------------------------------------
# stack runner (scan groups)
# ---------------------------------------------------------------------------

def _apply_block(p_blk, x, cache_blk, *, cfg, spec, mode, pos, cross_src,
                 impl, causal, kv_cap=0, length=None, segments=None,
                 kv_bits=0):
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for ui, (kind, use_moe) in enumerate(spec.units):
        c_in = None if cache_blk is None else cache_blk.get(f"u{ui}")
        x, c_out, aux = _apply_layer(
            p_blk[f"u{ui}"], x, cfg=cfg, kind=kind, use_moe=use_moe, mode=mode,
            pos=pos, cache=c_in, cross_src=cross_src, impl=impl, causal=causal,
            kv_cap=kv_cap, length=length, segments=segments, kv_bits=kv_bits)
        new_cache[f"u{ui}"] = c_out
        aux_total = aux_total + aux
    return x, (new_cache if mode != "train" else None), aux_total


def run_stack(stack_params, x, *, cfg, groups, mode, pos, caches=None,
              cross_src=None, impl="auto", causal=True, remat=False,
              remat_policy: Optional[str] = None, kv_cap=0,
              length=None, segments=None, kv_bits=0,
              decode_unroll: int = 8):
    """``decode_unroll``: decode-mode groups with at most this many repeats
    run as an unrolled Python loop instead of ``lax.scan``.  Scan passes the
    stacked KV pool through xs-slicing and ys-stacking — a full pool
    read+write per token that buffer donation cannot alias away.  Unrolled,
    the per-repeat update is a ``dynamic_update_slice`` on the stacked leaf,
    so a donated cache is updated in place (decode graphs are S=1 and tiny,
    so HLO growth is negligible; large-repeat configs keep scan to preserve
    O(1)-in-depth HLO for the dry-run)."""
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for gi, spec in enumerate(groups):
        gp = stack_params[gi]
        gc = None if caches is None else caches[gi]

        if mode in ("decode", "chunk") and gc is not None and not remat \
                and spec.repeats <= decode_unroll:
            new_gc = gc
            for r in range(spec.repeats):
                p_blk = jax.tree_util.tree_map(lambda p, r=r: p[r], gp)
                c_blk = jax.tree_util.tree_map(lambda c, r=r: c[r], gc)
                x, c_out, _ = _apply_block(
                    p_blk, x, c_blk, cfg=cfg, spec=spec, mode=mode, pos=pos,
                    cross_src=cross_src, impl=impl, causal=causal,
                    kv_cap=kv_cap, length=length, segments=segments,
                    kv_bits=kv_bits)
                new_gc = jax.tree_util.tree_map(
                    lambda pool, one, r=r: pool.at[r].set(one.astype(pool.dtype)),
                    new_gc, c_out)
            new_caches.append(new_gc)
            continue

        def step(carry, xs, spec=spec):
            x = carry
            p_blk, c_blk = xs
            x, c_out, aux = _apply_block(
                p_blk, x, c_blk, cfg=cfg, spec=spec, mode=mode, pos=pos,
                cross_src=cross_src, impl=impl, causal=causal, kv_cap=kv_cap,
                length=length, segments=segments, kv_bits=kv_bits)
            return x, (c_out, aux)

        if remat:
            policy = None
            if remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            step = jax.checkpoint(step, policy=policy)

        if gc is None:
            x, (c_stacked, aux) = jax.lax.scan(
                lambda c, p: step(c, (p, None)), x, gp)
        else:
            x, (c_stacked, aux) = jax.lax.scan(step, x, (gp, gc))
        new_caches.append(c_stacked)
        aux_total = aux_total + jnp.sum(aux)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens, pos, dtype):
    h = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if not cfg.use_rope:
        if "pos" in params["embed"]:
            pe = jnp.take(params["embed"]["pos"], pos, axis=0).astype(dtype)
        else:  # sinusoidal stub (whisper)
            pe = M.sinusoidal_positions(pos, cfg.d_model).astype(dtype)
        h = h + pe
    return constrain(h, "residual")


def unembed(params, cfg, h):
    h = constrain(h, "pre_logits")
    if cfg.tie_embeddings:
        w = params["embed"]["tok"]
        logits = jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype))
    else:
        logits = qdense(h, params["lm_head"], h.dtype)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap).astype(logits.dtype)
    return constrain(logits, "logits")


def _run_encoder(params, cfg, batch, dtype, impl, remat=False,
                 remat_policy=None):
    if cfg.family == "audio":
        h = batch["frames"].astype(dtype)  # precomputed frame embeddings (stub)
        S = h.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), h.shape[:2])
        h = h + M.sinusoidal_positions(pos, cfg.d_model).astype(dtype)
        h = constrain(h, "residual")
    else:  # bart-style text encoder
        toks = batch["encoder_tokens"]
        pos = jnp.broadcast_to(jnp.arange(toks.shape[1], dtype=jnp.int32), toks.shape)
        h = embed_tokens(params, cfg, toks, pos, dtype)
    groups = build_groups(cfg, encoder=True)
    h, _, _ = run_stack(params["encoder"], h, cfg=cfg, groups=groups,
                        mode="train", pos=pos, impl=impl, causal=False,
                        remat=remat, remat_policy=remat_policy)
    return M.apply_norm(params["encoder_norm"], h)


def _cross_source(params, cfg, batch, dtype, impl, remat=False,
                  remat_policy=None):
    if cfg.n_encoder_layers:
        return _run_encoder(params, cfg, batch, dtype, impl, remat,
                            remat_policy)
    if cfg.family == "vlm":
        return batch["image_embeds"].astype(dtype)  # patch embeddings (stub)
    return None


# ---------------------------------------------------------------------------
# public drivers
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch, *, impl="auto",
            compute_dtype=jnp.bfloat16, remat=False, remat_policy=None,
            aux_weight=0.01):
    """batch: tokens (B,S) [+ frames | encoder_tokens | image_embeds]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cross_src = _cross_source(params, cfg, batch, compute_dtype, impl,
                              remat, remat_policy)
    causal = cfg.family != "encoder"

    h = embed_tokens(params, cfg, tokens, pos, compute_dtype)
    h, _, aux = run_stack(params["stack"], h, cfg=cfg, groups=build_groups(cfg),
                          mode="train", pos=pos, cross_src=cross_src, impl=impl,
                          causal=causal, remat=remat, remat_policy=remat_policy)
    h = M.apply_norm(params["final_norm"], h)
    logits = unembed(params, cfg, h)

    lf = logits.astype(jnp.float32)
    if causal:
        lf = lf[:, :-1]
        targets = tokens[:, 1:]
    else:  # encoder (BERT-class): MLM-style proxy on fixed positions
        keep = (jnp.arange(S) % 7) == 3
        lf = lf
        targets = tokens
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if not causal:
        nll = jnp.where(keep[None, :], nll, 0.0)
        loss = nll.sum() / (keep.sum() * B)
    else:
        loss = nll.mean()
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


def prefill(params, cfg: ModelConfig, batch, *, impl="auto",
            compute_dtype=jnp.bfloat16, kv_cap: int = 0, length=None,
            kv_bits: int = 0):
    """Returns (last-token logits (B, V), cache).

    ``length`` (optional traced scalar): true prompt length when ``tokens``
    is right-padded to a static shape — logits are taken at position
    ``length - 1`` instead of the last position.  Causal masking makes
    attention exact under padding; ``length`` is also threaded into the
    stateful layer kinds (ring-buffer local attention, SSM, RG-LRU) so the
    *cache* at ``length`` is exact too — any prompt length can be served
    from a handful of padded compile shapes.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cross_src = _cross_source(params, cfg, batch, compute_dtype, impl)

    h = embed_tokens(params, cfg, tokens, pos, compute_dtype)
    h, caches, _ = run_stack(params["stack"], h, cfg=cfg, groups=build_groups(cfg),
                             mode="prefill", pos=pos, cross_src=cross_src,
                             impl=impl, causal=True, kv_cap=kv_cap,
                             length=length, kv_bits=kv_bits)
    h = M.apply_norm(params["final_norm"], h)
    if length is None:
        last = h[:, -1:]
    else:
        last = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
    logits = unembed(params, cfg, last)[:, 0]
    return logits, {"stack": caches}


def prefill_packed(params, cfg: ModelConfig, tokens, positions, segments,
                   gather_idx, *, impl="auto", compute_dtype=jnp.bfloat16,
                   kv_bits: int = 0):
    """Packed ragged prefill: several prompts in one ``(1, C)`` stream.

    ``positions`` are within-prompt positions (used for RoPE / absolute
    embeddings), ``segments`` per-token prompt ids (-1 = pad) — a query
    never attends across a prompt boundary.  ``gather_idx`` (n_seg,) picks
    the packed index of each prompt's last token; returns
    (logits (n_seg, V), raw per-token cache) — cache k/v/pos leaves keep
    the packed stream layout, the caller scatters segments into KV slots.

    Only attention layer kinds can be packed (SSM / recurrent state would
    integrate across prompt boundaries).
    """
    if not all(k in ("global", "local") for k in cfg.layer_kinds):
        raise ValueError(
            f"packed prefill needs attention-only stacks, got {cfg.layer_kinds}")
    h = embed_tokens(params, cfg, tokens, jnp.maximum(positions, 0),
                     compute_dtype)
    h, caches, _ = run_stack(params["stack"], h, cfg=cfg,
                             groups=build_groups(cfg), mode="prefill",
                             pos=positions, impl=impl, causal=True,
                             segments=segments, kv_bits=kv_bits)
    h = M.apply_norm(params["final_norm"], h)
    last = h[0][gather_idx][:, None]                    # (n_seg, 1, D)
    logits = unembed(params, cfg, last)[:, 0]
    return logits, {"stack": caches}


def chunk_prefill_step(params, cfg: ModelConfig, cache, tokens, pos, take_idx,
                       *, impl="auto", compute_dtype=jnp.bfloat16):
    """One chunked-prefill continuation step over the slot pool.

    ``tokens`` (B, C): next chunk per row (right-padded); ``pos`` (B, C):
    absolute positions, -1 = pad / inactive row; ``take_idx`` (B,): index
    of each row's last real chunk token (0 for inactive rows).  Chunk K/V
    is written into each row's cache at its positions, and the chunk
    attends to the whole cache — later chunks of a long prompt see the KV
    of earlier chunks.  Returns (logits (B, V) at take_idx, cache).
    """
    h = embed_tokens(params, cfg, tokens, jnp.maximum(pos, 0), compute_dtype)
    h, caches, _ = run_stack(params["stack"], h, cfg=cfg,
                             groups=build_groups(cfg), mode="chunk", pos=pos,
                             caches=cache["stack"], impl=impl, causal=True)
    h = M.apply_norm(params["final_norm"], h)
    last = jnp.take_along_axis(h, take_idx[:, None, None], axis=1)  # (B,1,D)
    logits = unembed(params, cfg, last)[:, 0]
    return logits, {"stack": caches}


def verify_step(params, cfg: ModelConfig, cache, tokens, pos, *, impl="auto",
                compute_dtype=jnp.bfloat16):
    """Batched multi-position scoring step (speculative-decoding verify).

    Identical mechanics to :func:`chunk_prefill_step` — ``tokens`` (B, C)
    are written into each row's cache at explicit absolute positions
    ``pos`` (B, C) (-1 = pad / inactive row) and attend to the pre-write
    cache plus the in-stream block — but the logits are kept at **every**
    chunk position instead of one ``take_idx`` gather: one call scores all
    k draft tokens of a speculative step (logits at in-stream index ``i``
    are the target's distribution for the token *after* ``tokens[:, i]``).
    Returns (logits (B, C, V), cache).
    """
    h = embed_tokens(params, cfg, tokens, jnp.maximum(pos, 0), compute_dtype)
    h, caches, _ = run_stack(params["stack"], h, cfg=cfg,
                             groups=build_groups(cfg), mode="chunk", pos=pos,
                             caches=cache["stack"], impl=impl, causal=True)
    h = M.apply_norm(params["final_norm"], h)
    logits = unembed(params, cfg, h)                        # (B, C, V)
    return logits, {"stack": caches}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *, impl="auto",
                compute_dtype=jnp.bfloat16):
    """One decode step.  tokens (B,), pos (B,) -> (logits (B, V), cache)."""
    B = tokens.shape[0]
    pos2 = pos[:, None]
    h = embed_tokens(params, cfg, tokens[:, None], pos2, compute_dtype)
    h, caches, _ = run_stack(params["stack"], h, cfg=cfg, groups=build_groups(cfg),
                             mode="decode", pos=pos2, caches=cache["stack"],
                             impl=impl, causal=True)
    h = M.apply_norm(params["final_norm"], h)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, {"stack": caches}


# ---------------------------------------------------------------------------
# cache init (dry-run decode inputs + serving engine)
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, kind, batch, kv_len, dtype, kv_bits=0):
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if kind == "recurrent":
        return init_rglru_cache(cfg, batch, dtype)
    n_cross = cfg.n_frontend_tokens
    if kind == "cross":
        return init_kv_cache(cfg, "cross", batch, kv_len, dtype, n_cross=n_cross)
    c = {"attn": init_kv_cache(cfg, kind, batch, kv_len, dtype,
                               kv_bits=kv_bits)}
    if cfg.cross_attn_decoder:
        c["cross"] = init_kv_cache(cfg, "cross", batch, kv_len, dtype, n_cross=n_cross)
        return c
    return c


def init_cache(cfg: ModelConfig, batch: int, kv_len: int, *,
               dtype=jnp.bfloat16, kv_bits: int = 0):
    groups = build_groups(cfg)
    caches = []
    for spec in groups:
        def one(kind=None):
            return {f"u{ui}": _init_layer_cache(cfg, kd, batch, kv_len, dtype,
                                                kv_bits=kv_bits)
                    for ui, (kd, _) in enumerate(spec.units)}
        blk = one()
        stacked = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (spec.repeats,) + leaf.shape).copy()
            if spec.repeats > 1 else leaf[None], blk)
        caches.append(stacked)
    return {"stack": caches}


# ---------------------------------------------------------------------------
# parameter counting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _param_shapes(cfg: ModelConfig):
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree_util.tree_flatten_with_path(shapes)[0]


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0.0
    for path, leaf in _param_shapes(cfg):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only:
            if any(k in ("tok", "pos") for k in keys) and not (
                    cfg.tie_embeddings and "tok" in keys):
                continue  # untied embedding tables don't do matmul FLOPs
            if "experts" in keys:
                n = n * cfg.top_k / cfg.n_experts
        total += n
    return int(total)
