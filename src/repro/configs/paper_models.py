"""The paper's own workload table (Table 3) as runnable configs.

These drive both the Plane-B simulator benchmarks (Figs. 8-11, Table 4)
and the runnable JAX model library (so per-kernel operation counts are
derived from the real graphs, not hand-listed).
"""
from repro.config import ModelConfig, register

BERT_BASE = register(ModelConfig(
    name="bert-base", family="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=30_522, norm="layernorm", act="gelu", glu=False,
    qkv_bias=True, mlp_bias=True, use_rope=False, max_abs_positions=8192,
    tie_embeddings=True, source="Table 3 / arXiv:1810.04805",
))

BERT_LARGE = register(ModelConfig(
    name="bert-large", family="encoder",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=30_522, norm="layernorm", act="gelu", glu=False,
    qkv_bias=True, mlp_bias=True, use_rope=False, max_abs_positions=8192,
    tie_embeddings=True, source="Table 3 / arXiv:1810.04805",
))

BART_BASE = register(ModelConfig(
    name="bart-base", family="encdec",
    n_layers=6, n_encoder_layers=6, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=50_265, norm="layernorm", act="gelu", glu=False,
    qkv_bias=True, mlp_bias=True, use_rope=False, max_abs_positions=8192,
    cross_attn_decoder=True, tie_embeddings=True,
    source="Table 3 / arXiv:1910.13461",
))

BART_LARGE = register(ModelConfig(
    name="bart-large", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab_size=50_265, norm="layernorm",
    act="gelu", glu=False, qkv_bias=True, mlp_bias=True, use_rope=False,
    max_abs_positions=8192, cross_attn_decoder=True, tie_embeddings=True,
    source="Table 3 / arXiv:1910.13461",
))

GPT_J = register(ModelConfig(
    name="gpt-j", family="dense",
    n_layers=28, d_model=4096, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=16_384, vocab_size=50_400, act="gelu", glu=False,
    parallel_block=True, rope_theta=10_000.0,
    source="Table 3 / EleutherAI GPT-J-6B",
    notes="parallel MHA+FF formulation (paper eq. 9)",
))

LLAMA2_7B = register(ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11_008,
    vocab_size=32_000, act="silu", glu=True, rope_theta=10_000.0,
    source="Table 3 / arXiv:2307.09288",
    notes="paper's Table-3 row; the paper describes it as MQA — the public "
          "7B checkpoint is MHA; the Plane-B simulator models the paper's "
          "MQA variant via its own workload descriptor",
))
