"""Crash-safe serving: snapshot/restore of live engine state with
bit-exact resume and journal replay.

The engine is a state machine over (device pytrees, host bookkeeping):
the slot-pool KV cache (bf16 rows, or int8/int4 codes + f32 scale leaves
under ``kv_bits``), the fused per-slot decode state including the
threaded PRNG key, the seed-path sampling key, request objects in
queue/slots/terminal lists, chunked-prefill progress, anomaly-quarantine
counters, and the measurement counters ``stats()`` reports.  A snapshot
captures *all* of it, so a process killed between any two ``step()``
calls restores to the exact pre-kill state and every subsequent token is
bit-identical to the uninterrupted run — greedy or temperature sampling
(the stored keys replay the same draws).

Storage layout (built on the shared ``repro.ckpt`` core, the same
atomic-commit discipline as ``training/checkpoint.py``)::

    <ckpt_dir>/
      journal.jsonl          append-only admission journal (one line per
                             accepted submit: uid, prompt, budget)
      snap_00000000/         versioned snapshot directories
        arrays.npz           every device leaf, dtype-exact (bf16-safe)
        meta.json            bookkeeping + config echo + sha256 digest
      LATEST                 pointer file, rewritten last (commit point)

**Exactly-once semantics.**  Requests admitted *after* the last snapshot
are not in it — they are recovered from the journal: ``restore_engine``
rewinds the engine to the snapshot, then resubmits the journal tail
(entries with ``uid >= `` the snapshot's next-uid) in uid order.  The
engine's restored ``_uid`` counter reassigns the same uids, and
re-prefilling from the prompt is deterministic, so the replayed requests
produce the same tokens the uninterrupted run would have — nothing lost
(journal), nothing duplicated (requests the snapshot already tracks are
skipped), nothing divergent (state + keys are bit-exact).  Requests that
*finished* between snapshot and crash simply rewind and re-decode to the
identical output.

Replay is bit-exact when post-snapshot submissions form one burst before
further ``step()`` calls (the chaos-harness kill points) or when the
bounded queue never sheds; interleaving submits with steps across a
bounded queue can re-shed differently on replay — the retriable
``REJECTED`` contract already covers that.  Deadlines are stored as
absolute engine-clock values: restoring into a process with a different
clock origin shifts them, so crash-safe deadline serving should inject a
persistent ``EngineConfig(clock=)``.

Transient-failure handling: snapshot IO runs under ``repro.ckpt.retry``
(bounded exponential backoff, layered on PR 6's anomaly quarantine —
a flaky store costs a late snapshot, not a crash), and restore walks
snapshots newest → oldest, skipping any whose integrity digest or
format version fails, so a torn/corrupt newest snapshot degrades to the
previous one instead of refusing to serve.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import sys
import time
import zipfile
from typing import Optional

import jax
import numpy as np

from repro.ckpt import (atomic_save_dir, digest_arrays, flatten_tree,
                        list_snapshots, load_arrays, read_latest, retry,
                        save_arrays, unflatten_tree)
from repro.serving.engine import REJECTED, EngineConfig, Request, ServingEngine

FORMAT_VERSION = 1
SNAP_PREFIX = "snap_"
JOURNAL = "journal.jsonl"

# engine-config fields echoed into the snapshot; all but the operational
# policy knobs (deadline/shedding/quarantine budgets — free to change
# across a restart) must match at restore or the resumed token stream
# could not be bit-exact
_ECHO_FIELDS = ("max_batch", "kv_len", "max_new_tokens", "temperature",
                "eos_token", "impl", "seed", "fused", "packed",
                "prefill_chunk", "decode_chunk", "weight_bits",
                "weight_group", "kv_bits", "deadline_ms", "max_queue",
                "anomaly_retries", "spec_k", "spec_draft",
                "spec_draft_bits")
_POLICY_FIELDS = ("deadline_ms", "max_queue", "anomaly_retries")
# dataclass defaults, the comparison fallback for echo fields a snapshot
# written by an older engine does not carry (it ran with the default)
_ECFG_DEFAULTS = {f.name: f.default for f in dataclasses.fields(EngineConfig)}


def _warn(msg: str) -> None:
    print(f"serving.checkpoint: {msg}", file=sys.stderr)


# ---------------------------------------------------------------------------
# request (de)serialisation
# ---------------------------------------------------------------------------

def _req_to_dict(req: Request) -> dict:
    return {"uid": req.uid, "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": req.max_new_tokens,
            "priority": req.priority,
            "output": list(req.output), "done": req.done,
            "status": req.status, "deadline": req.deadline,
            "t_enqueue": req.t_enqueue, "t_admit": req.t_admit,
            "t_first_token": req.t_first_token,
            "t_done": req.t_done}


def _req_from_dict(d: dict) -> Request:
    # .get defaults keep pre-layering (priority-less) snapshots restorable
    return Request(uid=int(d["uid"]),
                   prompt=np.asarray(d["prompt"], np.int32),
                   max_new_tokens=d["max_new_tokens"],
                   priority=int(d.get("priority", 0)),
                   output=list(d["output"]), done=bool(d["done"]),
                   status=d["status"], deadline=float(d["deadline"]),
                   t_enqueue=float(d["t_enqueue"]),
                   t_admit=float(d.get("t_admit", 0.0)),
                   t_first_token=float(d["t_first_token"]),
                   t_done=float(d["t_done"]))


def _engine_arrays(engine: ServingEngine) -> dict[str, np.ndarray]:
    """Every device/host array leaf of the engine, as one flat dict:
    the slot pool's serialization tree (``SlotPool.array_tree``) plus the
    seed-path sampling key.  Leaves are serialised with ``np.asarray``
    (a copy — donation-safe) rather than the executor's ``fetch`` choke
    point so snapshotting never perturbs the host-transfer accounting
    the benchmarks measure."""
    tree = dict(engine.pool.array_tree())
    tree["seed_key"] = engine._key
    return flatten_tree(tree)


def _engine_meta(engine: ServingEngine) -> dict:
    return {
        "version": FORMAT_VERSION,
        "model": engine.cfg.name,
        "engine": {f: getattr(engine.ecfg, f) for f in _ECHO_FIELDS},
        "uid": engine._uid,
        "slot_req": [None if r is None else _req_to_dict(r)
                     for r in engine.slot_req],
        "queue": [_req_to_dict(r) for r in engine.queue],
        "finished": [_req_to_dict(r) for r in engine.finished],
        "failed": [_req_to_dict(r) for r in engine.failed],
        "rejected": [_req_to_dict(r) for r in engine.rejected],
        **engine.pool.meta(),        # prefilling + slot_anomalies
        # adaptive scheduler state (SloScheduler's EWMA stall estimate +
        # deferral counter) — restoring it keeps post-restore admission
        # order identical to the uninterrupted run
        "scheduler": (engine.scheduler.state_dict()
                      if hasattr(engine.scheduler, "state_dict") else {}),
        "counters": {
            "host_transfers": engine.host_transfers,
            "host_bytes": engine.host_bytes,
            "decode_steps": engine.decode_steps,
            "prefill_tokens": engine.prefill_tokens,
            "prefill_time": engine.prefill_time,
            "prefill_calls": engine.prefill_calls,
            "max_stall_tokens": engine.max_stall_tokens,
            "stall_tokens": engine._stall_tokens,
            "checkpoints_written": engine.checkpoints_written,
            "restores": engine.restores,
            "replayed_requests": engine.replayed_requests,
            "spec_steps": engine.spec_steps,
            "spec_drafted": engine.spec_drafted,
            "spec_accepted": engine.spec_accepted,
            "spec_committed": engine.spec_committed,
            "active_slot_hist": {str(k): int(v)
                                 for k, v in engine.active_slot_hist.items()},
        },
    }


def _meta_digest(arrays: dict, meta: dict) -> str:
    """Integrity hash binding the array leaves to the bookkeeping."""
    canon = json.dumps({k: v for k, v in meta.items() if k != "digest"},
                       sort_keys=True)
    return digest_arrays(arrays, extra=canon)


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def save_engine(engine: ServingEngine, ckpt_dir: str, *, keep: int = 3,
                retries: int = 0, backoff_s: float = 0.05,
                sleep=time.sleep) -> str:
    """Snapshot the full engine state atomically; returns the committed
    snapshot path.  ``retries``/``backoff_s`` bound the transient-IO
    retry loop (``repro.ckpt.retry``)."""
    snaps = list_snapshots(ckpt_dir, SNAP_PREFIX)
    nxt = 1 + int(snaps[-1][len(SNAP_PREFIX):]) if snaps else 0
    name = f"{SNAP_PREFIX}{nxt:08d}"
    arrays = _engine_arrays(engine)
    # the snapshot counts itself, so a restore of it reports every
    # snapshot committed on its lineage (increment rolled back on failure)
    engine.checkpoints_written += 1
    meta = _engine_meta(engine)
    meta["digest"] = _meta_digest(arrays, meta)

    def write(tmp: str) -> None:
        save_arrays(os.path.join(tmp, "arrays.npz"), arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)

    try:
        return retry(
            lambda: atomic_save_dir(ckpt_dir, name, write,
                                    prefix=SNAP_PREFIX, keep=keep),
            retries=retries, backoff_s=backoff_s, sleep=sleep)
    except Exception:
        engine.checkpoints_written -= 1
        raise


# ---------------------------------------------------------------------------
# load + integrity walk
# ---------------------------------------------------------------------------

def _load_snapshot(path: str) -> tuple[dict, dict]:
    """(arrays, meta) of one snapshot dir; raises on any corruption —
    unreadable files, version mismatch, or a digest that does not match
    the stored leaves + bookkeeping."""
    arrays = load_arrays(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta.get("version") != FORMAT_VERSION:
        raise ValueError(f"snapshot format v{meta.get('version')} != "
                         f"v{FORMAT_VERSION}")
    if meta.get("digest") != _meta_digest(arrays, meta):
        raise ValueError("integrity digest mismatch (torn or corrupt write)")
    return arrays, meta


def load_newest_intact(ckpt_dir: str) -> tuple[dict, dict, str]:
    """Walk snapshots newest → oldest (the ``LATEST`` pointer first) and
    return the first that passes integrity checks.  A corrupt newest
    snapshot degrades to the previous one with a warning; no intact
    snapshot raises ``FileNotFoundError``."""
    names = list_snapshots(ckpt_dir, SNAP_PREFIX)
    order = list(reversed(names))
    latest = read_latest(ckpt_dir)
    if latest in names:
        order = [latest] + [n for n in order if n != latest]
    if not order:
        raise FileNotFoundError(f"no snapshot in {ckpt_dir}")
    last_err: Optional[Exception] = None
    for name in order:
        try:
            arrays, meta = _load_snapshot(os.path.join(ckpt_dir, name))
            return arrays, meta, name
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            _warn(f"skipping snapshot {name}: {e}")
            last_err = e
    raise FileNotFoundError(
        f"no intact snapshot in {ckpt_dir} (last error: {last_err})")


# ---------------------------------------------------------------------------
# restore + journal replay
# ---------------------------------------------------------------------------

def _check_config(meta: dict, cfg_name: str, ecfg: EngineConfig) -> None:
    if meta["model"] != cfg_name:
        raise ValueError(f"snapshot is of model {meta['model']!r}, "
                         f"restore got {cfg_name!r}")
    for f in _ECHO_FIELDS:
        if f in _POLICY_FIELDS:      # operational policy may change
            continue
        # a snapshot from an engine predating field f ran with its
        # default — compare against that, keeping old snapshots restorable
        snap_val = meta["engine"].get(f, _ECFG_DEFAULTS[f])
        if snap_val != getattr(ecfg, f):
            raise ValueError(
                f"engine config mismatch on {f!r}: snapshot has "
                f"{snap_val!r}, restore got {getattr(ecfg, f)!r} — "
                f"a bit-exact resume needs the snapshot's value")


def read_journal(ckpt_dir: str) -> list[dict]:
    """Parse the admission journal; a torn final line (a crash mid-
    append) is dropped, every complete line before it survives."""
    path = os.path.join(ckpt_dir, JOURNAL)
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                _warn("dropping torn journal tail line")
    return out


def restore_engine(cfg, params, ckpt_dir: str, *,
                   ecfg: Optional[EngineConfig] = None, mesh=None,
                   scheduler=None, replay: bool = True,
                   draft=None) -> ServingEngine:
    """Revive a :class:`ServingEngine` from its newest intact snapshot.

    ``ecfg=None`` rebuilds the engine config from the snapshot's echo
    (default clock); an explicit ``ecfg`` must match the snapshot on
    every field that shapes state or sampling (policy knobs —
    ``deadline_ms``/``max_queue``/``anomaly_retries`` — may differ).
    ``params`` are the caller's weights, exactly as at original
    construction (quantisation re-derives deterministically); they are
    not part of the snapshot.  ``scheduler`` is passed through to the
    revived engine (policy, like the operational knobs, may change
    across a restart — it shapes future admissions, not restored
    state).  With ``replay=True`` journal-tail requests (admitted after
    the snapshot) are resubmitted in uid order, reassigned their
    original uids by the restored counter."""
    arrays, meta, name = load_newest_intact(ckpt_dir)
    if ecfg is None:
        ecfg = EngineConfig(**meta["engine"])
    engine = ServingEngine(cfg, params, ecfg, mesh=mesh, scheduler=scheduler,
                           draft=draft)
    _check_config(meta, engine.cfg.name, engine.ecfg)

    host = any(k.startswith("host/") for k in arrays)
    template = engine.pool.array_template(with_host=host)
    template["seed_key"] = engine._key
    tree = unflatten_tree(template, arrays, cast=False)
    engine._key = jax.device_put(tree.pop("seed_key"))
    engine.pool.load_array_tree(tree)

    engine.slot_req = [None if r is None else _req_from_dict(r)
                       for r in meta["slot_req"]]
    engine.queue = collections.deque(_req_from_dict(r)
                                     for r in meta["queue"])
    engine.finished = [_req_from_dict(r) for r in meta["finished"]]
    engine.failed = [_req_from_dict(r) for r in meta["failed"]]
    engine.rejected = [_req_from_dict(r) for r in meta["rejected"]]
    engine.pool.load_meta(meta["prefilling"], meta["slot_anomalies"])
    engine._uid = int(meta["uid"])
    c = meta["counters"]
    engine.host_transfers = c["host_transfers"]
    engine.host_bytes = c["host_bytes"]
    engine.decode_steps = c["decode_steps"]
    engine.prefill_tokens = c["prefill_tokens"]
    engine.prefill_time = c["prefill_time"]
    engine.prefill_calls = c["prefill_calls"]
    engine.max_stall_tokens = c["max_stall_tokens"]
    engine._stall_tokens = c["stall_tokens"]
    engine.checkpoints_written = c["checkpoints_written"]
    engine.replayed_requests = c["replayed_requests"]
    # speculative-decoding acceptance counters (.get: absent from
    # snapshots written before the speculative engine existed)
    engine.spec_steps = int(c.get("spec_steps", 0))
    engine.spec_drafted = int(c.get("spec_drafted", 0))
    engine.spec_accepted = int(c.get("spec_accepted", 0))
    engine.spec_committed = int(c.get("spec_committed", 0))
    engine.active_slot_hist = collections.Counter(
        {int(k): int(v) for k, v in c["active_slot_hist"].items()})
    engine.restores = c["restores"] + 1
    # adaptive scheduler state: .get keeps pre-scheduler-state snapshots
    # restorable (their policies start cold, exactly as they used to)
    if hasattr(engine.scheduler, "load_state_dict"):
        engine.scheduler.load_state_dict(meta.get("scheduler", {}))

    if replay:
        tail = sorted((e for e in read_journal(ckpt_dir)
                       if int(e["uid"]) >= engine._uid),
                      key=lambda e: int(e["uid"]))
        for entry in tail:
            req = engine.submit(np.asarray(entry["prompt"], np.int32),
                                entry["max_new_tokens"],
                                priority=int(entry.get("priority", 0)))
            if req.uid != int(entry["uid"]):
                raise RuntimeError(
                    f"journal replay desync: resubmit assigned uid "
                    f"{req.uid}, journal recorded {entry['uid']}")
        engine.replayed_requests += len(tail)
    return engine


# ---------------------------------------------------------------------------
# checkpointer: journal + periodic snapshots around one engine
# ---------------------------------------------------------------------------

class EngineCheckpointer:
    """Admission journal + snapshot writer for one engine.

    Route submits through :meth:`submit` so every accepted request hits
    the append-only journal before it can be lost with the process;
    call :meth:`save` at snapshot boundaries (between ``step()`` calls —
    engine state is only consistent there).  ``every`` > 0 makes
    :meth:`maybe_save` snapshot each time that many engine iterations
    have passed since the last one."""

    def __init__(self, engine: ServingEngine, ckpt_dir: str, *,
                 keep: int = 3, every: int = 0, retries: int = 0,
                 backoff_s: float = 0.05, sleep=time.sleep):
        self.engine, self.ckpt_dir = engine, ckpt_dir
        self.keep, self.every = keep, every
        self.retries, self.backoff_s, self._sleep = retries, backoff_s, sleep
        self._steps_since = 0
        os.makedirs(ckpt_dir, exist_ok=True)

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               *, priority: int = 0) -> Request:
        req = self.engine.submit(prompt, max_new_tokens, priority=priority)
        if req.status != REJECTED:       # shed requests are the caller's
            #                              to retry — never replayed
            with open(os.path.join(self.ckpt_dir, JOURNAL), "a") as f:
                f.write(json.dumps(
                    {"uid": req.uid,
                     "prompt": [int(t) for t in req.prompt],
                     "max_new_tokens": req.max_new_tokens,
                     "priority": req.priority}) + "\n")
                f.flush()
                os.fsync(f.fileno())
        return req

    def save(self) -> str:
        self._steps_since = 0
        return save_engine(self.engine, self.ckpt_dir, keep=self.keep,
                           retries=self.retries, backoff_s=self.backoff_s,
                           sleep=self._sleep)

    def maybe_save(self) -> Optional[str]:
        """Call once per engine iteration; snapshots every ``every``-th."""
        self._steps_since += 1
        if self.every > 0 and self._steps_since >= self.every:
            return self.save()
        return None
