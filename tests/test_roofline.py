"""HLO-text analyzer unit tests against hand-written HLO, plus roofline
term arithmetic, the structured cost_analysis normaliser, and the
walked-HLO-vs-traffic-model byte agreement pin."""
import numpy as np
import pytest

from repro.roofline.analysis import V5E, roofline_terms
from repro.roofline.hlo import analyze_hlo_text, normalize_cost_analysis

HLO_DOT = """
HloModule test

ENTRY %main (a: f32[128,256], b: f32[256,512]) -> f32[128,512] {
  %a = f32[128,256]{1,0} parameter(0)
  %b = f32[256,512]{1,0} parameter(1)
  ROOT %dot = f32[128,512]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops():
    c = analyze_hlo_text(HLO_DOT, num_devices=1)
    assert c.flops == 2.0 * 128 * 512 * 256


HLO_COLLECTIVES = """
HloModule test

ENTRY %main (p: bf16[64,1024]) -> bf16[64,1024] {
  %p = bf16[64,1024]{1,0} parameter(0)
  %ag = bf16[256,1024]{1,0} all-gather(%p), replica_groups=[64,4]<=[256], dimensions={0}
  %ar = bf16[64,1024]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %rs = bf16[16,1024]{1,0} reduce-scatter(%p), replica_groups=[64,4]<=[256], dimensions={0}
  %cp = bf16[64,1024]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  ROOT %out = bf16[64,1024]{1,0} add(%ar, %cp)
}
"""


def test_collective_wire_bytes():
    c = analyze_hlo_text(HLO_COLLECTIVES, num_devices=256)
    bytes_p = 64 * 1024 * 2
    # all-gather: out 4x input over group 4 -> out*(g-1)/g
    assert c.collective_bytes["all-gather"] == 4 * bytes_p * 3 / 4
    # all-reduce over all 256 devices: 2*bytes*(g-1)/g
    assert abs(c.collective_bytes["all-reduce"]
               - 2 * bytes_p * 255 / 256) < 1.0
    # reduce-scatter: in_bytes*(g-1)/g
    assert c.collective_bytes["reduce-scatter"] == bytes_p * 3 / 4
    # collective-permute: out bytes
    assert c.collective_bytes["collective-permute"] == bytes_p


HLO_WHILE = """
HloModule test

%body (x: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %x = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%x), index=0
  %m = f32[64,64]{1,0} get-tuple-element(%x), index=1
  %d = f32[64,64]{1,0} dot(%m, %m), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%ip, %d)
}

%cond (x: (s32[], f32[64,64])) -> pred[] {
  %x = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%x), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (m0: f32[64,64]) -> f32[64,64] {
  %m0 = f32[64,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%zero, %m0)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies_flops():
    """cost_analysis counts loop bodies once; ours multiplies by the trip
    count parsed from the condition — the scan-over-layers fix."""
    c = analyze_hlo_text(HLO_WHILE, num_devices=1)
    one_iter = 2.0 * 64 * 64 * 64
    assert c.flops == 12 * one_iter
    assert c.n_while == 1


def test_roofline_terms_math():
    rep = roofline_terms(HLO_DOT, arch="x", shape="y", mesh_name="single",
                         n_devices=4, model_flops=1e9)
    flops = 2.0 * 128 * 512 * 256
    assert np.isclose(rep.compute_s, flops / V5E.peak_flops)
    assert rep.bottleneck in ("compute", "memory", "collective")
    assert rep.step_s == max(rep.compute_s, rep.memory_s, rep.collective_s)
    assert rep.roofline_frac <= 1.0


def test_bottleneck_identification():
    # memory-bound: big operands, tiny flops (no dot at all)
    hlo = """
HloModule t

ENTRY %main (p: f32[4096,4096]) -> f32[4096,4096] {
  %p = f32[4096,4096]{1,0} parameter(0)
  ROOT %f = f32[4096,4096]{1,0} fusion(%p), kind=kLoop, calls=%fc
}
"""
    rep = roofline_terms(hlo, arch="x", shape="y", mesh_name="single",
                         n_devices=1, model_flops=1.0)
    assert rep.bottleneck == "memory"


# ---------------------------------------------------------------------------
# normalize_cost_analysis: the dry-run's structured per-op estimate
# ---------------------------------------------------------------------------

_ZERO_CA = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
            "operand_bytes": {}, "output_bytes": 0.0, "utilization": {}}


def test_normalize_cost_analysis_none_and_empty():
    """A backend with no cost model (None), an empty module ({}), and the
    older-jax empty list all normalise to the same all-zero record."""
    assert normalize_cost_analysis(None) == _ZERO_CA
    assert normalize_cost_analysis({}) == _ZERO_CA
    assert normalize_cost_analysis([]) == _ZERO_CA
    assert normalize_cost_analysis(()) == _ZERO_CA


def test_normalize_cost_analysis_structured():
    ca = {"flops": 1056.0, "bytes accessed": 1152.0,
          "bytes accessed0{}": 640.0, "bytes accessed1{}": 384.0,
          "bytes accessedout{}": 256.0,
          "utilization0{}": 2.0, "utilization1{}": 2.0}
    d = normalize_cost_analysis(ca)
    assert d["flops"] == 1056.0 and d["bytes"] == 1152.0
    assert d["operand_bytes"] == {0: 640.0, 1: 384.0}
    assert d["output_bytes"] == 256.0
    assert d["utilization"] == {0: 2.0, 1: 2.0}
    # older jax wraps the same map in a one-element list
    assert normalize_cost_analysis([ca]) == d


def test_normalize_cost_analysis_missing_keys():
    """Partial maps (some backends omit operand/output breakdowns) fill
    with zeros instead of raising."""
    d = normalize_cost_analysis({"flops": 7.0})
    assert d["flops"] == 7.0
    assert d["bytes"] == 0.0 and d["output_bytes"] == 0.0
    assert d["operand_bytes"] == {} and d["utilization"] == {}
    # unknown keys are ignored, not misparsed as operand entries
    d = normalize_cost_analysis({"bytes accessedout{}": 3.0,
                                 "optimal_seconds": 1.0})
    assert d["output_bytes"] == 3.0 and d["bytes"] == 0.0


# ---------------------------------------------------------------------------
# walked-HLO bytes vs the traffic model: the byte terms the calibration
# plane fits against must be the bytes a compiled dot actually moves
# ---------------------------------------------------------------------------

def _dot_hlo(n: int, k: int, m: int) -> str:
    return f"""
HloModule t

ENTRY %main (x: f16[{n},{k}], w: f16[{k},{m}]) -> f16[{n},{m}] {{
  %x = f16[{n},{k}]{{1,0}} parameter(0)
  %w = f16[{k},{m}]{{1,0}} parameter(1)
  ROOT %dot = f16[{n},{m}]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""


@pytest.mark.parametrize("arch", ["bert-base", "gemma2-9b"])
def test_hlo_bytes_agree_with_traffic_phase_bytes(arch):
    """``traffic.phase_bytes`` for the kqv and score phases must equal the
    walked-HLO bytes of the dots those phases model (in + weights + out of
    ``f16[N,D] @ f16[D,(1+2f)D]`` resp. the ``[D,D]`` out-proj), within a
    pinned 2% — gemma2-9b covers the GQA-shrunk K/V path."""
    from repro.config import get_config
    from repro.core.traffic import (Workload, phase_bytes,
                                    transformer_phases)

    N = 64
    w = Workload.from_config(get_config(arch), seq_len=N)
    D = w.d_model
    fused = round((1 + 2 * w.n_kv_heads / w.n_heads) * D)
    phases = {p.name: p for p in transformer_phases(w)}

    for name, (k_dim, n_dim) in (("kqv", (D, fused)), ("score", (D, D))):
        walked = analyze_hlo_text(_dot_hlo(N, k_dim, n_dim)).bytes_hbm
        # the score phase's QK^T/softmax/.V ride on SM-local buffers; its
        # byte fields are exactly the out-projection dot
        modeled = phase_bytes(phases[name])
        assert walked > 0
        assert abs(walked - modeled) <= 0.02 * modeled, \
            f"{arch}/{name}: HLO walks {walked:.0f}B, traffic models " \
            f"{modeled:.0f}B"
