"""Chiplet device models — paper Table 1 / §4.1.1.

All throughput/energy constants carry their Table-1 (or cited-source)
provenance in comments.  Exactly two free calibration scalars exist in the
whole Plane-B model — ``sm_efficiency`` and ``reram_fill`` — fitted once to
the two Table-4 anchors (see core/simulator.py) and then held fixed for
every figure.
"""
from __future__ import annotations

import dataclasses
from enum import Enum


class ChipletType(str, Enum):
    SM = "SM"
    MC = "MC"
    DRAM = "DRAM"
    RERAM = "ReRAM"
    HOST = "HOST"      # baseline architectures use host chiplets (HAIMA §4.2)
    SRAM = "SRAM"      # HAIMA hybrid plane
    ACU = "ACU"        # TransPIM auxiliary compute units


@dataclasses.dataclass(frozen=True)
class SMChiplet:
    """Volta-class SM chiplet: 10 tensor cores, 1530 MHz (Table 1)."""
    # V100: 640 tensor cores over 80 SMs -> 125 TFLOP/s fp16 => one
    # 10-tensor-core SM chiplet ~ 1.95 TFLOP/s peak [43].
    peak_flops: float = 1.95e12
    sram_bytes: float = (64 + 96) * 1024      # 64KB regfile + 96KB L1
    power_w: float = 3.5                      # Volta SM power share @1530MHz
    area_mm2: float = 7.5


@dataclasses.dataclass(frozen=True)
class MCChiplet:
    """Memory-controller chiplet: 512KB L2, DFI/PHY to one HBM channel."""
    l2_bytes: float = 512 * 1024
    power_w: float = 0.8
    area_mm2: float = 3.2                     # Table 1
    # DFI interface bandwidth matches the HBM channel it fronts.


@dataclasses.dataclass(frozen=True)
class DRAMChiplet:
    """One HBM2 channel: 2GB, 16 banks, 128-bit TSV bus (Table 1/[26])."""
    capacity_bytes: float = 2 << 30
    bw: float = 32e9                          # 256-bit stack / 2 channels [26]
    energy_pj_per_bit: float = 3.9            # HBM2 access energy (VAMPIRE)
    idle_power_w: float = 0.25
    max_temp_c: float = 95.0                  # corruption threshold (§4.3)


@dataclasses.dataclass(frozen=True)
class ReRAMChiplet:
    """ISAAC-style: 16 tiles; tile = 96 crossbars of 128×128, 2-bit cells,
    96 8-bit ADCs, 0.34 W, 0.37 mm² @32 nm (Table 1 [66])."""
    tiles: int = 16
    crossbars_per_tile: int = 96
    xbar_rows: int = 128
    xbar_cols: int = 128
    cell_bits: int = 2
    # one crossbar MVM (128×128 MACs) per 100 ns read cycle [66]
    xbar_ops_per_s: float = 2 * 128 * 128 / 100e-9
    tile_power_w: float = 0.34
    area_mm2_per_tile: float = 0.37
    write_endurance: float = 1e8              # NVM endurance bound [28]
    write_energy_pj_per_bit: float = 2.5

    @property
    def peak_flops(self) -> float:
        return self.tiles * self.crossbars_per_tile * self.xbar_ops_per_s

    @property
    def power_w(self) -> float:
        return self.tiles * self.tile_power_w

    @property
    def weight_capacity_bytes(self) -> float:
        cells = (self.tiles * self.crossbars_per_tile
                 * self.xbar_rows * self.xbar_cols)
        return cells * self.cell_bits / 8


@dataclasses.dataclass(frozen=True)
class NoILink:
    """Interposer link: 1.55 mm / cycle @ 1.2 GHz, GRS @ 32 nm ([7][11])."""
    freq_hz: float = 1.2e9
    width_bits: int = 256
    hop_mm: float = 1.55
    energy_pj_per_bit: float = 1.17           # Nvidia GRS [51]
    router_pj_per_bit: float = 0.52

    @property
    def bw(self) -> float:                    # bytes/s
        return self.freq_hz * self.width_bits / 8


@dataclasses.dataclass(frozen=True)
class HostLink:
    """Host/off-interposer access used by HAIMA/TransPIM softmax paths."""
    bw: float = 16e9                          # PCIe4-ish
    latency_s: float = 2e-6


@dataclasses.dataclass(frozen=True)
class Calibration:
    """The ONLY free scalars in Plane B (fit in simulator.calibrate())."""
    sm_efficiency: float = 0.28               # achieved/peak on attention MVMs
    reram_fill: float = 0.32                  # ReRAM pipeline fill/utilisation


SM = SMChiplet()
MC = MCChiplet()
DRAM = DRAMChiplet()
RERAM = ReRAMChiplet()
LINK = NoILink()
HOST_LINK = HostLink()

# Dimensional-utilisation saturation points (structural constants, not
# fitted — see simulator.py): achieved/peak grows ~linearly with the
# stationary operand dim until these saturate.
SM_SAT_DIM = 4096       # Volta tensor-pipeline depth × MMA tile width
RERAM_SAT_DIM = 16384   # 128 crossbar columns × 128-wide tile groups

# Table 2: resource allocation per system size
SYSTEM_ALLOC = {
    36: {"SM": 20, "MC": 4, "DRAM": 4, "ReRAM": 8},
    64: {"SM": 36, "MC": 6, "DRAM": 6, "ReRAM": 16},
    100: {"SM": 64, "MC": 8, "DRAM": 8, "ReRAM": 20},
}

# HBM2 tiers per system size (§4.1.1)
HBM_TIERS = {36: 2, 64: 3, 100: 4}
