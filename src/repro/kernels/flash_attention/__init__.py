from repro.kernels.flash_attention.ops import attention  # noqa: F401
from repro.kernels.flash_attention.decode import flash_decode_fwd  # noqa: F401
