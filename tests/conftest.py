"""Shared fixtures.  NOTE: no global XLA_FLAGS here — in-process tests see
the container's single CPU device; multi-device tests go through
subprocesses (tests/test_multidevice.py) with their own env."""
import os
import sys

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("REPRO_EXTRA_XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env
