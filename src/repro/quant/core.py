"""Quantization plane: int8 / packed-int4 weights and quantized KV rows.

The paper's ReRAM PIM chiplets are low-precision compute by construction
(2-bit cells, bit-sliced weights), and the serving workloads it targets are
memory-bound: weight re-streaming and KV-cache reads dominate decode fabric
bytes (97–99% in the Plane-B generation model).  Quantization is the lever
that shrinks exactly those bytes, so this module is the single source of
truth for every quantised representation in the repo:

- **weights** — weight-only symmetric quantisation to int8 or packed int4
  with per-output-channel scales (optionally per-``group`` rows of the
  contraction dim).  :class:`QuantTensor` is a pytree, so quantised params
  ride through ``jax.jit``/``lax.scan``/donation like any other leaf;
- **KV rows** — per-(token, head) symmetric scales, quantised when a row is
  committed to the slot pool and dequantised on read
  (:mod:`repro.models.attention` / the Pallas decode kernel);
- **crossbar tiles** — ``quantize_weights``, the 128×128 per-crossbar-tile
  int8 quantiser the PIM-MVM kernel programs its arrays with (moved here
  from ``kernels/pim_mvm/ops.py``; that module re-exports it).

Packed int4 stores two codes per int8 byte as *adjacent pairs* along the
packing axis (code ``2i`` in the low nibble, ``2i+1`` in the high nibble),
so any contiguous block of packed rows maps to a contiguous block of
original rows — the property the blocked Pallas kernels rely on to unpack
tiles in VMEM.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

XBAR = 128          # crossbar dimension == MXU tile (pim_mvm contract)
QMAX = {8: 127, 4: 7}
WEIGHT_BITS = (0, 4, 8)   # 0 = native fp
KV_BITS = (0, 4, 8)

# parameter-tree keys eligible for weight-only quantisation: the dense
# projection matmuls (attention q/k/v/out, MLP, lm_head).  Routers, norms,
# biases, embeddings, MoE expert banks (einsum over a leading expert axis)
# and MLA factor tensors stay fp.
QUANT_PARAM_KEYS = frozenset(
    {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "lm_head"})


# ---------------------------------------------------------------------------
# int4 packing
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int4 codes (int8 values in [-8, 7]) two-per-byte along ``axis``
    as adjacent pairs: byte ``i`` holds code ``2i`` (low nibble) and code
    ``2i+1`` (high nibble).  The axis length must be even."""
    c = jnp.moveaxis(codes, axis, -1)
    if c.shape[-1] % 2:
        raise ValueError(f"pack axis length {c.shape[-1]} must be even")
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    packed = (lo & jnp.int8(0x0F)) | jnp.left_shift(hi, 4).astype(jnp.int8)
    return jnp.moveaxis(packed.astype(jnp.int8), -1, axis)


def unpack_int4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_int4` — sign-extending nibble unpack."""
    p = jnp.moveaxis(packed, axis, -1)
    lo = jnp.right_shift(jnp.left_shift(p, 4), 4)      # arithmetic: sign-ext
    hi = jnp.right_shift(p, 4)
    c = jnp.stack([lo, hi], axis=-1).reshape(p.shape[:-1] + (p.shape[-1] * 2,))
    return jnp.moveaxis(c.astype(jnp.int8), -1, axis)


# ---------------------------------------------------------------------------
# weight-only quantisation
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantTensor:
    """A quantised (..., K, N) weight matrix.

    ``q``     — int8 codes; for ``bits=4`` two codes per byte packed along
                the contraction axis (shape (..., K/2, N));
    ``scale`` — f32 scales, (..., 1, N) per-channel or (..., K/group, N);
    ``bits``  — 8 or 4 (static aux data);
    ``group`` — rows of K per scale group (0 = one scale per column).

    Registered as a pytree so quantised params flow through jit / scan /
    vmap / donation; slicing via ``tree_map(lambda l: l[i])`` slices codes
    and scales coherently (the stacked-layer access pattern of
    ``models/transformer.run_stack``).
    """
    q: jax.Array
    scale: jax.Array
    bits: int
    group: int = 0

    @property
    def k_dim(self) -> int:
        """Original contraction length K (codes are packed for int4)."""
        return self.q.shape[-2] * (2 if self.bits == 4 else 1)

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.group)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], bits=aux[0], group=aux[1])


def quantize(w: jax.Array, bits: int = 8, *, group: int = 0) -> QuantTensor:
    """Symmetric weight-only quantisation of a (..., K, N) matrix.

    One scale per output channel (column of N), or per ``group`` rows of K
    per channel when ``group`` divides K.  ``bits=4`` packs the codes along
    K (which must be even)."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    K = w.shape[-2]
    if group and K % group:
        raise ValueError(f"group {group} must divide K {K}")
    if bits == 4 and K % 2:
        raise ValueError(f"int4 packing needs even K, got {K}")
    qmax = QMAX[bits]
    wf = w.astype(jnp.float32)
    if group:
        g = wf.reshape(wf.shape[:-2] + (K // group, group, wf.shape[-1]))
        scale = jnp.max(jnp.abs(g), axis=-2) / qmax          # (..., K/g, N)
        scale = jnp.maximum(scale, 1e-12)
        expand = jnp.repeat(scale, group, axis=-2)
    else:
        scale = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / qmax
        scale = jnp.maximum(scale, 1e-12)                    # (..., 1, N)
        expand = scale
    codes = jnp.clip(jnp.round(wf / expand), -qmax, qmax).astype(jnp.int8)
    if bits == 4:
        codes = pack_int4(codes, axis=-2)
    return QuantTensor(codes, scale, bits=bits, group=group)


def dequantize(qt: QuantTensor) -> jax.Array:
    """(..., K, N) f32 reconstruction of a :class:`QuantTensor`."""
    codes = unpack_int4(qt.q, axis=-2) if qt.bits == 4 else qt.q
    if qt.group:
        scale = jnp.repeat(qt.scale, qt.group, axis=-2)
    else:
        scale = qt.scale
    return codes.astype(jnp.float32) * scale


def quantize_params(params, bits: int, *, group: int = 0):
    """Weight-only quantisation of a model parameter tree.

    Replaces every dense projection leaf (``QUANT_PARAM_KEYS``, 2-D at the
    top level or 3-D stacked under a scan group) by a :class:`QuantTensor`;
    everything else — biases, norms, embeddings, routers, MoE expert banks,
    MLA factors — is returned untouched.  Leaves whose contraction dim is
    incompatible (odd K for int4, K not a multiple of ``group``) stay fp
    rather than failing the whole tree."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")

    def visit(path, leaf):
        key = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
        if key not in QUANT_PARAM_KEYS:
            return leaf
        if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        K = leaf.shape[-2]
        g = group if (group and K % group == 0) else 0
        if bits == 4 and K % 2:
            return leaf
        return quantize(leaf, bits, group=g)

    return jax.tree_util.tree_map_with_path(visit, params)


def fake_quantize_params(params, bits: int, *, group: int = 0):
    """Quantise-dequantise round trip of :func:`quantize_params`: the same
    weights the quantised path computes with, materialised back as fp
    leaves.  An fp engine running these params is the exact oracle for the
    quantised engine's weight path (weight-only quantisation changes the
    *values* once, offline — not the arithmetic)."""
    qp = quantize_params(params, bits, group=group)
    return jax.tree_util.tree_map(
        lambda leaf: dequantize(leaf) if isinstance(leaf, QuantTensor) else leaf,
        qp, is_leaf=lambda leaf: isinstance(leaf, QuantTensor))


# ---------------------------------------------------------------------------
# crossbar-tile quantisation (PIM-MVM contract)
# ---------------------------------------------------------------------------

def quantize_weights(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(K, N) float -> (int8 values, (K/128, N/128) f32 per-tile scales).

    Symmetric per-crossbar-tile quantisation: each 128×128 tile gets one
    scale = max|w|/127 — the granularity a bit-sliced crossbar imposes
    (all cells in a crossbar share the DAC/ADC range).
    """
    K, N = w.shape
    if K % XBAR or N % XBAR:
        raise ValueError(f"weights {(K, N)} must tile {XBAR}x{XBAR} crossbars")
    t = w.astype(jnp.float32).reshape(K // XBAR, XBAR, N // XBAR, XBAR)
    t = t.transpose(0, 2, 1, 3)                      # (Kt, Nt, 128, 128)
    scales = jnp.max(jnp.abs(t), axis=(2, 3)) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    q = jnp.round(t / scales[:, :, None, None]).astype(jnp.int8)
    q = q.transpose(0, 2, 1, 3).reshape(K, N)
    return q, scales


# ---------------------------------------------------------------------------
# KV-row quantisation (slot-pool caches)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Quantise KV rows (..., hd) with one symmetric scale per row — the
    per-(token, head) granularity of the slot-pool cache.  Returns
    ``(codes, scale)`` with codes (..., hd) int8, packed to (..., hd/2)
    for ``bits=4``; all-zero rows (empty slots) get the floor scale and
    zero codes, so dequantisation reproduces exact zeros."""
    if bits not in (4, 8):
        raise ValueError(f"kv bits must be 4 or 8, got {bits}")
    qmax = QMAX[bits]
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / qmax, 1e-12)
    codes = jnp.clip(jnp.round(xf / scale[..., None]), -qmax, qmax)
    codes = codes.astype(jnp.int8)
    if bits == 4:
        codes = pack_int4(codes, axis=-1)
    return codes, scale


def dequantize_kv(codes: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`quantize_kv` — (..., hd) f32."""
    c = unpack_int4(codes, axis=-1) if bits == 4 else codes
    return c.astype(jnp.float32) * scale[..., None]


def quantize_kv_cache(cache: dict, bits: int) -> dict:
    """Quantise a freshly-prefilled fp KV cache ``{"k", "v", "pos"}`` into
    the quantised slot-pool layout ``{"k_q", "k_s", "v_q", "v_s", "pos"}``
    (per-(entry, head) scales).  Empty entries are zeros and stay exact."""
    k_q, k_s = quantize_kv(cache["k"], bits)
    v_q, v_s = quantize_kv(cache["v"], bits)
    return {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s,
            "pos": cache["pos"]}


def kv_cache_bits(cache: dict, head_dim: int) -> int:
    """Bit-width of a quantised slot-pool cache, inferred from the packed
    head dim (int4 halves it)."""
    return 4 if cache["k_q"].shape[-1] != head_dim else 8
