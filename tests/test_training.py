"""Training substrate: data determinism, checkpoint bitwise resume, fault
injection/retry, preemption, gradient compression, straggler watchdog."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, ShapeSpec, get_config, reduce_config
from repro.data.pipeline import DataConfig, LMDataPipeline
from repro.launch.mesh import small_mesh
from repro.training import checkpoint as CKPT
from repro.training.compression import (compress_decompress, compressed_bytes,
                                        init_error)
from repro.training.optimizer import OptConfig, adamw_init, adamw_update
from repro.training.trainer import Trainer, TrainerConfig

SMALL_SHAPE = ShapeSpec("smoke", "train", 16, 4)


def _mesh11():
    return small_mesh(1, 1)


def _trainer(tmp_path=None, **kw):
    cfg = reduce_config(get_config("qwen2.5-3b"))
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path) if tmp_path else "",
                         ckpt_every=0, **kw)
    return Trainer(cfg, SMALL_SHAPE, _mesh11(),
                   opt_cfg=OptConfig(warmup_steps=2, total_steps=50),
                   tcfg=tcfg)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_across_constructions():
    c = DataConfig(vocab_size=97, seq_len=12, global_batch=4, seed=3)
    a = LMDataPipeline(c).global_batch_at(7)
    b = LMDataPipeline(c).global_batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_host_sharding_matches_global():
    """Union of per-host shards == the global batch, independent of host
    count (the elastic-resume invariant)."""
    base = dict(vocab_size=101, seq_len=8, global_batch=8, seed=1)
    full = LMDataPipeline(DataConfig(**base)).global_batch_at(5)["tokens"]
    for n_hosts in (2, 4):
        parts = [
            LMDataPipeline(DataConfig(**base, n_hosts=n_hosts, host_id=h)
                           ).batch_at(5)["tokens"]
            for h in range(n_hosts)
        ]
        np.testing.assert_array_equal(np.concatenate(parts), full)


def test_data_steps_differ():
    c = DataConfig(vocab_size=97, seq_len=12, global_batch=2, seed=0)
    p = LMDataPipeline(c)
    assert not np.array_equal(p.global_batch_at(0)["tokens"],
                              p.global_batch_at(1)["tokens"])


def test_data_tokens_in_range():
    c = DataConfig(vocab_size=33, seq_len=64, global_batch=4)
    t = LMDataPipeline(c).global_batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 33


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_clips_gnorm():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    _, _, m = adamw_update({"w": jnp.full(3, 1e6)}, opt, params, cfg)
    assert float(m["gnorm"]) > 1e5  # reported raw norm


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_resume_bitwise(tmp_path):
    t1 = _trainer(tmp_path / "ck")
    t1.run(3)
    t1.save()
    ref = [t1.train_step(t1.pipeline.global_batch_at(t1.step))["loss"]
           for _ in range(2)]

    t2 = _trainer(tmp_path / "ck")          # restores from LATEST (step 3)
    assert t2.step == 3
    got = [t2.train_step(t2.pipeline.global_batch_at(t2.step))["loss"]
           for _ in range(2)]
    assert ref == got, (ref, got)           # bitwise identical continuation


def test_checkpoint_atomic_latest_pointer(tmp_path):
    d = str(tmp_path / "ck")
    params = {"w": np.arange(4, dtype=np.float32)}
    CKPT.save_checkpoint(d, 1, params=params)
    CKPT.save_checkpoint(d, 2, params={"w": np.ones(4, np.float32)})
    assert CKPT.latest_step(d) == 2
    p, _, meta = CKPT.restore_checkpoint(
        d, params_template=jax.eval_shape(lambda: {"w": jnp.zeros(4)}))
    np.testing.assert_array_equal(p["w"], np.ones(4))


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        CKPT.save_checkpoint(d, s, params={"w": np.zeros(1)}, keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    CKPT.save_checkpoint(d, 0, params={"w": np.zeros((2, 2), np.float32)})
    with pytest.raises(ValueError):
        CKPT.restore_checkpoint(
            d, params_template=jax.eval_shape(lambda: {"w": jnp.zeros((3, 3))}))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_step_retry_on_transient_failure():
    t = _trainer(max_retries=2, retry_backoff_s=0.01)
    calls = {"n": 0}

    def flaky(attempt):
        calls["n"] += 1
        if attempt == 0:
            raise RuntimeError("injected executor fault")

    m = t.train_step(t.pipeline.global_batch_at(0), fault_hook=flaky)
    assert m["retries"] == 1
    assert calls["n"] == 2
    assert t.step == 1


def test_step_fails_after_max_retries():
    t = _trainer(max_retries=1, retry_backoff_s=0.01)

    def always(attempt):
        raise RuntimeError("hard fault")

    with pytest.raises(RuntimeError, match="failed after"):
        t.train_step(t.pipeline.global_batch_at(0), fault_hook=always)
    assert t.step == 0  # nothing committed


def test_preemption_triggers_save_and_stop(tmp_path):
    t = _trainer(tmp_path / "ck")
    t.tcfg.ckpt_every = 0
    t.preemption._on_signal(signal.SIGTERM, None)  # simulate delivery
    out = t.run(10)
    assert len(out) == 1                      # stopped at the boundary
    assert CKPT.latest_step(str(tmp_path / "ck")) == 1


def test_straggler_watchdog_counts_slow_steps():
    t = _trainer(slow_step_factor=0.0)        # every step counts as slow
    t.run(3)
    assert t.slow_steps >= 1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_small_error():
    g = {"a": jnp.linspace(-1, 1, 1000).reshape(10, 100)}
    out, err = compress_decompress(g)
    rel = float(jnp.abs(out["a"] - g["a"]).max())
    assert rel < 1.0 / 127 + 1e-6


def test_compression_error_feedback_unbiased():
    """With error feedback, the running sum of compressed grads converges
    to the running sum of true grads (bias cancels)."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros(256)
    comp_sum = jnp.zeros(256)
    err = None
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (256,)) * 0.1
        cg, err = compress_decompress({"g": g}, {"g": err["g"]} if isinstance(err, dict) else None)
        err = {"g": err["g"]}
        true_sum += g
        comp_sum += cg["g"]
    # residual bounded by one quantisation step, not growing with steps
    assert float(jnp.abs(true_sum - comp_sum).max()) < 0.05


def test_compression_wire_bytes_4x_smaller():
    g = {"a": jnp.zeros((1024, 1024), jnp.float32)}
    assert compressed_bytes(g) < 0.3 * 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# trainer end-to-end: loss goes down
# ---------------------------------------------------------------------------

def test_loss_decreases_over_training():
    t = _trainer()
    t.opt_cfg = OptConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    ms = t.run(25)
    first = np.mean([m["loss"] for m in ms[:5]])
    last = np.mean([m["loss"] for m in ms[-5:]])
    assert last < first - 0.05, (first, last)
