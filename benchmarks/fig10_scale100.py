"""Fig. 10: 100-chiplet system, Llama2-7B and GPT-J (billions of params),
chiplet baselines AND the original (3-D monolithic) HAIMA/TransPIM.

Validates: up to ~11.8× latency / ~2.36× energy vs chiplet baselines;
~38× vs the originals; HAIMA-beats-TransPIM crossover at scale.
"""
from repro.config import get_config
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.simulator import simulate_2p5d_hi
from repro.core.traffic import Workload

from benchmarks.common import emit


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for arch in ("llama2-7b", "gpt-j"):
        for n in (64, 256, 1024, 4096):
            w = Workload.from_config(get_config(arch), seq_len=n)
            hi = simulate_2p5d_hi(w, 100)
            ha = simulate_haima_chiplet(w, 100)
            tp = simulate_transpim_chiplet(w, 100)
            ho = simulate_haima_chiplet(w, 100, chiplet=False)
            to = simulate_transpim_chiplet(w, 100, chiplet=False)
            rows.append({
                "arch": arch, "seq_len": n,
                "hi_ms": hi.latency_s * 1e3,
                "haima_gain_x": ha.latency_s / hi.latency_s,
                "transpim_gain_x": tp.latency_s / hi.latency_s,
                "orig_haima_gain_x": ho.latency_s / hi.latency_s,
                "orig_transpim_gain_x": to.latency_s / hi.latency_s,
                "haima_egain_x": ha.energy_j / hi.energy_j,
                "transpim_egain_x": tp.energy_j / hi.energy_j,
            })
    if verbose:
        emit(rows, "fig10: 100-chiplet billion-param models")
    best_lat = max(max(r["haima_gain_x"], r["transpim_gain_x"]) for r in rows)
    best_orig = max(max(r["orig_haima_gain_x"], r["orig_transpim_gain_x"])
                    for r in rows)
    best_en = max(max(r["haima_egain_x"], r["transpim_egain_x"]) for r in rows)
    assert 8.0 <= best_lat <= 14.0, f"paper: up to 11.8x, got {best_lat:.1f}x"
    assert 25.0 <= best_orig <= 50.0, f"paper: ~38x vs originals, got {best_orig:.1f}x"
    assert best_en >= 2.0, f"paper: up to 2.36x energy, got {best_en:.2f}x"
    if verbose:
        print(f"# headline: latency ≤{best_lat:.1f}x | originals ≤{best_orig:.1f}x "
              f"| energy ≤{best_en:.2f}x")
    return rows


if __name__ == "__main__":
    run()
