"""Pallas TPU kernel: fused dequantise-matmul over weight-only quantised
matrices (int8 and packed int4).

Generalises the PIM-MVM crossbar kernel (``kernels/pim_mvm``) from its
fixed 128×128-tile int8 layout to the serving quantisation layout of
:mod:`repro.quant.core`: per-output-channel (or per-K-group) scales and an
optional packed-int4 code plane.  The transferable property is the same —
**fp weights never exist in HBM**: codes stream HBM→VMEM at 1 or 0.5 bytes
per element, are dequantised in VMEM, and accumulate in fp32 on the MXU.

Grid ``(M/bm, N/bn, K/bk)``; the trailing K axis is sequential on TPU so
the fp32 accumulator lives in VMEM scratch across the K sweep.  For int4
the code block is ``(bk/2, bn)`` — adjacent-pair packing along K keeps a
contiguous packed block ↔ contiguous original rows, so the in-VMEM unpack
is a local nibble split + row interleave.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.core import unpack_int4


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _qmm_kernel(x_ref, q_ref, s_ref, o_ref, acc_scr, *,
                n_k: int, bits: int, group: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)               # (bm, bk)
    q = q_ref[...]                                   # int8 codes (packed?)
    # adjacent-pair nibble unpack along K (repro.quant.core contract)
    codes = unpack_int4(q, axis=0) if bits == 4 else q   # (bk, bn)
    s = s_ref[...].astype(jnp.float32)               # (bk/g | 1, bn)
    if group:
        s = jnp.repeat(s, group, axis=0)             # (bk, bn)
    w = codes.astype(jnp.float32) * s                # in-VMEM dequant
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def quant_matmul_pallas(x, q, scale, *, bits: int, group: int = 0,
                        bm: int = 128, bn: int = 256, bk: int = 512,
                        interpret: bool = False):
    """x (M, K) · dequant(q, scale) -> (M, N); output dtype follows x.

    ``q`` is (K, N) int8 or (K/2, N) packed int4; ``scale`` (1, N) f32
    per-channel or (K/group, N) per-group.  Every block must tile exactly
    (the dispatch wrapper falls back to the reference path otherwise).
    """
    pack = 2 if bits == 4 else 1
    M, K = x.shape
    Kq, N = q.shape
    if Kq * pack != K:
        raise ValueError(f"codes {q.shape} do not match K={K} at {bits} bits")
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or K % bk or N % bn:
        raise ValueError(f"dims {(M, K, N)} must divide blocks {(bm, bk, bn)}")
    if group and bk % group:
        raise ValueError(f"group {group} must divide the K block {bk}")
    n_k = K // bk
    sk = (bk // group) if group else 1               # scale rows per block

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=n_k, bits=bits, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pack, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((sk, bn),
                         (lambda i, j, k: (k, j)) if group else
                         (lambda i, j, k: (0, j))),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_vmem((bm, bn))],
        interpret=interpret,
    )(x, q, scale)
