"""Attention layers: MHA/GQA/MQA, local (sliding-window, ring-buffer cache),
cross-attention, and DeepSeek MLA (naive train path + absorbed decode path).

These are the paper's *dynamic* kernels — per-token-changing operands that
the paper routes to the SM/MC/DRAM plane (§3.1).  The sharding plan gives
their activations head-wise placement ("SM cluster"); the inner product
runs through :mod:`repro.kernels.flash_attention`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.models.modules import apply_rope, dense_init, rmsnorm
from repro.parallel import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, cross: bool = False, dtype=jnp.float32):
    D = cfg.d_model
    Hq, Hkv, hd, hdv = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd), dtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (D, Hkv * hdv), dtype),
        "wo": dense_init(ks[3], (Hq * hdv, D), dtype, fan_in=Hq * hdv),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * hdv,), jnp.float32)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def init_mla(key, cfg, *, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    p = {
        "wkv_a": dense_init(ks[0], (D, kvr + dr), dtype),
        "kv_norm": jnp.zeros((kvr,), jnp.float32),
        "wkv_b": dense_init(ks[1], (kvr, H, dn + dv), dtype, fan_in=kvr),
        "wo": dense_init(ks[2], (H * dv, D), dtype, fan_in=H * dv),
    }
    if qr:
        p["wq_a"] = dense_init(ks[3], (D, qr), dtype)
        p["q_norm"] = jnp.zeros((qr,), jnp.float32)
        p["wq_b"] = dense_init(ks[4], (qr, H, dn + dr), dtype, fan_in=qr)
    else:
        p["wq"] = dense_init(ks[3], (D, H, dn + dr), dtype)
    return p


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, kind: str, batch: int, kv_len: int, dtype, n_cross: int = 0):
    Hkv, hd, hdv = cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    if cfg.is_mla and kind != "cross":
        return {
            "ckv": jnp.zeros((batch, kv_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, kv_len, cfg.rope_head_dim), dtype),
            "pos": jnp.full((batch, kv_len), -1, jnp.int32),
        }
    if kind == "cross":
        return {
            "k": jnp.zeros((batch, n_cross, Hkv, hd), dtype),
            "v": jnp.zeros((batch, n_cross, Hkv, hdv), dtype),
        }
    cap = kv_len if kind == "global" else min(cfg.window, kv_len)
    return {
        "k": jnp.zeros((batch, cap, Hkv, hd), dtype),
        "v": jnp.zeros((batch, cap, Hkv, hdv), dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def _ring_fill(k, v, positions, cap):
    """Build a ring cache holding the last ``cap`` of S prefilled tokens."""
    B, S = k.shape[0], k.shape[1]
    keep = min(S, cap)
    pos_tail = positions[:, S - keep:]               # (B, keep)
    slots = pos_tail % cap
    bidx = jnp.arange(B)[:, None]
    kc = jnp.zeros((B, cap) + k.shape[2:], k.dtype).at[bidx, slots].set(k[:, S - keep:])
    vc = jnp.zeros((B, cap) + v.shape[2:], v.dtype).at[bidx, slots].set(v[:, S - keep:])
    pc = jnp.full((B, cap), -1, jnp.int32).at[bidx, slots].set(pos_tail)
    return kc, vc, pc


def _pad_cache(x, cap):
    B, S = x.shape[0], x.shape[1]
    if cap <= S:
        return x
    pad = jnp.zeros((B, cap - S) + x.shape[2:], x.dtype)
    return jnp.concatenate([x, pad], axis=1)


def _pad_pos(pos, cap):
    B, S = pos.shape
    if cap <= S:
        return pos
    return jnp.concatenate([pos, jnp.full((B, cap - S), -1, jnp.int32)], axis=1)


def _ring_write(cache, new_k, new_v, pos):
    """Write one token at per-batch ``pos`` (ring for local, direct for global)."""
    cap = cache["k"].shape[1]
    slot = pos % cap
    bidx = jnp.arange(pos.shape[0])
    return {
        "k": cache["k"].at[bidx, slot].set(new_k[:, 0]),
        "v": cache["v"].at[bidx, slot].set(new_v[:, 0]),
        "pos": cache["pos"].at[bidx, slot].set(pos),
    }


# ---------------------------------------------------------------------------
# apply — standard path
# ---------------------------------------------------------------------------

def apply_attention(
    p,
    x,                       # (B, S, D)
    *,
    cfg,
    kind: str,               # global | local | cross
    mode: str,               # train | prefill | decode
    pos,                     # (B, S) int32 (decode: (B, 1))
    cache=None,
    cross_src=None,          # (B, S_src, D) for cross in train/prefill
    impl: str = "auto",
    causal: bool = True,     # encoder stacks pass False
    kv_cap: int = 0,         # prefill: cache capacity to allocate (>= S)
):
    B, S, D = x.shape
    Hq, Hkv, hd, hdv = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    dt = x.dtype
    causal = causal and kind != "cross"
    window = cfg.window if kind == "local" else 0
    theta = cfg.rope_theta_local if (kind == "local" and cfg.rope_theta_local) else cfg.rope_theta

    q = x @ constrain(p["wq"].astype(dt), "weight_full")
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, S, Hq, hd)

    if kind == "cross":
        if mode == "decode":
            k, v = cache["k"], cache["v"]
            new_cache = cache
        else:
            src = cross_src.astype(dt)
            k = src @ p["wk"].astype(dt)
            v = src @ p["wv"].astype(dt)
            if "bk" in p:
                k = k + p["bk"].astype(dt)
                v = v + p["bv"].astype(dt)
            k = k.reshape(B, -1, Hkv, hd)
            v = v.reshape(B, -1, Hkv, hdv)
            new_cache = {"k": k, "v": v} if mode == "prefill" else None
        q = constrain(q, "act_heads")
        out = flash_attention(q, k, v, causal=False, softcap=cfg.attn_softcap,
                              impl=impl if mode != "decode" else "ref",
                              q_pos=None if mode != "decode" else pos,
                              kv_pos=None, kv_valid=None)
        out = out.reshape(B, S, Hq * hdv) @ p["wo"].astype(dt)
        return out, new_cache

    k = x @ constrain(p["wk"].astype(dt), "weight_full")
    v = x @ constrain(p["wv"].astype(dt), "weight_full")
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hdv)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    q = constrain(q, "act_heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")

    if mode in ("train", "prefill"):
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_softcap, impl=impl)
        new_cache = None
        if mode == "prefill":
            cap = max(kv_cap, S)
            if kind == "local":
                kc, vc, pc = _ring_fill(k, v, pos, min(cfg.window, cap))
                new_cache = {"k": kc, "v": vc, "pos": pc}
            else:
                new_cache = {"k": _pad_cache(k, cap), "v": _pad_cache(v, cap),
                             "pos": _pad_pos(pos, cap)}
    else:  # decode: S == 1 — flash routes to the Pallas decode kernel
        new_cache = _ring_write(cache, k, v, pos[:, 0])
        kv_pos = new_cache["pos"]
        out = flash_attention(
            q, new_cache["k"], new_cache["v"],
            q_pos=pos, kv_pos=kv_pos, kv_valid=kv_pos >= 0,
            causal=causal, window=window, softcap=cfg.attn_softcap, impl=impl)

    out = out.reshape(B, S, Hq * hdv) @ constrain(p["wo"].astype(dt),
                                                  "weight_full")
    return out, new_cache


# ---------------------------------------------------------------------------
# apply — MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(p, x, pos, cfg):
    B, S, _ = x.shape
    dt = x.dtype
    H, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    if "wq_a" in p:
        cq = rmsnorm(x @ p["wq_a"].astype(dt), p["q_norm"])
        q = jnp.einsum("bsr,rhd->bshd", cq, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsD,Dhd->bshd", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, x, pos, cfg):
    dt = x.dtype
    kvr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv_full = x @ p["wkv_a"].astype(dt)
    ckv = rmsnorm(ckv_full[..., :kvr], p["kv_norm"])
    kr = ckv_full[..., kvr:]
    kr = apply_rope(kr[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def apply_mla(p, x, *, cfg, mode, pos, cache=None, impl="auto", kv_cap: int = 0):
    """MLA self-attention.  train/prefill: naive expanded path; decode:
    absorbed latent-space path (the serving memory-traffic optimisation the
    paper's MQA discussion anticipates, §3.2)."""
    B, S, D = x.shape
    dt = x.dtype
    H, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _mla_q(p, x, pos, cfg)
    ckv, kr = _mla_kv_latent(p, x, pos, cfg)

    if mode in ("train", "prefill"):
        kv = jnp.einsum("bsr,rhd->bshd", ckv, p["wkv_b"].astype(dt))
        kv = constrain(kv, "kv_heads")
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))], -1)
        k = constrain(k, "kv_heads")
        v = constrain(v, "kv_heads")
        q = jnp.concatenate([q_nope, q_rope], -1)
        q = constrain(q, "act_heads")
        out = flash_attention(q, k, v, causal=True, scale=scale, impl=impl)
        new_cache = None
        if mode == "prefill":
            cap = max(kv_cap, S)
            new_cache = {"ckv": _pad_cache(ckv, cap), "kr": _pad_cache(kr, cap),
                         "pos": _pad_pos(pos, cap)}
    else:  # decode — absorbed
        bidx = jnp.arange(B)
        slot = pos[:, 0]
        new_cache = {
            "ckv": cache["ckv"].at[bidx, slot].set(ckv[:, 0]),
            "kr": cache["kr"].at[bidx, slot].set(kr[:, 0]),
            "pos": cache["pos"].at[bidx, slot].set(pos[:, 0]),
        }
        ckv_all, kr_all, kv_pos = new_cache["ckv"], new_cache["kr"], new_cache["pos"]
        w_uk = p["wkv_b"][..., :dn].astype(dt)        # (kvr, H, dn)
        w_uv = p["wkv_b"][..., dn:].astype(dt)        # (kvr, H, dv)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                             ckv_all.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                               kr_all.astype(jnp.float32))) * scale
        mask = (kv_pos[:, None, None, :] <= pos[:, None, :, None]) & \
               (kv_pos >= 0)[:, None, None, :]
        logits = jnp.where(mask, logits, -0.7 * float(jnp.finfo(jnp.float32).max))
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        ctx = jnp.einsum("bhqk,bkr->bqhr", w, ckv_all)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv)

    out = out.reshape(B, S, H * dv) @ p["wo"].astype(dt)
    return out, new_cache
