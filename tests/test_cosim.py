"""Decode-aware co-simulation: generation traffic invariants, the
simulate_generation execution model, the energy-accounting fixes, and the
Plane-A → Plane-B bridge (`core/cosim`)."""
import dataclasses

import numpy as np
import pytest

from repro.config import get_config
from repro.core import chiplets as C
from repro.core.cosim import (Episode, EpisodeMix, cosim_mix,
                              generation_objective, generation_phases,
                              mix_from_stats)
from repro.core.noi import evaluate_noi
from repro.core.placement import initial_placement
from repro.core.simulator import _energy, simulate_2p5d_hi, simulate_generation
from repro.core.traffic import (Phase, Workload, decode_step_phases,
                                kv_cache_bytes_per_layer, prefill_phases,
                                total_traffic_bytes, transformer_phases)


def _w(arch, n):
    return Workload.from_config(get_config(arch), seq_len=n)


# ---------------------------------------------------------------------------
# decode-phase traffic invariants
# ---------------------------------------------------------------------------

def test_kv_cache_read_grows_linearly_with_position():
    w = _w("llama2-7b", 64)
    by1 = {p.name: p for p in decode_step_phases(w, 256)}
    by2 = {p.name: p for p in decode_step_phases(w, 512)}
    fixed = w.d_model * w.d_model * 2          # weight stream, pos-independent
    kv1 = by1["score_dec"].dram_bytes - fixed
    kv2 = by2["score_dec"].dram_bytes - fixed
    assert kv2 == pytest.approx(2 * kv1)
    assert kv1 == pytest.approx(kv_cache_bytes_per_layer(w, 256))


def test_gqa_shrinks_kv_traffic_vs_mha():
    dims = dict(name="x", d_model=4096, n_layers=32, d_ff=11008,
                vocab=32000, seq_len=256)
    mha = Workload(n_heads=32, n_kv_heads=32, **dims)
    gqa = Workload(n_heads=32, n_kv_heads=8, **dims)
    mqa = Workload(n_heads=32, n_kv_heads=1, **dims)
    assert kv_cache_bytes_per_layer(gqa, 512) == pytest.approx(
        kv_cache_bytes_per_layer(mha, 512) / 4)
    assert kv_cache_bytes_per_layer(mqa, 512) == pytest.approx(
        kv_cache_bytes_per_layer(mha, 512) / 32)
    # ...and it reaches the score phase's streamed bytes
    s_mha = {p.name: p for p in decode_step_phases(mha, 512)}["score_dec"]
    s_gqa = {p.name: p for p in decode_step_phases(gqa, 512)}["score_dec"]
    assert s_gqa.dram_bytes < s_mha.dram_bytes


def test_decode_phases_cover_decoder_stack_only():
    w = _w("whisper-large-v3", 64)          # 32 enc + 32 dec layers
    assert w.n_enc_layers == 32 and w.n_dec_layers == 32
    by = {p.name: p for p in decode_step_phases(w, 128)}
    assert by["kqv_dec"].repeat == 32
    assert "cross_dec" in by                # enc-dec re-reads the cross-KV
    assert by["cross_dec"].repeat == 32


def test_enc_dec_cross_repeat_follows_decoder_stack():
    """The old ``n_layers // 2`` collapse was only right for symmetric
    stacks; an asymmetric workload must repeat cross per decoder layer."""
    sym = _w("bart-large", 64)              # 12 + 12
    by = {p.name: p for p in transformer_phases(sym)}
    assert by["cross"].repeat == 12
    asym = dataclasses.replace(sym, n_layers=30, n_enc_layers=24)
    by = {p.name: p for p in transformer_phases(asym)}
    assert by["cross"].repeat == 6          # = n_dec_layers, not 30//2


@pytest.mark.parametrize("n_chiplets", sorted(C.SYSTEM_ALLOC))
def test_decode_noi_routes_on_all_system_sizes(n_chiplets):
    w = _w("gemma2-9b", 128)
    p = initial_placement(n_chiplets)
    ev = evaluate_noi(p, decode_step_phases(w, 384))
    assert np.isfinite(ev.mu) and ev.mu > 0
    assert np.isfinite(ev.max_util)
    ev_pre = evaluate_noi(p, prefill_phases(w))
    assert np.isfinite(ev_pre.mu) and ev_pre.mu > 0


def test_prefill_phases_add_kv_writeback_only():
    w = _w("llama2-7b", 256)
    pre = prefill_phases(w)
    assert [p.name for p in pre[:-1]] == [p.name for p in transformer_phases(w)]
    kv = pre[-1]
    assert kv.name == "kv_write"
    assert kv.repeat == w.n_dec_layers
    assert kv.dram_bytes == pytest.approx(kv_cache_bytes_per_layer(w, 256))


# ---------------------------------------------------------------------------
# generation execution model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["2.5D-HI", "HAIMA_chiplet",
                                  "TransPIM_chiplet"])
def test_generation_slower_than_single_pass_same_tokens(arch):
    """Autoregressive generation of P+G tokens can never beat one
    single-pass forward over P+G tokens (decode loses batch amortisation)."""
    from repro.core.baselines import (simulate_haima_chiplet,
                                      simulate_transpim_chiplet)
    sims = {"2.5D-HI": simulate_2p5d_hi,
            "HAIMA_chiplet": simulate_haima_chiplet,
            "TransPIM_chiplet": simulate_transpim_chiplet}
    prompt, gen = 192, 64
    w = _w("llama2-7b", prompt + gen)
    single = sims[arch](w, 64)
    g = simulate_generation(w, 64, prompt, gen, arch=arch)
    assert g.latency_s >= single.latency_s
    assert g.ttft_s < g.latency_s
    assert g.energy_j > 0 and g.decode_step_s > 0


def test_generation_decode_latency_grows_with_position():
    w = _w("llama2-7b", 64)
    short = simulate_generation(w, 64, 64, 32)
    long = simulate_generation(w, 64, 2048, 32)
    assert long.decode_step_s > short.decode_step_s   # bigger KV to stream
    assert long.ttft_s > short.ttft_s


def test_generation_gqa_decodes_faster_than_mha():
    dims = dict(name="x", d_model=4096, n_layers=32, d_ff=11008,
                vocab=32000, seq_len=512)
    mha = Workload(n_heads=32, n_kv_heads=32, **dims)
    mqa = Workload(n_heads=32, n_kv_heads=1, **dims)
    g_mha = simulate_generation(mha, 64, 512, 64)
    g_mqa = simulate_generation(mqa, 64, 512, 64)
    assert g_mqa.decode_step_s < g_mha.decode_step_s
    assert g_mqa.decode_bytes < g_mha.decode_bytes


def test_generation_traffic_split_decode_heavy():
    """Weights re-stream per generated token: with a non-trivial gen length
    decode dominates the fabric traffic — the regime the NoI must serve."""
    w = _w("llama2-7b", 512)
    g = simulate_generation(w, 64, 512, 128)
    assert g.decode_bytes > g.prefill_bytes


# ---------------------------------------------------------------------------
# energy accounting (satellite bugfix)
# ---------------------------------------------------------------------------

def test_energy_background_weighted_by_repeat():
    """DRAM background energy integrates phase time × repeat; the busy /
    background composition is pinned against hand-computed values."""
    alloc = {"SM": 2, "DRAM": 3}
    phases = [Phase("a", repeat=10), Phase("b", repeat=1)]
    times = {"a": 0.5, "b": 2.0}
    busy = {"a": {"SM"}, "b": set()}
    e = _energy(phases, times, alloc, None, busy)
    busy_e = 2 * C.SM.power_w * 0.5 * 10          # SM busy during a × repeat
    background = 3 * C.DRAM.idle_power_w * (0.5 * 10 + 2.0)
    assert e == pytest.approx(busy_e + background)


def test_energy_background_scales_with_depth():
    """A 2× deeper model must carry ≥2× the background DRAM energy (the old
    sum-one-execution-per-phase under-counted this by ~n_layers×)."""
    w12 = _w("bert-base", 64)
    w24 = dataclasses.replace(w12, n_layers=24)
    e12 = simulate_2p5d_hi(w12, 36).energy_j
    e24 = simulate_2p5d_hi(w24, 36).energy_j
    assert e24 > 1.8 * e12


# ---------------------------------------------------------------------------
# Plane-A → Plane-B bridge
# ---------------------------------------------------------------------------

def _fake_stats():
    return {"finished": 4, "prompt_lens": [8, 8, 16, 24],
            "gen_lens": [4, 4, 8, 8], "prefill_chunk": 32, "max_batch": 4}


def test_mix_from_stats_groups_episodes():
    mix = mix_from_stats(_fake_stats())
    assert mix.requests == 4
    assert mix.prefill_chunk == 32 and mix.max_batch == 4
    assert Episode(8, 4, 2) in mix.episodes
    assert mix.prefill_tokens == 8 + 8 + 16 + 24
    assert mix.decode_tokens == 3 + 3 + 7 + 7
    with pytest.raises(ValueError):
        mix_from_stats({"finished": 0})


def test_cosim_mix_reports_all_archs():
    mix = mix_from_stats(_fake_stats())
    rec = cosim_mix("qwen2.5-3b", mix, 36)
    assert set(rec) == {"2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet"}
    for row in rec.values():
        assert row["ttft_s"] > 0 and row["tokens_per_s"] > 0
        assert 0.0 < row["decode_traffic_frac"] < 1.0


def test_generation_objective_is_finite_and_decode_weighted():
    mix = EpisodeMix([Episode(64, 32, 2)])
    objective, mesh_ev, phases = generation_objective("qwen2.5-3b", mix, 36)
    assert np.isfinite(mesh_ev.mu) and mesh_ev.mu > 0
    mu, sigma = objective(initial_placement(36))
    assert np.isfinite(mu) and np.isfinite(sigma)
    # decode phases must dominate the repeat-weighted traffic
    dec = sum(total_traffic_bytes([p]) for p in phases
              if p.name.endswith("_dec"))
    total = sum(total_traffic_bytes([p]) for p in phases)
    assert dec / total > 0.5


def test_generation_phases_scale_with_gen_len():
    one = generation_phases("qwen2.5-3b", EpisodeMix([Episode(64, 8, 1)]))
    two = generation_phases("qwen2.5-3b", EpisodeMix([Episode(64, 64, 1)]))
    assert total_traffic_bytes(two) > total_traffic_bytes(one)


@pytest.mark.parametrize("gen_len,samples", [(11, 4), (8, 4), (64, 3)])
def test_generation_phases_partition_decode_steps_exactly(gen_len, samples):
    """The sampled decode positions must represent exactly gen_len-1 steps
    (rounding must not over/under-weight decode in the MOO objective)."""
    w = _w("qwen2.5-3b", 64)
    mix = EpisodeMix([Episode(64, gen_len, 3)])
    phases = generation_phases("qwen2.5-3b", mix, samples=samples)
    per_layer = w.n_dec_layers * 3                  # repeat × episode count
    kqv_repeats = sum(p.repeat for p in phases if p.name == "kqv_dec")
    assert kqv_repeats == (gen_len - 1) * per_layer


def test_engine_stats_feed_the_bridge():
    """End-to-end: a real (tiny) engine drain produces stats the cosim can
    consume."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np_

    from repro.config import reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0), param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, kv_len=32, max_new_tokens=4))
    rng = np_.random.default_rng(0)
    for plen in (5, 9):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    st = eng.stats()
    assert st["prompt_lens"] == [5, 9] or sorted(st["prompt_lens"]) == [5, 9]
    rec = cosim_from_engine(eng, cfg=get_config("qwen2.5-3b"), n_chiplets=36)
    assert rec["mix"]["requests"] == 2
    assert rec["archs"]["2.5D-HI"]["ttft_s"] > 0
