"""Production mesh construction (assignment §Multi-pod dry-run step 1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod = 16×16 chips (v5e pod, 2-D torus
ICI); multi-pod adds a leading ``pod`` axis (2 pods = 512 chips) for
inter-pod data parallelism over DCN.

The ``sfc_order`` flag applies the paper's space-filling-curve placement
insight to the *device order* used to build the mesh: logical mesh rows
walk the physical 2-D torus along a boustrophedon curve so that ring
collectives over the ``model`` axis are nearest-neighbour (see
core/hetero.py and DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core.sfc import curve_positions


def _mesh_kwargs(n):
    """`axis_types` appeared after jax 0.4.x — pass it only when present
    (Auto is the default behaviour on older versions anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False, sfc_order: str = "") -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    avail = jax.devices()
    if len(avail) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(avail)} — run via "
            f"launch/dryrun.py (which forces 512 host devices) or on real hw")
    devices = np.asarray(avail[:n])
    if sfc_order:
        devices = devices[sfc_device_order(shape, sfc_order)]
    return jax.make_mesh(shape, axes, devices=list(devices),
                         **_mesh_kwargs(len(shape)))


def sfc_device_order(shape, curve: str = "boustrophedon") -> np.ndarray:
    """Permutation of flat device ids so the trailing 2-D (data, model) grid
    enumerates physical chips along ``curve`` on the 16×16 torus."""
    rows, cols = shape[-2], shape[-1]
    pods = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
    pos = curve_positions(curve, cols, rows)        # (rows*cols, 2) (x, y)
    flat = pos[:, 1] * cols + pos[:, 0]             # physical id per curve step
    order = np.concatenate([p * rows * cols + flat for p in range(pods)])
    return order


def small_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Tiny mesh for CPU integration tests (requires forced host devices)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n],
                         **_mesh_kwargs(2))
