"""Analytical latency/energy/EDP simulator for the chiplet architectures (§4).

Execution model (2.5D-HI, §4.2): attention phases run on the SM cluster fed
by MC/DRAM; feed-forward runs on the ReRAM macro; MHA of layer l overlaps
FF of layer l-1 ("the SMs efficiently accelerate MHA computation, and the
ReRAM layer computes the FF layer in parallel"); GPT-J's parallel
formulation (eq. 9) overlaps them within one layer.  Phase times are
max(compute, DRAM streaming, busiest-NoI-link serialisation); energies are
unit busy-power × time + byte-hop NoI energy + DRAM access energy.

Calibration: exactly two scalars for 2.5D-HI (sm_efficiency, reram_fill)
fitted to its two Table-4 anchors (BERT-Base/36 = 50 ms, GPT-J/100 =
143 ms), and two scalars per baseline (throughput eff + bank-parallelism
scale exponent) fitted to that baseline's own Table-4 row (340/975 ms
HAIMA, 210/1435 ms TransPIM); every other figure must *emerge*.  Fitted
values and residuals are reported in EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import chiplets as C
from repro.core.faults import DisconnectedFabric
from repro.core.noi import NoIEval, evaluate_noi, noi_energy, noi_phase_time
from repro.core.placement import Placement, initial_placement
from repro.core.traffic import (Phase, Workload, decode_step_phases,
                                prefill_phases, total_traffic_bytes,
                                transformer_phases)


@dataclasses.dataclass
class SimResult:
    arch: str
    workload: str
    n_chiplets: int
    seq_len: int
    latency_s: float
    energy_j: float
    per_kernel_s: dict
    noi: Optional[NoIEval] = None

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_j


@dataclasses.dataclass
class Calib:
    # Fitted by calibrate() to the Table-4 anchors (python -m repro.core.simulator);
    # residuals reported in EXPERIMENTS.md §Paper-validation.
    sm_efficiency: float = 0.011923    # fitted: 2.5D-HI anchors (50ms/143ms)
    reram_fill: float = 0.00029342     # fitted: 2.5D-HI anchors
    haima_eff: float = 0.0048701      # fitted to HAIMA_chiplet GPT-J anchor
    transpim_eff: float = 0.0045998   # fitted to TransPIM_chiplet GPT-J anchor
    # bank-parallelism scale exponents (dim-util curve shape), fitted to the
    # Table-4 GPT-J/100-chiplet row (975 ms / 1435 ms)
    haima_scale_exp: float = 1.2838
    transpim_scale_exp: float = 0.7141
    # originals: thermally-capped fraction of banks concurrently active
    orig_bank_cap: float = 0.25        # 4-of-16 banks (§4.3 thermal argument)


CALIB = Calib()


def _alloc(n_chiplets: int) -> dict:
    return dict(C.SYSTEM_ALLOC[n_chiplets])


def _phase_noi_times(placement: Placement, phases: list[Phase],
                     scenario=None) -> tuple[list[float], NoIEval]:
    ev = evaluate_noi(placement, phases, scenario=scenario)
    if ev.disconnected:
        raise DisconnectedFabric(
            f"fault scenario {getattr(scenario, 'label', scenario)!r} leaves "
            f"the fabric unable to route required traffic")
    times = []
    for u in ev.per_phase_link_bytes:
        times.append(noi_phase_time(u, ev.link_bw_scale))
    if not times:
        times = [0.0] * len(phases)
    return times, ev


def _energy(phases, times_by_phase, alloc, noi_ev, busy: dict) -> float:
    """busy: phase-name -> set of busy unit types."""
    e = 0.0
    # background term integrates over the *executed* runtime: each phase
    # runs ph.repeat times (summing one execution per phase under-counted
    # the idle-DRAM window by ~n_layers×)
    total_t = sum(times_by_phase.get(ph.name, 0.0) * ph.repeat
                  for ph in phases)
    unit_power = {
        "SM": alloc.get("SM", 0) * C.SM.power_w,
        "MC": alloc.get("MC", 0) * C.MC.power_w,
        "ReRAM": alloc.get("ReRAM", 0) * C.RERAM.power_w,
        "SRAM": alloc.get("SRAM", 0) * 1.2,
        "ACU": alloc.get("ACU", 0) * 0.9,
        "HOST": alloc.get("HOST", 0) * 6.0,
        # DRAM-PIM chiplet actively computing (Aquabolt-XL-class in-bank
        # logic [26]) — distinct from the idle/background term below.
        "DRAM": alloc.get("DRAM", 0) * 1.3,
    }
    for ph in phases:
        t = times_by_phase.get(ph.name, 0.0) * ph.repeat
        # sorted: busy sets are string sets, whose iteration order is
        # hash-randomised per process — summing in a fixed order keeps the
        # energy bit-identical across runs (the regression pins rely on it)
        for unit in sorted(busy.get(ph.name, ())):  # busy power
            e += unit_power.get(unit, 0.0) * t
        e += (ph.dram_bytes * ph.repeat) * 8 * C.DRAM.energy_pj_per_bit * 1e-12
    e += alloc.get("DRAM", 0) * C.DRAM.idle_power_w * total_t  # DRAM background
    if noi_ev is not None:
        e += noi_energy(noi_ev)
    return e


# ---------------------------------------------------------------------------
# 2.5D-HI
# ---------------------------------------------------------------------------

def simulate_2p5d_hi(w: Workload, n_chiplets: int, *,
                     placement: Optional[Placement] = None,
                     calib: Calib = CALIB, scenario=None) -> SimResult:
    alloc = _alloc(n_chiplets)
    placement = placement or initial_placement(n_chiplets)
    phases = transformer_phases(w)
    by_name = {p.name: p for p in phases}
    noi_t, ev = _phase_noi_times(placement, phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t)}

    dram_bw = alloc["DRAM"] * C.DRAM.bw

    # Dimensional utilisation (structural, NOT fitted): achieved fraction of
    # peak grows ~linearly with the stationary operand dimension until the
    # pipeline saturates — fill/drain overhead of the tensor-core pipeline
    # (SM) and of crossbar column groups (ReRAM) is amortised over the
    # contracted dim.  Saturation points: 4096 (SM, Volta pipeline depth ×
    # MMA tile) and 16384 (ReRAM, 128 crossbar columns × 128-wide tiles).
    # The paper's own Table-4 anchors imply this (~1% util @ d=768 vs ~4%
    # @ d=4096); the two calib scalars set the *level*, this sets the shape.
    def sm_rate(dim):
        return (alloc["SM"] * C.SM.peak_flops * calib.sm_efficiency
                * min(1.0, dim / C.SM_SAT_DIM))

    def rer_rate(dim):
        # Weight duplication (§4.1.1) keeps the macro full regardless of
        # the stationary matrix's width: copies of the weights are
        # parallelised across idle crossbars ("prevents any
        # underutilization of ReRAM chiplets"), so — unlike the SM plane —
        # ReRAM throughput is dim-independent; ``reram_fill`` captures the
        # pipeline fill/drain share alone.
        del dim
        return alloc["ReRAM"] * C.RERAM.peak_flops * calib.reram_fill

    def t_attn(name, dim=w.d_model):
        p = by_name[name]
        return max(p.sm_flops / sm_rate(dim),
                   p.dram_bytes / dram_bw,
                   noi_by[name])

    def t_reram(name, dim):
        p = by_name[name]
        return max(p.reram_flops / rer_rate(dim), noi_by[name])

    t_embed = t_reram("embed", w.d_model)
    stage_attn = t_attn("kqv") + t_attn("score")
    if "cross" in by_name:
        stage_attn += t_attn("cross") * by_name["cross"].repeat / max(w.n_layers, 1)
    stage_ff = t_reram("ff", w.d_ff)
    t_head = t_reram("lm_head", min(w.vocab, C.RERAM_SAT_DIM))

    k = w.n_layers
    if w.parallel_mha_ff:  # eq. 9: overlap within the layer
        total = t_embed + k * max(stage_attn, stage_ff) + t_head
    else:  # software pipeline: FF(l-1) under MHA(l)
        total = (t_embed + stage_attn + (k - 1) * max(stage_attn, stage_ff)
                 + stage_ff + t_head)

    per_kernel = {"embed": t_embed, "kqv": t_attn("kqv") * k,
                  "score": t_attn("score") * k, "ff": stage_ff * k,
                  "lm_head": t_head}
    times = {"embed": t_embed, "kqv": t_attn("kqv"), "score": t_attn("score"),
             "ff": stage_ff, "lm_head": t_head}
    if "cross" in by_name:
        times["cross"] = t_attn("cross")
        per_kernel["cross"] = t_attn("cross") * by_name["cross"].repeat
    busy = {"embed": {"ReRAM"}, "kqv": {"SM", "MC"}, "score": {"SM", "MC"},
            "cross": {"SM", "MC"}, "ff": {"ReRAM", "MC"}, "lm_head": {"ReRAM"}}
    energy = _energy(phases, times, alloc, ev, busy)
    return SimResult("2.5D-HI", w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


# ---------------------------------------------------------------------------
# generation episodes (prefill + autoregressive decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GenResult:
    """One generation episode: prefill a prompt, decode ``gen_len`` tokens.

    The first token is sampled from the prefill logits (standard serving
    convention), so TTFT = prefill latency (+ KV-cache write-back) and the
    remaining ``gen_len - 1`` tokens run the decode step.

    ``batch`` models the continuous-batching regime: ``batch`` concurrent
    episodes of the same shape share every decode step (weights stream
    once per step, KV reads sum over the slots).  ``decode_step_s`` is the
    *batched* step latency; per-episode quantities (``latency_s``,
    ``energy_j``, ``prefill_bytes``/``decode_bytes``) are one episode's
    share, so they stay comparable across batch sizes, while
    ``tokens_per_s``/``decode_tok_s`` report system throughput over all
    ``batch`` streams."""
    arch: str
    workload: str
    n_chiplets: int
    prompt_len: int
    gen_len: int
    ttft_s: float
    decode_step_s: float          # mean batched decode-step latency
    latency_s: float              # full episode wall time
    energy_j: float               # per-episode energy (share of the batch)
    prefill_bytes: float          # fabric bytes injected during prefill
    decode_bytes: float           # per-episode decode fabric bytes (share)
    prefill: Optional[SimResult] = None
    noi: Optional[NoIEval] = None  # decode-step NoI at the mid position
    batch: int = 1                # concurrent episodes per decode step

    @property
    def tokens_per_s(self) -> float:
        """System generation throughput: all ``batch`` streams together."""
        return self.batch * self.gen_len / max(self.latency_s, 1e-30)

    @property
    def decode_tok_s(self) -> float:
        """Steady-state decode throughput (ignoring TTFT): the batched
        step emits one token per active slot."""
        return self.batch / max(self.decode_step_s, 1e-30)

    @property
    def energy_per_token_j(self) -> float:
        return self.energy_j / max(self.gen_len, 1)


def _decode_positions(prompt_len: int, gen_len: int, samples: int) -> list[int]:
    """KV positions at which to evaluate the decode step.  Decode runs
    ``gen_len - 1`` steps at positions ``prompt_len … prompt_len+gen_len-2``;
    phase costs are linear in position, so a few samples averaged across the
    range reconstruct the episode sum (max() of linear terms makes this an
    approximation only when the binding bottleneck flips mid-episode)."""
    steps = max(gen_len - 1, 1)
    lo, hi = prompt_len, prompt_len + steps - 1
    n = min(samples, steps)
    if n <= 1:
        return [(lo + hi) // 2]
    return [round(lo + (hi - lo) * i / (n - 1)) for i in range(n)]


_DECODE_BUSY = {"embed_dec": {"ReRAM"}, "kqv_dec": {"SM", "MC"},
                "score_dec": {"SM", "MC"}, "cross_dec": {"SM", "MC"},
                "ff_dec": {"ReRAM", "MC"}, "lm_head_dec": {"ReRAM"}}


def _hi_decode_step(w: Workload, alloc: dict, placement: Placement,
                    kv_pos: int, calib: Calib, batch: int = 1,
                    scenario=None):
    """(step_time_s, step_energy_j, NoIEval) of one 2.5D-HI decode step
    over ``batch`` active slots.

    Same execution model as the single pass (SM attention fed by MC/DRAM,
    FF on the ReRAM macro, layer-l MHA over layer-(l-1) FF pipelining) at
    N=1 per slot, with the KV-cache reads bounding the score phase; the
    weight streams are shared across the batch."""
    phases = decode_step_phases(w, kv_pos, batch)
    noi_t, ev = _phase_noi_times(placement, phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t)}
    by = {p.name: p for p in phases}
    dram_bw = alloc["DRAM"] * C.DRAM.bw

    def sm_rate(dim):
        return (alloc["SM"] * C.SM.peak_flops * calib.sm_efficiency
                * min(1.0, dim / C.SM_SAT_DIM))

    def rer_rate():
        return alloc["ReRAM"] * C.RERAM.peak_flops * calib.reram_fill

    def t_attn(name):
        p = by[name]
        return max(p.sm_flops / sm_rate(w.d_model),
                   p.dram_bytes / dram_bw, noi_by[name])

    def t_reram(name):
        p = by[name]
        return max(p.reram_flops / rer_rate(), noi_by[name])

    times = {"embed_dec": t_reram("embed_dec"), "kqv_dec": t_attn("kqv_dec"),
             "score_dec": t_attn("score_dec"), "ff_dec": t_reram("ff_dec"),
             "lm_head_dec": t_reram("lm_head_dec")}
    stage_attn = times["kqv_dec"] + times["score_dec"]
    if "cross_dec" in by:
        times["cross_dec"] = t_attn("cross_dec")
        stage_attn += times["cross_dec"]
    stage_ff = times["ff_dec"]
    k = max(w.n_dec_layers, 1)
    if w.parallel_mha_ff:
        step = (times["embed_dec"] + k * max(stage_attn, stage_ff)
                + times["lm_head_dec"])
    else:
        step = (times["embed_dec"] + stage_attn
                + (k - 1) * max(stage_attn, stage_ff) + stage_ff
                + times["lm_head_dec"])
    energy = _energy(phases, times, alloc, ev, _DECODE_BUSY)
    return step, energy, ev


def simulate_generation(w: Workload, n_chiplets: int, prompt_len: int,
                        gen_len: int, *, arch: str = "2.5D-HI",
                        placement: Optional[Placement] = None,
                        calib: Calib = CALIB, samples: int = 4,
                        batch: int = 1, scenario=None) -> GenResult:
    """Full generation episode on any of the three architectures.

    TTFT is the calibrated single-pass latency over the prompt plus the
    explicit KV-cache write-back; decode is evaluated at ``samples`` KV
    positions across the episode and averaged (costs are linear in
    position).  ``batch`` runs the decode steps in the continuous-batching
    regime: ``batch`` concurrent same-shape episodes share every step
    (weights stream once per step); ``batch=1`` reproduces the
    single-stream episode bit-identically.  ``scenario`` (a
    ``core.faults.FaultScenario``) degrades the NoI for the whole episode;
    raises ``DisconnectedFabric`` when the surviving fabric cannot route
    the required traffic."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if arch != "2.5D-HI":
        from repro.core import baselines as B  # local import (module cycle)
        fn = {"HAIMA_chiplet": B.simulate_generation_haima,
              "TransPIM_chiplet": B.simulate_generation_transpim}[arch]
        return fn(w, n_chiplets, prompt_len, gen_len, calib=calib,
                  samples=samples, batch=batch, scenario=scenario)

    w = dataclasses.replace(w, seq_len=prompt_len)
    alloc = _alloc(n_chiplets)
    placement = placement or initial_placement(n_chiplets)
    prefill = simulate_2p5d_hi(w, n_chiplets, placement=placement,
                               calib=calib, scenario=scenario)

    # KV write-back rides on top of the calibrated single pass: per-layer
    # commit of the prompt's K/V (or the cross-KV projection) to DRAM
    pre_phases = prefill_phases(w)
    kv_phase = pre_phases[-1]
    kv_noi, kv_ev = _phase_noi_times(placement, [kv_phase], scenario)
    t_kv = max(kv_phase.dram_bytes / (alloc["DRAM"] * C.DRAM.bw), kv_noi[0])
    kv_energy = _energy([kv_phase], {"kv_write": t_kv}, alloc, kv_ev,
                        {"kv_write": {"MC"}})
    ttft = prefill.latency_s + t_kv * kv_phase.repeat

    steps = max(gen_len - 1, 0)
    step_t, step_e, ev = [], [], None
    for pos in _decode_positions(prompt_len, gen_len, samples):
        t, e, ev = _hi_decode_step(w, alloc, placement, pos, calib, batch,
                                   scenario)
        step_t.append(t)
        step_e.append(e)
    decode_step = sum(step_t) / len(step_t)
    # per-episode shares: the batched step's energy/traffic serve `batch`
    # concurrent streams (x / 1 is exact, so batch=1 is bit-identical)
    decode_energy = steps * sum(step_e) / len(step_e) / batch

    mid = _decode_positions(prompt_len, gen_len, 1)[0]
    decode_bytes = (steps * total_traffic_bytes(decode_step_phases(w, mid,
                                                                   batch))
                    / batch)
    return GenResult(
        arch="2.5D-HI", workload=w.name, n_chiplets=n_chiplets,
        prompt_len=prompt_len, gen_len=gen_len, ttft_s=ttft,
        decode_step_s=decode_step, latency_s=ttft + steps * decode_step,
        energy_j=prefill.energy_j + kv_energy + decode_energy,
        prefill_bytes=total_traffic_bytes(pre_phases),
        decode_bytes=decode_bytes, prefill=prefill, noi=ev, batch=batch)


# ---------------------------------------------------------------------------
# calibration (§4 Table-4 anchors; see DESIGN.md §6)
# ---------------------------------------------------------------------------

# Table 4 anchors (ms): the ONLY numbers any free scalar is fitted to.
ANCHORS = {
    "2.5D-HI": (("bert-base", 64, 36, 50.0), ("gpt-j", 64, 100, 143.0)),
    "HAIMA_chiplet": (("bert-base", 64, 36, 340.0),
                      ("gpt-j", 64, 100, 975.0)),
    "TransPIM_chiplet": (("bert-base", 64, 36, 210.0),
                         ("gpt-j", 64, 100, 1435.0)),
}


def _hi_residual(calib: Calib, workloads: dict) -> float:
    r = 0.0
    for arch, n, chips, target_ms in ANCHORS["2.5D-HI"]:
        res = simulate_2p5d_hi(workloads[(arch, n)], chips, calib=calib)
        r += math.log(res.latency_s * 1e3 / target_ms) ** 2
    return r


def calibrate(verbose: bool = False) -> Calib:
    """Fit the free scalars to the Table-4 anchors.

    2.5D-HI: 2 scalars (sm_efficiency, reram_fill) ↔ 2 anchors —
    coarse→fine log-grid search.  Each baseline: 1 throughput scalar ↔ its
    own 36-chiplet anchor — log-bisection (latency is monotone in the
    scalar).  Everything else in Plane B stays at its Table-1 value.
    """
    from repro.config import get_config

    workloads = {(a, n): Workload.from_config(get_config(a), seq_len=n)
                 for a, n, _, _ in (ANCHORS["2.5D-HI"]
                                    + ANCHORS["HAIMA_chiplet"]
                                    + ANCHORS["TransPIM_chiplet"])}

    # --- 2.5D-HI: 2-D log-grid, 3 refinement rounds ----------------------
    lo = (math.log(1e-4), math.log(1e-4))
    hi = (math.log(1.0), math.log(1.0))
    best = (float("inf"), None)
    for _round in range(4):
        g0 = [lo[0] + (hi[0] - lo[0]) * i / 23 for i in range(24)]
        g1 = [lo[1] + (hi[1] - lo[1]) * i / 23 for i in range(24)]
        for a in g0:
            for b in g1:
                c = dataclasses.replace(CALIB, sm_efficiency=math.exp(a),
                                        reram_fill=math.exp(b))
                r = _hi_residual(c, workloads)
                if r < best[0]:
                    best = (r, (a, b))
        (a, b) = best[1]
        da = (hi[0] - lo[0]) / 23
        db = (hi[1] - lo[1]) / 23
        lo, hi = (a - da, b - db), (a + da, b + db)
    sm_eff, fill = math.exp(best[1][0]), math.exp(best[1][1])

    # --- baselines: 2 scalars ↔ 2 anchors each ----------------------------
    # The GPT-J anchor pins the throughput eff (its kqv/ff dims saturate the
    # util curve, so the exponent is inert there); the BERT anchor then pins
    # the bank-parallelism scale exponent.
    def fit_baseline(sim_fn, eff_field: str, exp_field: str, anchors):
        bert_anchor, gptj_anchor = anchors

        def latency_ms(eff, exp, anchor):
            arch, n, chips, _ = anchor
            c = dataclasses.replace(CALIB, **{eff_field: eff, exp_field: exp})
            return sim_fn(workloads[(arch, n)], chips, calib=c).latency_s * 1e3

        lo_e, hi_e = 1e-6, 1.0            # eff ↔ GPT-J (decreasing)
        for _ in range(60):
            mid = math.sqrt(lo_e * hi_e)
            if latency_ms(mid, 1.0, gptj_anchor) > gptj_anchor[3]:
                lo_e = mid
            else:
                hi_e = mid
        eff = math.sqrt(lo_e * hi_e)

        lo_x, hi_x = 0.2, 4.0             # exp ↔ BERT (increasing)
        for _ in range(60):
            mid = 0.5 * (lo_x + hi_x)
            if latency_ms(eff, mid, bert_anchor) < bert_anchor[3]:
                lo_x = mid
            else:
                hi_x = mid
        return eff, 0.5 * (lo_x + hi_x)

    from repro.core import baselines as B  # local import (module cycle)
    haima_eff, haima_exp = fit_baseline(
        B.simulate_haima_chiplet, "haima_eff", "haima_scale_exp",
        ANCHORS["HAIMA_chiplet"])
    transpim_eff, transpim_exp = fit_baseline(
        B.simulate_transpim_chiplet, "transpim_eff", "transpim_scale_exp",
        ANCHORS["TransPIM_chiplet"])

    fitted = Calib(sm_efficiency=sm_eff, reram_fill=fill,
                   haima_eff=haima_eff, transpim_eff=transpim_eff,
                   haima_scale_exp=haima_exp, transpim_scale_exp=transpim_exp,
                   orig_bank_cap=CALIB.orig_bank_cap)
    if verbose:
        print(f"fitted: sm_efficiency={sm_eff:.5g} reram_fill={fill:.5g} "
              f"haima_eff={haima_eff:.5g} haima_scale_exp={haima_exp:.4f} "
              f"transpim_eff={transpim_eff:.5g} "
              f"transpim_scale_exp={transpim_exp:.4f}")
        for arch, n, chips, target in ANCHORS["2.5D-HI"]:
            res = simulate_2p5d_hi(workloads[(arch, n)], chips, calib=fitted)
            print(f"  2.5D-HI {arch} n={n} {chips}c: {res.latency_s*1e3:.1f} ms "
                  f"(anchor {target})")
        for name, fn in (("HAIMA_chiplet", B.simulate_haima_chiplet),
                         ("TransPIM_chiplet", B.simulate_transpim_chiplet)):
            for arch, n, chips, target in ANCHORS[name]:
                res = fn(workloads[(arch, n)], chips, calib=fitted)
                print(f"  {name} {arch} n={n} {chips}c: "
                      f"{res.latency_s*1e3:.1f} ms (anchor {target})")
    return fitted


if __name__ == "__main__":
    calibrate(verbose=True)
