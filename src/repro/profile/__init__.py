"""Measured-cost calibration plane: profile-and-replay (ROADMAP item 4).

``bench``     — deterministic micro-timer for the real Pallas kernels and
                the jitted executor step (warmup/compile split, injectable
                clock, interpret-mode aware on CPU).
``costmodel`` — affine least-squares fits of phase time in the
                ``core.traffic`` byte/FLOP terms, with held-out residuals
                and confidence intervals in a versioned
                ``CalibrationTable``.
``calibrate`` — maps fitted rates onto ``simulator.Calib`` rate constants
                behind an explicit ``calib=`` opt-in, and reports
                analytical-vs-measured error per phase.

The default analytical path is untouched: nothing here runs unless a
caller times kernels and passes the resulting ``Calib`` explicitly.
"""
from repro.profile.bench import (Sample, Timing, executor_samples,
                                 interpret_default, kernel_samples, measure)
from repro.profile.calibrate import (error_bar_rel, measured_calib,
                                     phase_error_report)
from repro.profile.costmodel import (CALIBRATION_VERSION, CalibrationTable,
                                     PhaseFit, build_table, fit_phase,
                                     fit_samples)

__all__ = [
    "Sample", "Timing", "measure", "interpret_default",
    "kernel_samples", "executor_samples",
    "CALIBRATION_VERSION", "PhaseFit", "CalibrationTable",
    "fit_phase", "fit_samples", "build_table",
    "measured_calib", "phase_error_report", "error_bar_rel",
]
