"""Pallas TPU decode-attention kernel: one query token per KV slot.

The serving hot loop is the paper's end-to-end inference term: every decoded
token re-reads the whole KV pool (X-Former §IV, HeTraX §3 both identify this
attention-to-memory traffic as the dominant cost).  The prefill flash kernel
(:mod:`.kernel`) tiles a *long* query block; decode has ``Sq == 1`` per slot,
so the operative constraint is streaming K/V through VMEM exactly once while
the (tiny) query block and the online-softmax state never leave VMEM.

Layout/grid:

- grid ``(B, Hkv, Skv/bk)`` — one program per (slot, KV head, K/V block);
  the trailing axis is sequential on TPU so VMEM scratch carries the
  online-softmax state ``(m, l, acc)`` across the K/V sweep of each slot.
- GQA head-folding: the ``rep = Hq // Hkv`` query heads that share one KV
  head are folded into the *rows* of a single ``(rep, hd)`` query block, so
  the score matmul is one MXU op per block instead of ``rep`` vector ops.
- positions are explicit: ``kv_pos`` is the per-entry token position in the
  slotted pool (``-1`` = empty / invalid entry) and ``q_pos`` the query
  position per slot.  Causality, sliding window, per-slot lengths and
  empty-slot masking all reduce to one mask on ``(q_pos, kv_pos)`` — the
  kernel never assumes entries are ordered, so ring-buffer (local-window)
  caches work unmodified.
- fully-masked slots (empty pool slots in a continuous-batching engine)
  produce exact zeros, not NaN.

``interpret=True`` runs the same kernel body through the Pallas interpreter
so CPU tests exercise the real kernel, not a shadow implementation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.common import NEG_INF, block_size, vmem
from repro.quant.core import unpack_int4


def _decode_mask(qpos_ref, kvpos_ref, window: int):
    """(1, bk) valid+causal(+window) mask from explicit positions."""
    qp = qpos_ref[0, 0]                               # scalar int32
    kp = kvpos_ref[...]                               # (1, bk)
    mask = (kp >= 0) & (kp <= qp)                     # valid + causal
    if window:
        mask &= qp - kp < window
    return mask


def _online_update(q, k, v, mask, m_scr, l_scr, acc_scr, *,
                   scale: float, softcap: float):
    """One K/V block of the online-softmax sweep (shared by the fp and
    quantised-KV decode kernels; operands already dequantised f32)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (rep, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, NEG_INF)                   # (1,bk) -> (rep,bk)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _decode_kernel(
    q_ref,                        # (1, 1, rep, hd)
    k_ref,                        # (1, 1, bk, hd)
    v_ref,                        # (1, 1, bk, hdv)
    qpos_ref,                     # (1, 1)
    kvpos_ref,                    # (1, bk)
    o_ref,                        # (1, 1, rep, hdv)
    m_scr, l_scr, acc_scr,        # VMEM scratch: (rep,1), (rep,1), (rep,hdv)
    *,
    scale: float,
    window: int,
    softcap: float,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    mask = _decode_mask(qpos_ref, kvpos_ref, window)

    # whole block masked (empty slot / outside the window) -> skip the MXU
    @pl.when(jnp.any(mask))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (rep, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, hdv)
        _online_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                       scale=scale, softcap=softcap)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # empty slot -> zeros
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_fwd(
    q: jax.Array,        # (B, 1, Hq, hd)   one query token per slot
    k: jax.Array,        # (B, Skv, Hkv, hd)  slotted KV pool
    v: jax.Array,        # (B, Skv, Hkv, hdv)
    *,
    q_pos: jax.Array,    # (B, 1) int32  query position per slot
    kv_pos: jax.Array,   # (B, Skv) int32  entry position, -1 = empty
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, hdv = v.shape
    if Sq != 1:
        raise ValueError(f"decode kernel needs Sq == 1, got {Sq}")
    rep = Hq // Hkv
    if rep * Hkv != Hq:
        raise ValueError(f"Hq ({Hq}) must be a multiple of Hkv ({Hkv})")
    scale = scale if scale is not None else hd ** -0.5
    bk = block_size(block_k, Skv)
    if Skv % bk:
        raise ValueError(f"block size ({bk}) must divide Skv ({Skv})")

    # fold GQA groups into query-block rows: (B, Hkv, rep, hd)
    qf = q[:, 0].reshape(B, Hkv, rep, hd)
    kt = k.transpose(0, 2, 1, 3)                  # (B, Hkv, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)                  # (B, Hkv, Skv, hdv)
    qp = q_pos.astype(jnp.int32).reshape(B, 1)
    kp = kv_pos.astype(jnp.int32)

    grid = (B, Hkv, Skv // bk)
    kern = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hdv), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hdv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hdv), q.dtype),
        scratch_shapes=[
            vmem((rep, 1)),
            vmem((rep, 1)),
            vmem((rep, hdv)),
        ],
        interpret=interpret,
    )(qf, kt, vt, qp, kp)

    return out.reshape(B, 1, Hq, hdv)


# ---------------------------------------------------------------------------
# quantised-KV variant
# ---------------------------------------------------------------------------

def _decode_quant_kernel(
    q_ref,                        # (1, 1, rep, hd)
    kq_ref,                       # (1, 1, bk, hd')  int8 codes (hd' = hd/pack)
    ks_ref,                       # (1, 1, bk, 1)    f32 per-(entry, head)
    vq_ref,                       # (1, 1, bk, hdv')
    vs_ref,                       # (1, 1, bk, 1)
    qpos_ref,                     # (1, 1)
    kvpos_ref,                    # (1, bk)
    o_ref,                        # (1, 1, rep, hdv)
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    window: int,
    softcap: float,
    kv_bits: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    mask = _decode_mask(qpos_ref, kvpos_ref, window)

    @pl.when(jnp.any(mask))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (rep, hd)
        kq = kq_ref[0, 0]                             # (bk, hd') int8
        vq = vq_ref[0, 0]
        if kv_bits == 4:
            # adjacent-pair nibble unpack along the head dim — the packing
            # contract of repro.quant.core (single source of truth)
            kq = unpack_int4(kq, axis=-1)
            vq = unpack_int4(vq, axis=-1)
        # in-VMEM dequant: the pool streams HBM→VMEM at 1 or 0.5 B/element
        k = kq.astype(jnp.float32) * ks_ref[0, 0].astype(jnp.float32)
        v = vq.astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
        _online_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                       scale=scale, softcap=softcap)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # empty slot -> zeros
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_quant_fwd(
    q: jax.Array,        # (B, 1, Hq, hd)
    k_q: jax.Array,      # (B, Skv, Hkv, hd')  int8 codes (hd' = hd or hd/2)
    k_s: jax.Array,      # (B, Skv, Hkv) f32 per-(entry, head) scales
    v_q: jax.Array,      # (B, Skv, Hkv, hdv')
    v_s: jax.Array,      # (B, Skv, Hkv)
    *,
    kv_bits: int,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over a *quantised* slot pool: same grid, masking and
    online-softmax sweep as :func:`flash_decode_fwd`, but the K/V blocks
    arrive as int8 codes (packed two-per-byte for ``kv_bits=4``) with
    per-(entry, head) scales and are dequantised in VMEM — an fp copy of
    the cache never exists outside the per-block scratch."""
    if kv_bits not in (4, 8):
        raise ValueError(f"kv_bits must be 4 or 8, got {kv_bits}")
    pack = 2 if kv_bits == 4 else 1
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, hdq = k_q.shape
    hdv = v_q.shape[-1] * pack
    if Sq != 1:
        raise ValueError(f"decode kernel needs Sq == 1, got {Sq}")
    if hdq * pack != hd:
        raise ValueError(f"codes head dim {hdq} != {hd} at {kv_bits} bits")
    rep = Hq // Hkv
    if rep * Hkv != Hq:
        raise ValueError(f"Hq ({Hq}) must be a multiple of Hkv ({Hkv})")
    scale = scale if scale is not None else hd ** -0.5
    bk = block_size(block_k, Skv)
    if Skv % bk:
        raise ValueError(f"block size ({bk}) must divide Skv ({Skv})")

    qf = q[:, 0].reshape(B, Hkv, rep, hd)
    kqt = k_q.transpose(0, 2, 1, 3)               # (B, Hkv, Skv, hd')
    vqt = v_q.transpose(0, 2, 1, 3)
    kst = k_s.transpose(0, 2, 1)[..., None].astype(jnp.float32)
    vst = v_s.transpose(0, 2, 1)[..., None].astype(jnp.float32)
    qp = q_pos.astype(jnp.int32).reshape(B, 1)
    kp = kv_pos.astype(jnp.int32)

    grid = (B, Hkv, Skv // bk)
    kern = functools.partial(
        _decode_quant_kernel, scale=scale, window=window, softcap=softcap,
        kv_bits=kv_bits)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hdq), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, 1), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, v_q.shape[-1]),
                         lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, 1), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hdv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hdv), q.dtype),
        scratch_shapes=[
            vmem((rep, 1)),
            vmem((rep, 1)),
            vmem((rep, hdv)),
        ],
        interpret=interpret,
    )(qf, kqt, kst, vqt, vst, qp, kp)

    return out.reshape(B, 1, Hq, hdv)
