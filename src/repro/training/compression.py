"""Gradient compression for the cross-pod data-parallel all-reduce.

int8 block quantisation with **error feedback** (residual carried to the
next step), the standard trick for bandwidth-bound DP over DCN: the pod
axis of the production mesh crosses data-center network, ~25 GB/s/host vs
~50 GB/s/link ICI inside the pod, so compressing the pod-axis all-reduce
4× (fp32→int8) moves the collective roofline term down proportionally.

Usage inside a train step (see launch/train.py --grad-compression):

    grads, err = compress_decompress(grads, err)   # quantise + feedback
    ... psum over 'pod' happens on the int8-rounded values ...

The quantise→dequantise round trip is exact enough that AdamW training
matches uncompressed loss within noise (tests/test_training.py).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

BLOCK = 256  # quantisation block (per-block scale → 1/256 relative error)


def _quant_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp32 leaf -> (int8 blocks, fp32 per-block scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_decompress(grads: Any, err: Optional[Any] = None):
    """Quantise grads to int8 (+error feedback); returns (grads', err').

    ``err`` is the residual pytree from the previous step (None on step 0).
    The returned grads' are the dequantised values — exactly what the
    receiving side of the all-reduce would see — so the train step can be
    tested end-to-end on CPU without a real multi-host network.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale = _quant_leaf(g32)
        deq = _dequant_leaf(q, scale, g32.shape)
        return deq.astype(g.dtype), (g32 - deq)

    if err is None:
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        outs = [one(g, None) for g in flat_g]
    else:
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_e = tdef.unflatten([o[1] for o in outs])
    return new_g, new_e


def init_error(grads_shape: Any):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)


def compressed_bytes(tree) -> int:
    """Wire footprint of the compressed representation (int8 + scales)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = leaf.size
        blocks = -(-n // BLOCK)
        total += n + 4 * blocks
    return total
