"""Shared atomic-checkpoint core for both planes' stateful runtimes.

``training/checkpoint.py`` (params/optimizer snapshots) and
``serving/checkpoint.py`` (live engine state) need the same four
primitives, factored here instead of duplicated:

- **pytree ↔ flat dict** — ``flatten_tree`` / ``unflatten_tree`` join
  ``tree_flatten_with_path`` key paths with ``/`` so any nested
  dict/list pytree round-trips through a single npz archive.
- **dtype-safe npz** — ``save_arrays`` / ``load_arrays``: numpy's npz
  silently stores extension dtypes (ml_dtypes bfloat16 — every serving
  cache leaf) as opaque void records that load back as ``|V2`` garbage,
  so non-native dtypes are viewed as same-width uints for storage and
  the true dtype names ride along in a reserved JSON entry, restored on
  load.  Native dtypes are written as-is (bit-identical either way).
- **integrity digest** — ``digest_arrays``: one sha256 over every leaf's
  (key, shape, dtype, bytes) in sorted key order.  A torn write, a
  bit-flipped block device, or a half-synced network mount shows up as a
  digest mismatch at restore time, not as silently-wrong tokens later.
- **atomic directory commit** — ``atomic_save_dir``: populate a temp
  dir, ``os.replace`` it into place, and update the ``LATEST`` pointer
  file last.  A process dying at *any* instruction leaves the previous
  checkpoint fully restorable; ``read_latest`` validates the pointer
  against the directory it names.

``retry`` wraps transient-failure-prone IO (a flaky network filesystem,
an interrupted syscall) in bounded retries with exponential backoff —
the serving plane layers it over PR 6's anomaly quarantine so a
checkpoint write hiccup degrades to a late snapshot, never a crash.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Callable, Optional

import numpy as np

# reserved key inside the npz for the {leaf key: true dtype name} map —
# leaf keys come from pytree paths joined with "/" and never collide
DTYPE_KEY = "__dtypes__"


# ---------------------------------------------------------------------------
# pytree <-> flat dict-of-arrays
# ---------------------------------------------------------------------------

def flatten_tree(tree) -> dict[str, np.ndarray]:
    """Flatten any pytree into {``/``-joined key path: host ndarray}."""
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        out[key] = np.asarray(leaf)
    return out


def unflatten_tree(template, flat: dict[str, np.ndarray], *,
                   cast: bool = True):
    """Rebuild ``template``'s structure from a flat dict.

    Missing leaves and shape mismatches raise (a checkpoint for a
    different config must fail loudly, not load garbage).  ``cast=True``
    coerces each leaf to the template leaf's dtype (the training-plane
    contract: checkpoints are fp32, the model decides precision);
    ``cast=False`` keeps the stored dtype bit-exactly (the serving-plane
    contract: the pool's quantised int8 codes / f32 scales / bf16 rows
    must come back as written)."""
    import jax

    paths, _ = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {np.shape(tmpl)}")
        leaves.append(arr.astype(tmpl.dtype) if cast else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


# ---------------------------------------------------------------------------
# dtype-safe npz
# ---------------------------------------------------------------------------

def _storage_view(a: np.ndarray) -> tuple[np.ndarray, str]:
    """(npz-safe array, true dtype name).  Extension dtypes (numpy kind
    ``V`` — ml_dtypes bfloat16/fp8) are viewed as same-width uints; npz
    stores them losslessly and ``load_arrays`` views them back."""
    name = a.dtype.name
    if a.dtype.kind == "V":
        return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}
                      [a.dtype.itemsize]), name
    return a, name


def save_arrays(path: str, arrays: dict[str, np.ndarray]) -> None:
    """``np.savez`` with extension-dtype (bf16) round-trip safety."""
    if DTYPE_KEY in arrays:
        raise ValueError(f"leaf key {DTYPE_KEY!r} is reserved")
    stored, dtypes = {}, {}
    for k, a in arrays.items():
        stored[k], dtypes[k] = _storage_view(np.asarray(a))
    stored[DTYPE_KEY] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    np.savez(path, **stored)


def load_arrays(path: str) -> dict[str, np.ndarray]:
    """Inverse of :func:`save_arrays` — true dtypes restored."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    dtypes = {}
    if DTYPE_KEY in flat:
        dtypes = json.loads(flat.pop(DTYPE_KEY).tobytes().decode())
    out = {}
    for k, a in flat.items():
        want = dtypes.get(k, a.dtype.name)
        out[k] = a if a.dtype.name == want else a.view(np.dtype(want))
    return out


def digest_arrays(arrays: dict[str, np.ndarray],
                  extra: Optional[str] = None) -> str:
    """sha256 over every leaf's (key, shape, dtype, bytes), sorted by
    key, plus an optional ``extra`` string (canonicalised metadata) —
    the integrity hash stored beside, and checked against, a snapshot."""
    h = hashlib.sha256()
    for k in sorted(arrays):
        a = np.ascontiguousarray(arrays[k])
        h.update(k.encode())
        h.update(repr((tuple(a.shape), a.dtype.name)).encode())
        h.update(a.tobytes())
    if extra is not None:
        h.update(extra.encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# atomic directory commit + LATEST pointer
# ---------------------------------------------------------------------------

def atomic_save_dir(root: str, name: str,
                    writer: Callable[[str], None], *,
                    prefix: Optional[str] = None, keep: int = 0) -> str:
    """Atomically materialise ``<root>/<name>`` via ``writer(tmp_dir)``.

    The writer populates a ``tmp.<name>`` sibling; one ``os.replace``
    commits the directory and the ``LATEST`` pointer is rewritten last
    (its own tmp + replace) — the commit point.  ``keep`` > 0 garbage-
    collects all but the newest ``keep`` ``prefix``-named siblings."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f"tmp.{name}")
    final = os.path.join(root, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    writer(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(root, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(root, "LATEST"))
    if keep > 0 and prefix:
        gc_dirs(root, prefix, keep, protect=name)
    return final


def read_latest(root: str) -> Optional[str]:
    """Name the ``LATEST`` pointer commits to, or None when there is no
    pointer or it names a directory that does not (yet/anymore) exist."""
    ptr = os.path.join(root, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not name or not os.path.isdir(os.path.join(root, name)):
        return None
    return name


def list_snapshots(root: str, prefix: str) -> list[str]:
    """``prefix``-named checkpoint directories under ``root``, oldest
    first (names must sort chronologically — both planes zero-pad)."""
    if not os.path.isdir(root):
        return []
    return sorted(d for d in os.listdir(root)
                  if d.startswith(prefix)
                  and os.path.isdir(os.path.join(root, d)))


def gc_dirs(root: str, prefix: str, keep: int,
            protect: Optional[str] = None) -> None:
    """Delete all but the newest ``keep`` ``prefix``-dirs (never the one
    named ``protect`` — the snapshot just committed)."""
    names = list_snapshots(root, prefix)
    for d in names[:-keep] if keep > 0 else []:
        if d != protect:
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)


# ---------------------------------------------------------------------------
# transient-failure retry
# ---------------------------------------------------------------------------

def retry(fn: Callable, *, retries: int = 0, backoff_s: float = 0.05,
          exceptions: tuple = (OSError,), sleep: Callable = time.sleep):
    """Run ``fn()``; on a transient failure retry up to ``retries`` times
    with exponential backoff (``backoff_s``, doubling).  The final
    failure re-raises — a persistently broken store must surface, the
    caller (the serving checkpointer) decides whether that is fatal or
    just a missed snapshot.  ``sleep`` is injectable so tests don't
    wait."""
    attempt = 0
    while True:
        try:
            return fn()
        except exceptions:
            if attempt >= retries:
                raise
            sleep(backoff_s * (2 ** attempt))
            attempt += 1
