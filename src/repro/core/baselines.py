"""Baseline architectures (§2, §4.2): HAIMA_chiplet, TransPIM_chiplet, the
original (non-chiplet) HAIMA/TransPIM, and the ReTransformer endurance
analysis (§4.4).

Execution models follow the paper's descriptions:

- **HAIMA_chiplet** [3]: SRAM chiplets compute score (eqs 5-6), DRAM-PIM
  chiplets compute self-attention projections + FF; host chiplets do the
  arithmetic (softmax) → per-layer host round-trips; disintegrated banks
  cause frequent SRAM↔DRAM exchange and contention.
- **TransPIM_chiplet** [2]: all kernels bit-serial row-parallel in DRAM-PIM;
  ACUs do vector reduction + softmax; token-sharing ring broadcast among
  memory chiplets carries activations (simple dataflow, lower energy, but
  per-kernel latency overhead from ACU hand-offs).
- **Originals**: monolithic 3-D PIM stacks whose concurrent bank activation
  is thermally capped (§4.3) — modelled as a fraction of banks active.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import chiplets as C
from repro.core.noi import evaluate_noi, noi_energy, noi_phase_time
from repro.core.placement import Placement, grid_for, initial_placement, mesh_links
from repro.core.simulator import (Calib, CALIB, GenResult, SimResult,
                                  _decode_positions, _energy)
from repro.core.traffic import (BYTES, Phase, Workload, decode_step_phases,
                                kv_cache_bytes_per_layer, total_traffic_bytes,
                                transformer_phases)


def _baseline_placement(n_chiplets: int, kinds: dict) -> Placement:
    """Mesh-linked placement with the baseline's own chiplet mix, placed by
    the same MOO seed layout (iso-chiplet comparison, §4.1.1)."""
    w, h = grid_for(n_chiplets)
    types = []
    for t, cnt in kinds.items():
        types += [t] * cnt
    types += ["DRAM"] * (w * h - len(types))
    return Placement(w, h, types[: w * h], mesh_links(w, h),
                     [i for i, t in enumerate(types[: w * h]) if t == "ReRAM"])


# ---------------------------------------------------------------------------
# HAIMA_chiplet
# ---------------------------------------------------------------------------

def _dim_util(dim: int, exponent: float = 1.0) -> float:
    """Structural dimensional-utilisation curve (same family as 2.5D-HI's,
    see simulator.py): achieved/peak grows with the stationary operand dim
    until the compute saturates.

    ``exponent`` encodes *how parallelism scales with model size* per
    architecture (§4.2):
      - 1.0 — row-width utilisation only (SM/SRAM pipelines; TransPIM's
        token-sharding spreads work by tokens, so weight size buys nothing);
      - 1.5 — HAIMA's DRAM-PIM bank-level parallelism: concurrently
        activated banks grow with the weight footprint (∝ D·F) *and*
        per-bank row utilisation grows with row width (∝ D) — the paper's
        "HAIMA maximizes throughput by activating multiple banks in
        parallel".
    """
    return min(1.0, (dim / C.SM_SAT_DIM) ** exponent)


def _phase_dim(name: str, w: Workload) -> int:
    """Governing parallelism dim per phase for *in-memory* compute.

    Bit-serial row-parallel PIM parallelism is set by the stationary
    matrix's ROW width — d_model for every transformer kernel (FC1 rows =
    D, FC2 activations re-written per token).  This is the structural
    asymmetry behind the paper's Fig-8 "gain is maximum for the FF layer":
    2.5D-HI's ReRAM macro scales with the full F width via weight
    duplication (simulator.py uses d_ff there), while the baselines' PIM
    banks stay row-bound at D ≪ F.
    """
    return w.d_model


# Dynamic-operand write penalty (the paper's central thesis, §3.1/§4.4):
# compute-in-memory arrays must WRITE per-token operands (Q, K, V, score
# rows) into the array before each MVM — bit-(de)serialisation of 16-bit
# dynamic operands costs ~an order of magnitude over weight-stationary
# operation.  2.5D-HI avoids this entirely by running dynamic kernels on
# SM chiplets with fused score+softmax.
DYNAMIC_WRITE_PENALTY = 8.0

# Milder factor for kernels whose *outputs* (not stationary operands) are
# dynamic intermediates that must be written back into banks before the
# next in-memory kernel (TransPIM's K/Q/V → score hand-off): the write-back
# work is ~a quarter of the MAC work at fp16 into bit-serial banks.
KQV_WRITEBACK = 1.25


def _haima_env(n_chiplets: int, calib: Calib, chiplet: bool) -> dict:
    """Chiplet mix, placement and effective rates of the HAIMA_chiplet plane
    (shared between the single-pass and decode-step models)."""
    n_sram = max(n_chiplets // 6, 2)
    n_host = max(n_chiplets // 18, 1)
    n_dram = n_chiplets - n_sram - n_host
    pl = _baseline_placement(n_chiplets,
                             {"SRAM": n_sram, "HOST": n_host, "DRAM": n_dram})
    # DRAM-PIM effective rate: banks × bit-serial MAC rate × calibrated eff.
    bank_rate = 32e9                      # ops/s per chiplet's PIM banks
    cap = 1.0 if chiplet else calib.orig_bank_cap
    return {
        "n_sram": n_sram, "n_host": n_host, "n_dram": n_dram, "pl": pl,
        "pim_rate0": n_dram * bank_rate * 64 * calib.haima_eff * cap,
        "sram_rate0": n_sram * 2.0e12 * calib.haima_eff * 24,
        "alloc": {"SRAM": n_sram, "HOST": n_host, "DRAM": n_dram},
    }


def simulate_haima_chiplet(w: Workload, n_chiplets: int, *,
                           calib: Calib = CALIB,
                           chiplet: bool = True, scenario=None) -> SimResult:
    env = _haima_env(n_chiplets, calib, chiplet)
    n_dram, pl = env["n_dram"], env["pl"]

    # score/softmax spill: the N²·h attention matrix leaves the SRAM plane
    # for the host (softmax) and back (§4.2 — "repeated data exchange with
    # the host"; 2.5D-HI avoids this via fused score+softmax on SMs).
    score_spill = 2.0 * w.seq_len * w.seq_len * w.n_heads * BYTES

    phases = transformer_phases(w)
    # HAIMA adds host round-trips for softmax/arithmetic on every layer and
    # SRAM↔DRAM exchange for the score operands
    for p in phases:
        if p.name == "score":
            p.host_bytes = 2 * w.seq_len * w.d_model * BYTES + score_spill
            p.sm_mc_bytes *= 2.0          # contention paths (§4.2)
        if p.name == "embed":
            # token vectors leave the banks for the compute plane (2.5D-HI
            # keeps this on the contiguous ReRAM macro instead)
            p.sm_mc_bytes += w.seq_len * w.d_model * BYTES
    noi_t_list, ev = _phase_noi_times_baseline(pl, phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t_list)}

    pim_rate0, sram_rate0 = env["pim_rate0"], env["sram_rate0"]

    def host_time(p):
        return (p.host_bytes / C.HOST_LINK.bw
                + (2 * C.HOST_LINK.latency_s if p.host_bytes else 0.0))

    by = {p.name: p for p in phases}

    def t_of(p, rate0, *, exponent=1.5, dyn=1.0):
        rate = rate0 * _dim_util(_phase_dim(p.name, w), exponent) / dyn
        return max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                   p.dram_bytes / (n_dram * C.DRAM.bw)) + host_time(p)

    # weight-stationary kernels on DRAM-PIM: bank-parallelism exponent
    # (fitted to the Table-4 GPT-J anchor — HAIMA activates more banks as
    # the weight footprint grows); score on the SRAM plane: linear
    # row-width util × dynamic-write penalty
    e = calib.haima_scale_exp
    t_embed = t_of(by["embed"], pim_rate0, exponent=e)
    t_kqv = t_of(by["kqv"], pim_rate0, exponent=e)
    t_score = t_of(by["score"], sram_rate0, exponent=1.0,
                   dyn=DYNAMIC_WRITE_PENALTY)
    t_ff = t_of(by["ff"], pim_rate0, exponent=e)
    t_cross = t_of(by["cross"], pim_rate0, exponent=e) if "cross" in by else 0.0
    t_head = t_of(by["lm_head"], pim_rate0, exponent=e)

    k = w.n_layers
    total = t_embed + k * (t_kqv + t_score + t_ff) + t_head  # serialized
    if "cross" in by:
        total += by["cross"].repeat * t_cross

    per_kernel = {"embed": t_embed, "kqv": t_kqv * k, "score": t_score * k,
                  "ff": t_ff * k, "lm_head": t_head}
    times = {"embed": t_embed, "kqv": t_kqv, "score": t_score, "ff": t_ff,
             "lm_head": t_head}
    if "cross" in by:
        times["cross"] = t_cross
        per_kernel["cross"] = t_cross * by["cross"].repeat
    alloc = env["alloc"]
    # per-phase active units: score on the SRAM plane + host softmax; the
    # weight-stationary kernels on DRAM-PIM banks
    busy = {n: ({"SRAM", "HOST"} if n == "score" else {"DRAM"})
            for n in times}
    energy = _energy(phases, times, alloc, ev, busy) * 1.35  # contention (§4.2)
    name = "HAIMA_chiplet" if chiplet else "HAIMA"
    if not chiplet:
        energy *= 1.15
    return SimResult(name, w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


# ---------------------------------------------------------------------------
# TransPIM_chiplet
# ---------------------------------------------------------------------------

ACU_LATENCY = 1.2e-6                 # per-kernel ACU hand-off (§2)
ACU_BW = 25e9                        # ACU vector-unit stream bandwidth


def _transpim_env(n_chiplets: int, calib: Calib, chiplet: bool) -> dict:
    """Chiplet mix, placement and effective rates of the TransPIM_chiplet
    plane (shared between the single-pass and decode-step models)."""
    n_acu = max(n_chiplets // 9, 1)
    n_dram = n_chiplets - n_acu
    pl = _baseline_placement(n_chiplets, {"ACU": n_acu, "DRAM": n_dram})
    bank_rate = 32e9
    cap = 1.0 if chiplet else calib.orig_bank_cap
    return {
        "n_acu": n_acu, "n_dram": n_dram, "pl": pl,
        "pim_rate0": n_dram * bank_rate * 64 * calib.transpim_eff * cap,
        "alloc": {"ACU": n_acu, "DRAM": n_dram},
    }


def simulate_transpim_chiplet(w: Workload, n_chiplets: int, *,
                              calib: Calib = CALIB,
                              chiplet: bool = True,
                              scenario=None) -> SimResult:
    env = _transpim_env(n_chiplets, calib, chiplet)
    n_acu, n_dram, pl = env["n_acu"], env["n_dram"], env["pl"]

    phases = transformer_phases(w)
    ring_bytes = w.seq_len * w.d_model * BYTES
    # softmax runs on the ACUs: the N²·h score matrix crosses bank→ACU→bank
    # (TransPIM "suffers from latency overhead at each kernel" §2)
    acu_spill = 2.0 * w.seq_len * w.seq_len * w.n_heads * BYTES
    for p in phases:
        if p.name in ("kqv", "score"):
            # token-sharing ring broadcast among memory chiplets
            p.sm_mc_bytes += ring_bytes
        if p.name == "score":
            p.sm_mc_bytes += acu_spill
        if p.name == "embed":
            p.sm_mc_bytes += w.seq_len * w.d_model * BYTES
    noi_t_list, ev = _phase_noi_times_baseline(pl, phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t_list)}

    pim_rate0 = env["pim_rate0"]
    acu_latency, acu_bw = ACU_LATENCY, ACU_BW

    by = {p.name: p for p in phases}

    def t_of(p):
        # token-sharding parallelism is ~width-linear (fitted exponent —
        # sub-linear: ring-broadcast overheads grow with row width); score
        # pays the bit-serial dynamic-operand write penalty in-bank; kqv
        # pays a milder write-back factor (K/Q/V are dynamic intermediates
        # bit-serially written into banks for the score phase)
        dyn = 1.0
        if p.name == "score":
            dyn = DYNAMIC_WRITE_PENALTY
        elif p.name == "kqv":
            dyn = KQV_WRITEBACK
        rate = (pim_rate0
                * _dim_util(_phase_dim(p.name, w), calib.transpim_scale_exp)
                / dyn)
        spill_t = (acu_spill / (n_acu * acu_bw)) if p.name == "score" else 0.0
        return (max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                    p.dram_bytes / (n_dram * C.DRAM.bw)) + acu_latency
                + spill_t)

    t = {n: t_of(p) for n, p in by.items()}
    k = w.n_layers
    total = t["embed"] + k * (t["kqv"] + t["score"] + t["ff"]) + t["lm_head"]
    if "cross" in by:
        total += by["cross"].repeat * t["cross"]

    per_kernel = {"embed": t["embed"], "kqv": t["kqv"] * k,
                  "score": t["score"] * k, "ff": t["ff"] * k,
                  "lm_head": t["lm_head"]}
    alloc = env["alloc"]
    busy = {n: ({"ACU", "DRAM"} if n == "score" else {"DRAM"}) for n in t}
    energy = _energy(phases, t, alloc, ev, busy)
    name = "TransPIM_chiplet" if chiplet else "TransPIM"
    if not chiplet:
        energy *= 1.15
    return SimResult(name, w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


def _phase_noi_times_baseline(pl, phases, scenario=None):
    """Baseline NoI evaluation with role aliasing: the traffic model speaks
    SM/MC/DRAM/ReRAM; in the baselines the compute plane is SRAM (HAIMA) or
    the ACUs (TransPIM) and the DRAM-PIM banks are both memory and compute —
    a subset of banks act as the 'MC' heads the many-to-few traffic hits."""
    from repro.core.faults import DisconnectedFabric

    roles = pl.roles()
    aliased = dict(roles)
    aliased["SM"] = roles.get("SRAM", []) + roles.get("ACU", [])
    drams = roles.get("DRAM", [])
    aliased["MC"] = drams[: max(len(drams) // 8, 1)]
    ev = evaluate_noi(pl, phases, roles_override=aliased, scenario=scenario)
    if ev.disconnected:
        raise DisconnectedFabric(
            f"fault scenario {getattr(scenario, 'label', scenario)!r} leaves "
            f"the baseline fabric unable to route required traffic")
    times = ([noi_phase_time(u, ev.link_bw_scale)
              for u in ev.per_phase_link_bytes]
             or [0.0] * len(phases))
    return times, ev


# ---------------------------------------------------------------------------
# generation episodes on the baselines
# ---------------------------------------------------------------------------
#
# Both baselines keep the KV cache inside the DRAM-PIM banks where it was
# computed, so prefill write-back is an intra-bank commit (DRAM access
# energy + bank-bandwidth time, no NoI crossing).  Every decode step still
# has to move the cached K/V to wherever score runs: HAIMA streams it to
# the SRAM plane (and round-trips the softmax through the host), TransPIM
# ring-broadcasts the token state and spills the score row through the
# ACUs — the per-kernel hand-off latencies the paper calls out (§2) are
# paid per generated token, per layer.

def _haima_decode_step(w: Workload, env: dict, kv_pos: int, calib: Calib,
                       batch: int = 1, scenario=None):
    phases = decode_step_phases(w, kv_pos, batch)
    # per-slot 1×P score rows, ×2 ways; the host round-trip latency itself
    # is paid once per step — the batch amortises it
    score_spill = 2.0 * kv_pos * w.n_heads * BYTES * batch
    for p in phases:
        if p.name == "score_dec":
            p.host_bytes = batch * 2 * w.d_model * BYTES + score_spill
            p.sm_mc_bytes *= 2.0          # contention paths (§4.2); the
            # cached K/V itself crosses the DRAM↔SRAM boundary via dram_bytes
        if p.name == "embed_dec":
            p.sm_mc_bytes += batch * w.d_model * BYTES
    noi_t, ev = _phase_noi_times_baseline(env["pl"], phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t)}
    by = {p.name: p for p in phases}

    def host_time(p):
        return (p.host_bytes / C.HOST_LINK.bw
                + (2 * C.HOST_LINK.latency_s if p.host_bytes else 0.0))

    def t_of(p, rate0, *, exponent=1.5, dyn=1.0):
        rate = rate0 * _dim_util(_phase_dim(p.name, w), exponent) / dyn
        return max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                   p.dram_bytes / (env["n_dram"] * C.DRAM.bw)) + host_time(p)

    e = calib.haima_scale_exp
    t = {"embed_dec": t_of(by["embed_dec"], env["pim_rate0"], exponent=e),
         "kqv_dec": t_of(by["kqv_dec"], env["pim_rate0"], exponent=e),
         "score_dec": t_of(by["score_dec"], env["sram_rate0"], exponent=1.0,
                           dyn=DYNAMIC_WRITE_PENALTY),
         "ff_dec": t_of(by["ff_dec"], env["pim_rate0"], exponent=e),
         "lm_head_dec": t_of(by["lm_head_dec"], env["pim_rate0"], exponent=e)}
    if "cross_dec" in by:
        t["cross_dec"] = t_of(by["cross_dec"], env["pim_rate0"], exponent=e)
    k = max(w.n_dec_layers, 1)
    per_layer = t["kqv_dec"] + t["score_dec"] + t["ff_dec"] \
        + t.get("cross_dec", 0.0)
    step = t["embed_dec"] + k * per_layer + t["lm_head_dec"]   # serialized
    busy = {n: ({"SRAM", "HOST"} if n == "score_dec" else {"DRAM"})
            for n in t}
    energy = _energy(phases, t, env["alloc"], ev, busy) * 1.35  # contention
    return step, energy, ev


def _transpim_decode_step(w: Workload, env: dict, kv_pos: int, calib: Calib,
                          batch: int = 1, scenario=None):
    phases = decode_step_phases(w, kv_pos, batch)
    # per-slot token-state broadcast and score-row spill; the per-kernel
    # ACU hand-off latency is paid once per step (batch-amortised)
    ring_bytes = w.d_model * BYTES * batch           # 1 token per slot
    acu_spill = 2.0 * kv_pos * w.n_heads * BYTES * batch  # 1×P rows via ACUs
    for p in phases:
        if p.name in ("kqv_dec", "score_dec"):
            p.sm_mc_bytes += ring_bytes
        if p.name == "score_dec":
            p.sm_mc_bytes += acu_spill
        if p.name == "embed_dec":
            p.sm_mc_bytes += batch * w.d_model * BYTES
    noi_t, ev = _phase_noi_times_baseline(env["pl"], phases, scenario)
    noi_by = {p.name: t for p, t in zip(phases, noi_t)}
    by = {p.name: p for p in phases}

    def t_of(p):
        dyn = 1.0
        if p.name == "score_dec":
            dyn = DYNAMIC_WRITE_PENALTY
        elif p.name == "kqv_dec":
            dyn = KQV_WRITEBACK
        rate = (env["pim_rate0"]
                * _dim_util(_phase_dim(p.name, w), calib.transpim_scale_exp)
                / dyn)
        spill_t = (acu_spill / (env["n_acu"] * ACU_BW)
                   if p.name == "score_dec" else 0.0)
        return (max((p.sm_flops + p.reram_flops) / rate, noi_by[p.name],
                    p.dram_bytes / (env["n_dram"] * C.DRAM.bw)) + ACU_LATENCY
                + spill_t)

    t = {n: t_of(p) for n, p in by.items()}
    k = max(w.n_dec_layers, 1)
    per_layer = t["kqv_dec"] + t["score_dec"] + t["ff_dec"] \
        + t.get("cross_dec", 0.0)
    step = t["embed_dec"] + k * per_layer + t["lm_head_dec"]
    busy = {n: ({"ACU", "DRAM"} if n == "score_dec" else {"DRAM"}) for n in t}
    energy = _energy(phases, t, env["alloc"], ev, busy)
    return step, energy, ev


def _baseline_generation(arch: str, w: Workload, n_chiplets: int,
                         prompt_len: int, gen_len: int, *, calib: Calib,
                         samples: int, prefill_fn, env: dict,
                         step_fn, batch: int = 1,
                         scenario=None) -> GenResult:
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    w = dataclasses.replace(w, seq_len=prompt_len)
    prefill = prefill_fn(w, n_chiplets, calib=calib, scenario=scenario)
    # intra-bank KV commit: bank-bandwidth time + DRAM access energy
    kv_bytes = kv_cache_bytes_per_layer(w, prompt_len) * max(w.n_dec_layers, 1)
    t_kv = kv_bytes / (env["n_dram"] * C.DRAM.bw)
    kv_energy = kv_bytes * 8 * C.DRAM.energy_pj_per_bit * 1e-12
    ttft = prefill.latency_s + t_kv

    steps = max(gen_len - 1, 0)
    step_t, step_e, ev = [], [], None
    for pos in _decode_positions(prompt_len, gen_len, samples):
        t, e, ev = step_fn(w, env, pos, calib, batch, scenario)
        step_t.append(t)
        step_e.append(e)
    decode_step = sum(step_t) / len(step_t)
    # per-episode shares of the batched step (see simulator.GenResult)
    decode_energy = steps * sum(step_e) / len(step_e) / batch
    mid = _decode_positions(prompt_len, gen_len, 1)[0]
    return GenResult(
        arch=arch, workload=w.name, n_chiplets=n_chiplets,
        prompt_len=prompt_len, gen_len=gen_len, ttft_s=ttft,
        decode_step_s=decode_step, latency_s=ttft + steps * decode_step,
        energy_j=prefill.energy_j + kv_energy + decode_energy,
        # the intra-bank KV commit never crosses the fabric, so prefill
        # traffic is the plain forward pass (unlike 2.5D-HI's kv_write)
        prefill_bytes=total_traffic_bytes(transformer_phases(w)),
        decode_bytes=(steps
                      * total_traffic_bytes(decode_step_phases(w, mid, batch))
                      / batch),
        prefill=prefill, noi=ev, batch=batch)


def simulate_generation_haima(w: Workload, n_chiplets: int, prompt_len: int,
                              gen_len: int, *, calib: Calib = CALIB,
                              samples: int = 4, batch: int = 1,
                              scenario=None) -> GenResult:
    env = _haima_env(n_chiplets, calib, chiplet=True)
    return _baseline_generation(
        "HAIMA_chiplet", w, n_chiplets, prompt_len, gen_len, calib=calib,
        samples=samples, prefill_fn=simulate_haima_chiplet, env=env,
        step_fn=_haima_decode_step, batch=batch, scenario=scenario)


def simulate_generation_transpim(w: Workload, n_chiplets: int,
                                 prompt_len: int, gen_len: int, *,
                                 calib: Calib = CALIB,
                                 samples: int = 4, batch: int = 1,
                                 scenario=None) -> GenResult:
    env = _transpim_env(n_chiplets, calib, chiplet=True)
    return _baseline_generation(
        "TransPIM_chiplet", w, n_chiplets, prompt_len, gen_len, calib=calib,
        samples=samples, prefill_fn=simulate_transpim_chiplet, env=env,
        step_fn=_transpim_decode_step, batch=batch, scenario=scenario)


# ---------------------------------------------------------------------------
# ReTransformer endurance analysis (§4.4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EnduranceReport:
    writes_per_cell_per_token: float
    writes_per_encoder: float
    days_to_failure_at_1khz: float
    feasible: bool


def retransformer_endurance(w: Workload) -> EnduranceReport:
    """Quantifies §4.4: KQV intermediates rewrite ReRAM cells ~1e7×/token;
    at N=4096 a single encoder reaches ~1e10 writes — far past the ~1e8
    endurance bound [28]."""
    from repro.core.traffic import rewrites_per_token

    per_tok = rewrites_per_token(w)
    per_encoder = per_tok * w.seq_len
    # token rate 1 kHz: lifetime until endurance bound
    seconds = C.RERAM.write_endurance / max(per_tok, 1e-9) / 1e3
    return EnduranceReport(
        writes_per_cell_per_token=per_tok,
        writes_per_encoder=per_encoder,
        days_to_failure_at_1khz=seconds * 1e3 / 86_400,
        feasible=per_encoder < C.RERAM.write_endurance)
