from repro.serving.engine import EngineConfig, Request, ServingEngine  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    FifoScheduler, Scheduler, SloClass, SloScheduler)
from repro.serving.executor import Executor  # noqa: F401
from repro.serving.pool import SlotPool  # noqa: F401
from repro.serving.checkpoint import (  # noqa: F401
    EngineCheckpointer, restore_engine, save_engine)
from repro.serving.frontend import ServingFrontend, TokenStream  # noqa: F401
from repro.serving.workload import (  # noqa: F401
    Arrival, bursty_arrivals, make_workload, poisson_arrivals,
    synthetic_prompts, trace_arrivals)
