"""Dispatch layer for quantised dense compute.

``qdense`` is the single matmul entry point the model library routes its
dense projections through: a plain fp array behaves exactly as the
pre-quantisation code (cast + optional sharding constraint + ``@``, so the
fp path is bit-identical), a :class:`repro.quant.core.QuantTensor` runs the
fused dequant-matmul — the Pallas kernel on TPU (codes dequantised in VMEM,
fp weights never in HBM), a reference dequant+matmul elsewhere.
"""
from __future__ import annotations

import jax

from repro.parallel import constrain
from repro.quant import kernel as _kernel
from repro.quant.core import QuantTensor, dequantize


def quant_matmul(x: jax.Array, qt: QuantTensor, *, impl: str = "auto"):
    """x (..., K) · dequant(qt (K, N)) -> (..., N), dtype follows x.

    impl: ref | pallas | pallas_interpret | auto (pallas on TPU, else ref).
    Shapes the Pallas grid cannot tile exactly fall back to ref.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl in ("pallas", "pallas_interpret"):
        lead = x.shape[:-1]
        K = x.shape[-1]
        N = qt.scale.shape[-1]
        M = 1
        for d in lead:
            M *= d
        bm, bn, bk = min(128, M), min(256, N), min(512, K)
        tiles = M % bm == 0 and N % bn == 0 and K % bk == 0 \
            and (not qt.group or bk % qt.group == 0)
        if tiles and qt.q.ndim == 2:
            out = _kernel.quant_matmul_pallas(
                x.reshape(M, K), qt.q, qt.scale, bits=qt.bits, group=qt.group,
                bm=bm, bn=bn, bk=bk, interpret=impl == "pallas_interpret")
            return out.reshape(lead + (N,))
    return x @ dequantize(qt).astype(x.dtype)


def qdense(x: jax.Array, w, dt=None, constraint: str | None = None, *,
           impl: str = "auto"):
    """Dense projection that accepts fp weights or a QuantTensor.

    fp: ``x @ constrain(w.astype(dt), constraint)`` — byte-for-byte the
    pre-quantisation path.  QuantTensor: fused dequant-matmul (sharding
    constraints don't apply to code planes; quantised serving runs
    replicated weights).
    """
    if isinstance(w, QuantTensor):
        return quant_matmul(x, w, impl=impl)
    dt = dt if dt is not None else x.dtype
    wf = w.astype(dt)
    if constraint is not None:
        wf = constrain(wf, constraint)
    return x @ wf
