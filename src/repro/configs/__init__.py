"""Architecture registry — importing this package registers every config.

Each module holds exactly one public ``CONFIG`` (or several for the paper's
own workload table) built from the published numbers cited in DESIGN.md.
"""
from repro.configs import (  # noqa: F401
    qwen3_moe_30b_a3b,
    deepseek_v2_236b,
    recurrentgemma_9b,
    whisper_large_v3,
    qwen2_5_3b,
    gemma3_27b,
    gemma2_9b,
    minitron_8b,
    mamba2_130m,
    llama3_2_vision_90b,
    paper_models,
)
