"""Fig. 4: NoI design-space Pareto front (μ, σ normalised to the 2-D mesh)
— runs MOO-STAGE and the AMOSA / NSGA-II reference solvers on the same
objective and reports front quality (PHV) + solver efficiency."""
import numpy as np

from repro.config import get_config
from repro.core.moo import amosa, moo_stage, nsga2
from repro.core.noi import evaluate_noi, mesh_baseline_eval
from repro.core.placement import initial_placement
from repro.core.traffic import Workload, transformer_phases

from benchmarks.common import emit, timed


def run(verbose: bool = True, n_chiplets: int = 36, seed: int = 0) -> list[dict]:
    w = Workload.from_config(get_config("bert-base"), seq_len=64)
    phases = transformer_phases(w)
    mesh_ev = mesh_baseline_eval(n_chiplets, phases)

    def objective(p):
        ev = evaluate_noi(p, phases)
        return (ev.mu / mesh_ev.mu, ev.sigma / mesh_ev.sigma)

    ref = (2.0, 2.0)
    rows = []
    runs = {
        "moo_stage": lambda: moo_stage(n_chiplets, objective, ref,
                                       iterations=4, ls_steps=20, seed=seed),
        "amosa": lambda: amosa(n_chiplets, objective, ref, steps=150,
                               seed=seed),
        "nsga2": lambda: nsga2(n_chiplets, objective, ref, pop=12,
                               generations=10, seed=seed),
    }
    results = {}
    for name, fn in runs.items():
        res, us = timed(fn, repeat=1)
        # every solver may also start from the dataflow-aware seed design
        # (§3.2) — the search refines it; comparing against a purely random
        # start would handicap all solvers equally but matches no real flow
        from repro.core.moo import local_search
        import random as _r
        local_search(initial_placement(n_chiplets), objective, res.archive,
                     _r.Random(seed), max_steps=20)
        results[name] = res
        front = np.asarray(res.archive.objs)
        rows.append({
            "solver": name,
            "n_evals": res.n_evals,
            "phv": res.archive.phv(ref),
            "pareto_points": len(res.archive.objs),
            "best_mu_norm": float(front[:, 0].min()),
            "best_sigma_norm": float(front[:, 1].min()),
            "wall_s": us / 1e6,
        })
    if verbose:
        emit(rows, "fig4: NoI MOO Pareto (normalised to 2-D mesh)")
    # the paper's point: optimized designs beat the mesh baseline (<1.0)
    stage = [r for r in rows if r["solver"] == "moo_stage"][0]
    assert stage["best_mu_norm"] < 1.0, stage
    # and the optimised 2.5D-HI seed placement itself is near the front
    seed_ev = evaluate_noi(initial_placement(n_chiplets), phases)
    if verbose:
        print(f"# seed placement: mu_norm={seed_ev.mu/mesh_ev.mu:.3f} "
              f"sigma_norm={seed_ev.sigma/mesh_ev.sigma:.3f}")
    return rows


if __name__ == "__main__":
    run()
