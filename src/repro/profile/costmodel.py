"""Affine cost models fitted to measured kernel/phase times.

Each phase class (``Sample.kind``) gets a least-squares fit of

    time_s  =  intercept_s  +  term / rate

in one of the ``core.traffic`` regressors (``bytes_term`` or
``flops_term``): the intercept is the launch/dispatch overhead, the
slope's reciprocal is the *effective rate* (bytes/s or FLOP/s) — exactly
the shape of Plane B's analytical phase charges, so fitted rates drop
into ``simulator.Calib`` without unit gymnastics (``profile.calibrate``).

Residual discipline
-------------------
The grid is split deterministically (every third point by term
magnitude is held out), the model is fitted on the rest, and the
held-out relative errors are recorded on the fit.  Those residuals are
the *error bars* every calibrated co-sim claim carries — a
``CalibrationTable`` whose fits have large held-out error is reporting
its own untrustworthiness, not hiding it.  ``rate_ci95_rel`` is the
standard OLS 95% half-width on the slope, relative to the slope.

Tables are versioned (``CALIBRATION_VERSION``); loading a table written
by a different schema version raises instead of silently re-interpreting
stale rates.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

from repro.profile.bench import Sample

__all__ = [
    "CALIBRATION_VERSION", "DEFAULT_TERMS", "PhaseFit", "CalibrationTable",
    "fit_phase", "fit_samples", "build_table",
]

CALIBRATION_VERSION = 1

# primary regressor per phase class: the memory-streaming kinds fit
# against bytes (effective bandwidth), the compute-bound prefill kind
# against FLOPs (effective flop rate)
DEFAULT_TERMS = {
    "decode_attn": "bytes",
    "decode_attn_kv8": "bytes",
    "decode_attn_kv4": "bytes",
    "prefill_attn": "flops",
    "dequant_matmul": "bytes",
    "executor_step": "bytes",
}


@dataclasses.dataclass(frozen=True)
class PhaseFit:
    """One phase class's affine cost model + its residual pedigree."""
    kind: str
    term: str                 # "bytes" | "flops" — the fitted regressor
    intercept_s: float        # launch overhead (clamped at >= 0)
    rate: float               # effective rate: term units per second
    rate_ci95_rel: Optional[float]   # 95% CI half-width / rate (n>2 only)
    r2: float
    n_train: int
    n_heldout: int
    heldout_max_rel_err: float   # max |pred - t| / t over held-out points
    heldout_mean_rel_err: float  # (falls back to train residuals, n_heldout=0)
    flops_per_unit: float     # mean FLOPs per term unit (rate conversion)
    ref_term: float           # median grid point, for the error report
    ref_seconds: float        # its measured steady-state time

    def predict(self, term_value: float) -> float:
        return self.intercept_s + term_value / self.rate

    @property
    def flops_rate(self) -> float:
        """Effective FLOP/s implied by the fit (identity when
        ``term == "flops"``)."""
        return self.rate * self.flops_per_unit

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "PhaseFit":
        return cls(**d)


def _term_value(s: Sample, term: str) -> float:
    if term == "bytes":
        return s.bytes_term
    if term == "flops":
        return s.flops_term
    raise ValueError(f"unknown regressor {term!r} (want 'bytes' or 'flops')")


def _ols(xs: Sequence[float], ys: Sequence[float]):
    """Plain OLS y = a + s*x.  Returns (a, s, r2, slope_stderr)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    if sxx <= 0.0:
        return my, 0.0, 0.0, None
    s = sxy / sxx
    a = my - s * mx
    rss = sum((y - (a + s * x)) ** 2 for x, y in zip(xs, ys))
    tss = sum((y - my) ** 2 for y in ys)
    r2 = 1.0 - rss / tss if tss > 0 else 1.0
    stderr = (rss / (n - 2) / sxx) ** 0.5 if n > 2 else None
    return a, s, r2, stderr


def fit_phase(samples: Sequence[Sample], *, term: Optional[str] = None,
              holdout_every: int = 3) -> PhaseFit:
    """Fit one phase class; every ``holdout_every``-th point (by term
    magnitude, deterministic) is held out for the residual report.

    Degenerate grids (slope <= 0 from timing noise at tiny scales) fall
    back to the through-origin aggregate rate with intercept 0 — flagged
    by ``r2`` and the residuals, never by a crash.
    """
    if not samples:
        raise ValueError("fit_phase needs at least one sample")
    kinds = {s.kind for s in samples}
    if len(kinds) != 1:
        raise ValueError(f"fit_phase got mixed kinds {sorted(kinds)}")
    kind = samples[0].kind
    term = term or DEFAULT_TERMS.get(kind, "bytes")

    ordered = sorted(samples, key=lambda s: (_term_value(s, term), s.seconds))
    if len(ordered) >= 2 * holdout_every:
        held = [s for i, s in enumerate(ordered) if i % holdout_every == 1]
        train = [s for i, s in enumerate(ordered) if i % holdout_every != 1]
    else:
        held, train = [], list(ordered)

    xs = [_term_value(s, term) for s in train]
    ys = [s.seconds for s in train]
    if len(train) >= 2:
        a, slope, r2, stderr = _ols(xs, ys)
    else:
        a, slope, r2, stderr = 0.0, ys[0] / max(xs[0], 1e-30), 1.0, None
    if a < 0.0:
        # a negative launch overhead is unphysical (noise tilted the
        # line): refit through the origin rather than clamp-and-keep a
        # slope that no longer minimises anything
        sxx = sum(x * x for x in xs)
        slope = (sum(x * y for x, y in zip(xs, ys)) / sxx) if sxx else 0.0
        a = 0.0
        my = sum(ys) / len(ys)
        tss = sum((y - my) ** 2 for y in ys)
        rss = sum((y - slope * x) ** 2 for x, y in zip(xs, ys))
        r2 = 1.0 - rss / tss if tss > 0 else 1.0
        stderr = ((rss / (len(xs) - 1) / sxx) ** 0.5
                  if len(xs) > 1 and sxx else None)
    if slope <= 0.0:
        if a > 0.0:
            # latency-floor regime (times flat across the grid — e.g. a
            # tiny executor step that vectorises away the batch): keep
            # the intercept as the floor and make the slope's largest
            # contribution 1% of it, i.e. an effectively infinite rate
            # that still serialises as a finite float
            slope = 0.01 * a / max(max(xs), 1e-30)
            stderr = None
        else:                              # noise floor: aggregate rate
            slope = sum(ys) / max(sum(xs), 1e-30)
            a, r2, stderr = 0.0, 0.0, None
    rate = 1.0 / slope
    ci = (1.96 * stderr / slope) if stderr is not None and slope > 0 else None

    def rel_errs(pts):
        return [abs(a + _term_value(s, term) * slope - s.seconds)
                / max(s.seconds, 1e-30) for s in pts]

    resid = rel_errs(held) if held else rel_errs(train)
    fpu = (sum(s.flops_term for s in ordered)
           / max(sum(_term_value(s, term) for s in ordered), 1e-30))
    ref = ordered[len(ordered) // 2]
    return PhaseFit(
        kind=kind, term=term, intercept_s=a, rate=rate,
        rate_ci95_rel=ci, r2=r2,
        n_train=len(train), n_heldout=len(held),
        heldout_max_rel_err=max(resid) if resid else 0.0,
        heldout_mean_rel_err=(sum(resid) / len(resid)) if resid else 0.0,
        flops_per_unit=fpu,
        ref_term=_term_value(ref, term), ref_seconds=ref.seconds)


def fit_samples(samples: Sequence[Sample], *,
                terms: Optional[dict] = None,
                holdout_every: int = 3) -> dict[str, PhaseFit]:
    """Group samples by kind and fit each phase class."""
    terms = terms or DEFAULT_TERMS
    by_kind: dict[str, list[Sample]] = {}
    for s in samples:
        by_kind.setdefault(s.kind, []).append(s)
    return {k: fit_phase(v, term=terms.get(k), holdout_every=holdout_every)
            for k, v in sorted(by_kind.items())}


@dataclasses.dataclass
class CalibrationTable:
    """Versioned, serializable bundle of fitted phase cost models."""
    backend: str
    interpret: bool
    fits: dict[str, PhaseFit]
    meta: dict = dataclasses.field(default_factory=dict)
    version: int = CALIBRATION_VERSION

    @property
    def error_bar_rel(self) -> float:
        """The calibration error bar: worst held-out relative residual
        across all fitted phases — the ± attached to every co-sim
        headline replayed through this table."""
        if not self.fits:
            return 0.0
        return max(f.heldout_max_rel_err for f in self.fits.values())

    def to_json(self) -> dict:
        return {"version": self.version, "backend": self.backend,
                "interpret": self.interpret, "meta": dict(self.meta),
                "fits": {k: f.to_json() for k, f in self.fits.items()}}

    @classmethod
    def from_json(cls, d: dict) -> "CalibrationTable":
        ver = d.get("version")
        if ver != CALIBRATION_VERSION:
            raise ValueError(
                f"CalibrationTable version {ver!r} != supported "
                f"{CALIBRATION_VERSION} — re-run the profiler instead of "
                "re-interpreting stale rates")
        return cls(backend=d["backend"], interpret=bool(d["interpret"]),
                   fits={k: PhaseFit.from_json(f)
                         for k, f in d["fits"].items()},
                   meta=dict(d.get("meta", {})))

    def save(self, path: str):
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as fh:
            return cls.from_json(json.load(fh))


def build_table(samples: Sequence[Sample], *, backend: Optional[str] = None,
                interpret: Optional[bool] = None, meta: Optional[dict] = None,
                holdout_every: int = 3) -> CalibrationTable:
    """Fit every phase class in ``samples`` into a fresh table."""
    import jax

    from repro.profile.bench import interpret_default
    return CalibrationTable(
        backend=backend if backend is not None else jax.default_backend(),
        interpret=interpret_default() if interpret is None else interpret,
        fits=fit_samples(samples, holdout_every=holdout_every),
        meta=dict(meta or {}))
