"""Fault injection for the chiplet fabric: link/router/chiplet failures,
bandwidth derating, and deterministic seeded scenario sampling.

Chiplet platforms are exactly where faults live: interposer link defects,
router wear-out, and ReRAM endurance limits mean a NoI tuned only for the
fault-free case can degrade catastrophically when a single link drops.
This module defines the fault *vocabulary* the rest of Plane B speaks:

- :class:`FaultScenario` — one concrete failure set (links down, chiplets
  down, links bandwidth-derated).  Frozen/hashable so scenario lists can
  be cached and compared.
- :class:`FaultModel` — a distribution over scenarios with deterministic
  seeded sampling (``sample_scenarios``) and the exhaustive single-fault
  enumerations the resilience benchmarks sweep
  (``all_link_scenarios``).  Sampling is a pure function of
  (placement link set, seed), so the same design always sees the same
  scenario set — MOO archives stay comparable across evaluations.
- :class:`DisconnectedFabric` — the explicit error raised when a faulted
  fabric cannot route a required flow (``core.noi.evaluate_noi`` returns
  a ``NoIEval`` with ``disconnected=True``; the simulators raise this
  instead of reporting a bogus finite time).
- ``endurance_link_weights`` — the optional wear-driven failure
  distribution: per-link failure weight proportional to the byte-hops the
  *measured* traffic pushes through the link, with links touching the
  ReRAM macro up-weighted by the §4.4 endurance argument (dynamic-operand
  rewrites are what exhausts ReRAM cells — see
  ``baselines.retransformer_endurance`` / ``benchmarks.sec44_endurance``).

Routing semantics (implemented in ``core/noi.py``): a failed link is
removed from the graph; a failed chiplet (router-down == chiplet-down at
the NoI level) loses *all* its links and is dropped from the role map, so
its traffic share redistributes over the surviving same-role chiplets; a
derated link keeps routing but serialises at ``bw_factor`` of the nominal
link bandwidth.  Shortest surviving paths are recomputed per scenario.
"""
from __future__ import annotations

import dataclasses
import random
from itertools import combinations
from typing import Iterable, Optional, Sequence


class DisconnectedFabric(RuntimeError):
    """A fault scenario left the fabric unable to route required traffic.

    Raised by the simulators (``simulate_generation`` & friends) when the
    surviving link graph cannot carry a phase's flows; ``evaluate_noi``
    itself reports it as ``NoIEval.disconnected`` so MOO archives can
    reject the design without exception plumbing."""


def _norm_link(link) -> tuple:
    a, b = link
    return (min(int(a), int(b)), max(int(a), int(b)))


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One concrete failure set applied to a Placement.

    ``derated_links`` maps link → bandwidth factor in (0, 1]; failed
    links/chiplets are removed from routing entirely.  The empty scenario
    (``FaultScenario()``) is the fault-free fabric and evaluates
    bit-identically to no scenario at all."""
    failed_links: frozenset = frozenset()
    failed_chiplets: frozenset = frozenset()
    derated_links: tuple = ()           # sorted ((a, b), factor) pairs
    label: str = ""

    @classmethod
    def make(cls, failed_links: Iterable = (), failed_chiplets: Iterable = (),
             derated_links: Optional[dict] = None,
             label: str = "") -> "FaultScenario":
        der = tuple(sorted((_norm_link(l), float(f))
                           for l, f in (derated_links or {}).items()))
        for _, f in der:
            if not (0.0 < f <= 1.0):
                raise ValueError(f"bandwidth derate factor must be in (0, 1], got {f}")
        return cls(frozenset(_norm_link(l) for l in failed_links),
                   frozenset(int(c) for c in failed_chiplets), der, label)

    @property
    def is_nominal(self) -> bool:
        return not (self.failed_links or self.failed_chiplets
                    or self.derated_links)

    def surviving_links(self, links: Iterable) -> set:
        """Links of a placement that survive this scenario."""
        down = self.failed_chiplets
        return {l for l in (_norm_link(x) for x in links)
                if l not in self.failed_links
                and l[0] not in down and l[1] not in down}

    def derate_of(self, link) -> float:
        link = _norm_link(link)
        for l, f in self.derated_links:
            if l == link:
                return f
        return 1.0


NOMINAL = FaultScenario(label="nominal")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """A seeded distribution over fault scenarios.

    ``k_links`` / ``k_chiplets`` are the number of simultaneous failures
    per sampled scenario; ``bw_derate`` < 1 additionally derates
    ``k_derated`` surviving links to that bandwidth factor (0 disables).
    ``link_weights`` (optional, aligned with ``sorted(placement.links)``)
    biases which links fail — e.g. the endurance-driven wear weights from
    ``endurance_link_weights``.  Sampling is deterministic in
    (link set, seed): the same design always draws the same scenarios."""
    k_links: int = 1
    k_chiplets: int = 0
    k_derated: int = 0
    bw_derate: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.k_links < 0 or self.k_chiplets < 0 or self.k_derated < 0:
            raise ValueError("fault counts must be >= 0")
        if not (0.0 < self.bw_derate <= 1.0):
            raise ValueError(f"bw_derate must be in (0, 1], got {self.bw_derate}")

    def _rng_for(self, links: Sequence[tuple]) -> random.Random:
        # deterministic in the link *set* (int tuples hash stably), the
        # seed, and nothing else — scenario draws are reproducible per
        # design across processes
        key = (self.seed, tuple(sorted(links)))
        return random.Random(repr(key))

    def sample_scenarios(self, placement, n_scenarios: int,
                         link_weights: Optional[Sequence[float]] = None,
                         ) -> list[FaultScenario]:
        """Draw ``n_scenarios`` deterministic scenarios for a placement.

        Each scenario fails ``k_links`` distinct links (weighted by
        ``link_weights`` when given), ``k_chiplets`` distinct chiplets,
        and derates ``k_derated`` further links to ``bw_derate``.
        Duplicate draws are kept (they are what the distribution says);
        an empty fabric or k larger than the link count yields the
        all-links-failed scenario."""
        links = sorted(_norm_link(l) for l in placement.links)
        rng = self._rng_for(links)
        if link_weights is not None and len(link_weights) != len(links):
            raise ValueError(
                f"link_weights length {len(link_weights)} != "
                f"{len(links)} links")
        out = []
        n_cells = placement.n
        for s in range(n_scenarios):
            failed = self._draw_links(rng, links, self.k_links, link_weights)
            chips = (rng.sample(range(n_cells),
                                min(self.k_chiplets, n_cells))
                     if self.k_chiplets else [])
            derated = {}
            if self.k_derated and self.bw_derate < 1.0:
                alive = [l for l in links if l not in failed]
                for l in self._draw_links(rng, alive,
                                          min(self.k_derated, len(alive)),
                                          None):
                    derated[l] = self.bw_derate
            out.append(FaultScenario.make(failed, chips, derated,
                                          label=f"sample{s}"))
        return out

    @staticmethod
    def _draw_links(rng: random.Random, links: Sequence[tuple], k: int,
                    weights: Optional[Sequence[float]]) -> set:
        k = min(k, len(links))
        if k <= 0 or not links:
            return set()
        if weights is None:
            return set(rng.sample(list(links), k))
        # weighted sampling without replacement (small k, small fabrics)
        pool = list(links)
        w = [max(float(x), 0.0) for x in weights]
        chosen: set = set()
        for _ in range(k):
            total = sum(w)
            if total <= 0.0:
                chosen.update(rng.sample(pool, k - len(chosen)))
                break
            r = rng.random() * total
            acc = 0.0
            idx = len(pool) - 1
            for i, wi in enumerate(w):
                acc += wi
                if r <= acc:
                    idx = i
                    break
            chosen.add(pool.pop(idx))
            w.pop(idx)
        return chosen


def all_link_scenarios(placement, k: int = 1,
                       max_scenarios: int = 0) -> list[FaultScenario]:
    """Exhaustive k-link-failure scenarios of a placement (every size-k
    subset of its links).  ``max_scenarios`` > 0 caps the enumeration
    (deterministically: lexicographic order over the sorted link list) so
    k=2 sweeps on dense fabrics stay bounded."""
    links = sorted(_norm_link(l) for l in placement.links)
    out = []
    for combo in combinations(links, min(k, len(links))):
        out.append(FaultScenario.make(combo, label="+".join(map(str, combo))))
        if max_scenarios and len(out) >= max_scenarios:
            break
    return out


def all_chiplet_scenarios(placement, k: int = 1,
                          max_scenarios: int = 0) -> list[FaultScenario]:
    """Exhaustive k-chiplet-loss scenarios of a placement (every size-k
    subset of its cells) — the MTTR sweeps' ground truth: each scenario
    drops the chiplets from the role map (traffic redistributes over the
    surviving same-role members; wiping a whole role disconnects) and
    removes their links.  ``max_scenarios`` > 0 caps the enumeration
    deterministically (lexicographic cell order)."""
    out = []
    for combo in combinations(range(placement.n), min(k, placement.n)):
        out.append(FaultScenario.make(
            failed_chiplets=combo,
            label="chip" + "+".join(map(str, combo))))
        if max_scenarios and len(out) >= max_scenarios:
            break
    return out


def endurance_link_weights(placement, phases,
                           reram_wear_factor: float = 4.0) -> list[float]:
    """Per-link failure weights driven by measured traffic wear (§4.4).

    Weight of each link (aligned with ``sorted(placement.links)``) is the
    repeat-weighted bytes the phase list pushes through it — switching
    activity is what wears interposer links and router buffers — with
    links incident to ReRAM chiplets multiplied by ``reram_wear_factor``:
    the endurance-limited macro (``RERAM.write_endurance``,
    ``baselines.retransformer_endurance``) makes wear accumulated at its
    boundary disproportionately likely to surface as a failure.  A
    uniform floor keeps never-used links sampleable (defects do not care
    about traffic)."""
    from repro.core.noi import evaluate_noi

    ev = evaluate_noi(placement, phases)
    links = sorted(_norm_link(l) for l in placement.links)
    if ev.disconnected or not ev.per_phase_link_bytes:
        return [1.0] * len(links)
    per_link = [0.0] * len(links)
    for ph, u in zip(phases, ev.per_phase_link_bytes):
        for i, b in enumerate(u):
            per_link[i] += float(b) * ph.repeat
    total = sum(per_link)
    if total <= 0.0:
        return [1.0] * len(links)
    rerams = set(placement.roles().get("ReRAM", []))
    floor = 0.05 * total / max(len(links), 1)
    out = []
    for link, b in zip(links, per_link):
        w = b + floor
        if link[0] in rerams or link[1] in rerams:
            w *= reram_wear_factor
        out.append(w)
    return out
