"""Plane-B recovery accounting: DRAM↔DRAM re-shard routing, checkpoint
write-back amortisation, recovery phases on the degraded fabric, the
exhaustive chiplet-loss enumeration, and the MTTR-aware NoI objective."""
import math

import pytest

from repro.config import get_config
from repro.core.cosim import (Episode, EpisodeMix, fabric_time,
                              mttr_resilience_objective, recovery_time)
from repro.core.faults import FaultScenario, all_chiplet_scenarios
from repro.core.placement import initial_placement
from repro.core.traffic import (Phase, Workload, checkpoint_phases,
                                decode_step_phases, phase_bytes,
                                phase_traffic_matrix,
                                pool_kv_bytes_per_layer, prefill_phases,
                                recovery_phases, transformer_phases)


@pytest.fixture(scope="module")
def w():
    return Workload.from_config(get_config("gpt-j"), seq_len=64)


@pytest.fixture(scope="module")
def p36():
    return initial_placement(36)


@pytest.fixture(scope="module")
def mix():
    return EpisodeMix([Episode(64, 16, 4)], prefill_chunk=16, max_batch=4,
                      active_hist={4: 1}, max_stall_tokens=16)


# ---------------------------------------------------------------------------
# traffic: the new recovery streams
# ---------------------------------------------------------------------------

def test_nominal_phases_carry_no_recovery_traffic(w):
    """Every nominal builder leaves dram_dram_bytes at 0.0 — the Table-4
    calibration surface must not see the recovery plumbing."""
    for ph in (transformer_phases(w) + prefill_phases(w)
               + decode_step_phases(w, 32)):
        assert ph.dram_dram_bytes == 0.0


def test_dram_dram_ring_routing(w, p36):
    roles = p36.roles()
    drams = roles["DRAM"]
    ph = Phase("kv_migrate", dram_dram_bytes=1000.0)
    F = phase_traffic_matrix(ph, roles, p36.n)
    ring = {(d, drams[(i + 1) % len(drams)]): 1000.0 / len(drams)
            for i, d in enumerate(drams)}
    assert F == pytest.approx(ring)
    assert sum(F.values()) == pytest.approx(1000.0)
    # a single surviving DRAM member has nobody to re-shard with
    solo = dict(roles, DRAM=drams[:1])
    assert phase_traffic_matrix(ph, solo, p36.n) == {}
    assert phase_bytes(ph) == 1000.0


def test_checkpoint_phases_amortise_the_pool(w):
    pool = pool_kv_bytes_per_layer(w, 32, batch=4)
    (ph,) = checkpoint_phases(w, 32, batch=4, every=16)
    assert ph.sm_mc_bytes == pytest.approx(pool / 16)
    assert ph.dram_bytes == pytest.approx(pool / 16)
    assert ph.repeat == w.n_dec_layers
    with pytest.raises(ValueError, match="checkpoint period"):
        checkpoint_phases(w, 32, every=0)


def test_recovery_phases_scale_with_lost_fraction(w):
    pool = pool_kv_bytes_per_layer(w, 32, batch=4)
    full = recovery_phases(w, 32, batch=4, lost_frac=0.25)
    assert [ph.name for ph in full] == ["kv_migrate", "ckpt_restore"]
    mig, rst = full
    assert mig.dram_dram_bytes == pytest.approx(pool * 0.25)
    assert rst.dram_bytes == pytest.approx(pool)
    assert rst.sm_mc_bytes == pytest.approx(pool)
    # a non-DRAM loss orphans nothing but still pays the restore read
    (only,) = recovery_phases(w, 32, batch=4, lost_frac=0.0)
    assert only.name == "ckpt_restore"
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError, match="lost_frac"):
            recovery_phases(w, 32, lost_frac=bad)


def test_pool_bytes_match_decode_accounting(w):
    """Pool footprint is linear in the position *sum* — per-slot position
    lists and their scalar mean price identically."""
    assert pool_kv_bytes_per_layer(w, [10, 20, 30], batch=3) == \
        pytest.approx(pool_kv_bytes_per_layer(w, 20, batch=3))


# ---------------------------------------------------------------------------
# faults: exhaustive chiplet-loss enumeration
# ---------------------------------------------------------------------------

def test_all_chiplet_scenarios_exhaustive_and_capped(p36):
    scs = all_chiplet_scenarios(p36, k=1)
    assert len(scs) == p36.n
    assert {next(iter(s.failed_chiplets)) for s in scs} \
        == set(range(p36.n))
    assert all(not s.failed_links for s in scs)
    capped = all_chiplet_scenarios(p36, k=2, max_scenarios=10)
    assert len(capped) == 10
    assert all(len(s.failed_chiplets) == 2 for s in capped)


# ---------------------------------------------------------------------------
# cosim: recovery time + MTTR-aware objective
# ---------------------------------------------------------------------------

def test_recovery_time_nominal_is_zero(p36, mix):
    assert recovery_time(p36, "gpt-j", mix, None) == 0.0
    assert recovery_time(p36, "gpt-j", mix,
                         FaultScenario(label="nominal")) == 0.0


def test_recovery_time_prices_dram_loss_above_compute_loss(p36, mix):
    roles = p36.roles()
    t_by_role = {}
    for role in ("DRAM", "SM"):
        sc = FaultScenario.make(failed_chiplets=[roles[role][0]])
        t = recovery_time(p36, "gpt-j", mix, sc)
        assert math.isfinite(t) and t > 0.0
        t_by_role[role] = t
    # losing a DRAM member adds the KV re-shard stream on top of the
    # restore read every loss pays
    assert t_by_role["DRAM"] > t_by_role["SM"]


def test_mttr_objective_normalised_and_admissible(mix):
    obj, seed_t, phases = mttr_resilience_objective(
        "gpt-j", mix, 36, n_scenarios=4)
    assert seed_t > 0.0
    assert any(ph.name == "ckpt_write" for ph in phases)
    mean_t, worst_t = obj(initial_placement(36))
    assert math.isfinite(mean_t) and math.isfinite(worst_t)
    # the worst case carries recovery on top of degraded service: it can
    # never undercut the nominal-service mean
    assert worst_t >= mean_t > 0.0

    no_ckpt_obj, _, no_ckpt_phases = mttr_resilience_objective(
        "gpt-j", mix, 36, n_scenarios=4, ckpt_every=0)
    assert all(ph.name != "ckpt_write" for ph in no_ckpt_phases)
    # dropping the write-back stream cheapens steady-state service
    assert no_ckpt_obj(initial_placement(36))[0] <= mean_t


def test_mttr_worst_case_tracks_exhaustive_chiplet_loss(p36, mix):
    """Every exhaustive k=1 loss must be finitely recoverable on the seed
    placement — the benchmark's ground-truth sweep never silently drops a
    scenario."""
    _, _, phases = mttr_resilience_objective("gpt-j", mix, 36,
                                             n_scenarios=2)
    for sc in all_chiplet_scenarios(p36, k=1):
        svc = fabric_time(p36, phases, sc)
        rec = recovery_time(p36, "gpt-j", mix, sc)
        assert math.isfinite(svc) and math.isfinite(rec)
