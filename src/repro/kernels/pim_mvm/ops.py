"""jit'd dispatch wrapper for the PIM-MVM kernel.

``quantize_weights`` — the "programming the crossbars" step: done once,
offline, per static weight matrix (the paper's weight-stationary claim) —
lives in :mod:`repro.quant.core` (the repo's single source of truth for
scales/rounding) and is re-exported here; ``pim_mvm`` is the streaming
execute step.
"""
from __future__ import annotations

import jax

from repro.kernels.pim_mvm import kernel as _kernel
from repro.kernels.pim_mvm.ref import pim_mvm_ref
from repro.quant.core import quantize_weights  # noqa: F401  (re-export)

XBAR = _kernel.XBAR


def pim_mvm(x, wq, scales, *, impl: str = "auto", **blocks):
    """Quantised weight-stationary matmul.

    impl: ref | pallas | pallas_interpret | auto (pallas on TPU, else ref).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return pim_mvm_ref(x, wq, scales)
    if impl == "pallas":
        return _kernel.pim_mvm_pallas(x, wq, scales, **blocks)
    if impl == "pallas_interpret":
        return _kernel.pim_mvm_pallas(x, wq, scales, interpret=True, **blocks)
    raise ValueError(f"unknown impl {impl!r}")
