"""NoI evaluation: routing, link utilisation u_k, μ(λ), σ(λ) (eqs 11-15).

Routing is shortest-path (BFS) over the candidate link graph — the paper's
NoI routers are a hierarchical wormhole fabric; at the utilisation-
objective level only the path→link incidence q_ijk matters (eq. 11).

Fault semantics (``scenario=`` — see ``core/faults.py``): a failed link is
removed from the routing graph; a failed chiplet loses all its links *and*
is dropped from the role map, so its traffic share redistributes over the
surviving same-role chiplets; a bandwidth-derated link keeps routing but
serialises slower (``NoIEval.link_bw_scale`` → ``noi_phase_time``).  When
the surviving graph cannot carry a required flow — or a whole role is
wiped out — the result is an explicit ``disconnected`` ``NoIEval`` (all
metrics inf), never a bogus finite time.  ``scenario=None`` (or the
nominal scenario) is bit-identical to the pre-fault evaluator.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.chiplets import LINK
from repro.core.placement import Placement
from repro.core.traffic import Phase, phase_traffic_matrix


def _paths(p: Placement, links=None) -> dict:
    """All-pairs BFS parents over ``links`` (default: every placement
    link): returns hop-path cache {src: parents array}."""
    adj: dict[int, list[int]] = {i: [] for i in range(p.n)}
    for a, b in (p.links if links is None else links):
        adj[a].append(b)
        adj[b].append(a)
    out = {}
    for s in range(p.n):
        par = np.full(p.n, -1, np.int32)
        par[s] = s
        q = deque([s])
        while q:
            u = q.popleft()
            for v in adj[u]:
                if par[v] < 0:
                    par[v] = u
                    q.append(v)
        out[s] = par
    return out


@dataclasses.dataclass
class NoIEval:
    mu: float                 # eq. 14 (time-avg of eq. 12)
    sigma: float              # eq. 15 (time-avg of eq. 13)
    max_util: float
    total_byte_hops: float
    mean_hops: float
    per_phase_link_bytes: list
    disconnected: bool = False       # no surviving route for some flow
    # per-link bandwidth factors aligned with per_phase_link_bytes columns
    # (sorted placement links); None = nominal bandwidth everywhere
    link_bw_scale: Optional[np.ndarray] = None


def _disconnected() -> NoIEval:
    return NoIEval(np.inf, np.inf, np.inf, np.inf, np.inf, [],
                   disconnected=True)


def evaluate_noi(p: Placement, phases: list[Phase],
                 roles_override: dict | None = None,
                 scenario=None) -> NoIEval:
    """Evaluate a placement's NoI under the phase traffic, optionally
    degraded by a ``core.faults.FaultScenario`` (failed links/chiplets
    removed from routing and roles, derated links slowed).  Statistics
    (μ, σ, max) run over the *surviving* links only."""
    if scenario is not None and scenario.is_nominal:
        scenario = None
    links = sorted(p.links)
    if scenario is None:
        if not p.connected():
            return _disconnected()
        alive_links = links
        roles = roles_override if roles_override is not None else p.roles()
        alive_mask = None
        bw_scale = None
    else:
        alive = scenario.surviving_links(links)
        alive_links = [l for l in links if l in alive]
        roles = dict(roles_override if roles_override is not None
                     else p.roles())
        if scenario.failed_chiplets:
            down = scenario.failed_chiplets
            for name, ids in list(roles.items()):
                kept = [i for i in ids if i not in down]
                if not kept:
                    # a whole role wiped out: no surviving chiplet can
                    # source/sink that traffic class
                    return _disconnected()
                roles[name] = kept
        alive_mask = np.array([l in alive for l in links], bool)
        if not alive_mask.any() and p.n > 1:
            return _disconnected()
        bw_scale = None
        if scenario.derated_links:
            bw_scale = np.ones(len(links))
            for l, f in scenario.derated_links:
                if l in alive:
                    bw_scale[links.index(l)] = f

    parents = _paths(p, links=alive_links) if scenario is not None \
        else _paths(p)
    link_idx = {l: i for i, l in enumerate(links)}

    mus, sigmas, weights, per_phase = [], [], [], []
    total_byte_hops = 0.0
    total_hops = 0
    n_flows = 0
    max_util = 0.0

    for ph in phases:
        F = phase_traffic_matrix(ph, roles, p.n)
        # u = per-link bytes for ONE execution of the phase (one timestamp
        # of eq. 12/13).  Repeats weight the time-average (eqs 14-15) — a
        # phase that runs k times contributes k identical timestamps — and
        # scale the energy byte-hops, but NOT the per-execution link time.
        u = np.zeros(len(links))
        for (i, j), bytes_ in F.items():
            par = parents[i]
            if par[j] < 0:
                return _disconnected()
            # walk j -> i collecting links (q_ijk in eq. 11)
            cur = j
            hops = 0
            while cur != i:
                prev = int(par[cur])
                u[link_idx[(min(cur, prev), max(cur, prev))]] += bytes_
                cur = prev
                hops += 1
            total_byte_hops += bytes_ * hops * ph.repeat
            total_hops += hops
            n_flows += 1
        us = u if alive_mask is None else u[alive_mask]
        # degenerate fabrics (single chiplet: no links at all) carry no
        # inter-chiplet traffic — their link stats are exactly zero, not
        # a NaN from an empty-array mean
        mus.append(float(us.mean()) if len(us) else 0.0)
        sigmas.append(float(us.std()) if len(us) else 0.0)
        weights.append(float(ph.repeat))
        max_util = max(max_util, float(us.max()) if len(us) else 0.0)
        per_phase.append(u)

    wsum = sum(weights) or 1.0
    return NoIEval(
        mu=float(np.dot(mus, weights) / wsum) if mus else 0.0,
        sigma=float(np.dot(sigmas, weights) / wsum) if sigmas else 0.0,
        max_util=max_util, total_byte_hops=total_byte_hops,
        mean_hops=total_hops / max(n_flows, 1),
        per_phase_link_bytes=per_phase,
        link_bw_scale=bw_scale)


def noi_phase_time(link_bytes: np.ndarray, bw_scale=None) -> float:
    """Serialisation time of a phase on the NoI: the busiest link bounds
    throughput (wormhole, all flows concurrent).  ``bw_scale`` (per-link
    bandwidth factors, e.g. ``NoIEval.link_bw_scale`` of a derated fault
    scenario) slows the affected links; None is the nominal fabric."""
    if len(link_bytes) == 0:
        return 0.0
    if bw_scale is None:
        return float(link_bytes.max()) / LINK.bw
    return float(np.max(np.asarray(link_bytes)
                        / (LINK.bw * np.asarray(bw_scale))))


def noi_energy(eval_: NoIEval) -> float:
    """Link + router traversal energy for the whole workload (J)."""
    pj_per_bit = LINK.energy_pj_per_bit + LINK.router_pj_per_bit
    return eval_.total_byte_hops * 8 * pj_per_bit * 1e-12


def mesh_baseline_eval(n_chiplets: int, phases, n_samples: int = 5,
                       scenario=None) -> NoIEval:
    """Reference 2-D mesh NoI (paper Fig-4 normaliser): full mesh links with
    *placement-unaware* (shuffled) chiplet assignment, averaged over a few
    draws — the "standard multi-hop regular topology" the paper argues
    against (§3.2).  A fault ``scenario`` degrades every draw; if any draw
    disconnects, the baseline is reported disconnected (explicitly — no
    NaN from averaging infs)."""
    import random

    from repro.core.placement import random_placement

    evs = [evaluate_noi(random_placement(n_chiplets, random.Random(s)),
                        phases, scenario=scenario)
           for s in range(n_samples)]
    if any(e.disconnected for e in evs):
        return _disconnected()
    mu = float(np.mean([e.mu for e in evs]))
    sigma = float(np.mean([e.sigma for e in evs]))
    return NoIEval(mu=mu, sigma=sigma,
                   max_util=float(np.mean([e.max_util for e in evs])),
                   total_byte_hops=float(np.mean([e.total_byte_hops for e in evs])),
                   mean_hops=float(np.mean([e.mean_hops for e in evs])),
                   per_phase_link_bytes=[])
