"""Recovery benchmark: crash-safe serving and MTTR-aware NoI design.

Two sections, one per plane:

- **chaos** — Plane A exactly-once semantics under kill+restore.  For
  every engine-servable zoo model the same request burst is drained
  twice: once uninterrupted, once killed at an adversarially chosen
  iteration (post-admission pre-snapshot, mid-prefill-chunk of a long
  prompt, mid-decode) with two further iterations of work thrown away,
  then revived via ``ServingEngine.restore`` from the snapshot + journal
  (``repro.serving.checkpoint``).  The token streams must be
  *bit-identical* per request uid — zero lost, duplicated, or divergent
  tokens — including temperature sampling (per-slot PRNG keys are part
  of the snapshot) and the int8 quantised slot pool.  Encoder-decoder
  zoo members are reported as explicit unsupported rows (the engine has
  no encoder prefill path); they are still covered by the Plane-B
  section below.
- **mttr_noi_search** — Plane B: the NoI design MOO-STAGE finds under
  the fault-oblivious generation objective vs the MTTR-aware one
  (``core.cosim.mttr_resilience_objective``: amortised checkpoint
  write-back stream in steady state, KV-shard migration + restore read
  priced into the worst case).  Both designs are scored under the same
  *exhaustive* k=1 chiplet-loss sweep on worst-case service + recovery
  time; the MTTR-aware design should carry the lower worst case.

    PYTHONPATH=src python -m benchmarks.perf_recovery [--smoke]

Results: ``experiments/BENCH_recovery.json``
(``BENCH_recovery_smoke.json`` with ``--smoke``); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

ZOO = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b",
       "bart-large", "whisper-large-v3")

# adversarial kill kinds the chaos sweep must cover per servable model
KILL_KINDS = ("post_admission", "mid_prefill", "mid_decode")

_CHAOS_KEYS = {"model", "supported", "kv_bits", "temperature", "kills"}

_KILL_KEYS = {"kill_at", "kind", "match", "lost", "duplicated",
              "n_requests", "replayed_requests", "restores",
              "checkpoints_written"}

_MTTR_KEYS = {"model", "chiplets", "oblivious", "aware", "gain_worst_k1",
              "aware_survives_k1", "same_design", "n_evals"}

_SCORE_KEYS = {"nominal_t", "ckpt_overhead", "worst_total_k1",
               "worst_service_k1", "worst_recovery_k1",
               "n_disconnected_k1", "links"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_recovery.json record shape (CI bit-rot gate)."""
    for key in ("bench", "smoke", "chiplets", "prompt_len", "gen_len",
                "batch", "chaos", "mttr_noi_search"):
        assert key in rec, f"missing top-level key {key!r}"
    cells = rec["chaos"]["cells"]
    assert cells, "chaos must not be empty"
    for cell in cells:
        missing = _CHAOS_KEYS - set(cell)
        assert not missing, f"chaos cell missing {missing}"
        if not cell["supported"]:
            continue
        assert cell["kills"], f"{cell['model']}: no kill points exercised"
        for kill in cell["kills"]:
            kmissing = _KILL_KEYS - set(kill)
            assert not kmissing, f"kill row missing {kmissing}"
            # the exactly-once contract is unconditional — smoke included
            assert kill["match"], \
                f"{cell['model']} kill@{kill['kill_at']}: token divergence"
            assert kill["lost"] == 0 and kill["duplicated"] == 0, \
                f"{cell['model']} kill@{kill['kill_at']}: lost/dup requests"
            assert kill["restores"] == 1
    if not rec["smoke"]:
        servable = [c for c in cells if c["supported"]]
        assert len(servable) >= 4, "full chaos must cover >=4 zoo models"
        for cell in servable:
            kinds = {k["kind"] for k in cell["kills"]}
            assert set(KILL_KINDS) <= kinds, \
                f"{cell['model']}: kill kinds {kinds} miss {KILL_KINDS}"
            assert len({k["kill_at"] for k in cell["kills"]}) >= 3, \
                f"{cell['model']}: need >=3 distinct kill iterations"
        assert any(c["kv_bits"] for c in servable), \
            "full chaos must include a quantised slot-pool variant"
    cells = rec["mttr_noi_search"]["cells"]
    assert cells, "mttr_noi_search must not be empty"
    for cell in cells:
        missing = _MTTR_KEYS - set(cell)
        assert not missing, f"mttr_noi_search cell missing {missing}"
        for side in ("oblivious", "aware"):
            smissing = _SCORE_KEYS - set(cell[side])
            assert not smissing, f"{side} score missing {smissing}"
    if not rec["smoke"]:
        assert len(cells) >= 6, "full sweep must cover the whole zoo"
        improved = [c for c in cells
                    if c["gain_worst_k1"] is None or c["gain_worst_k1"] > 1.0]
        assert len(improved) >= 4, (
            "MTTR-aware search must beat the fault-oblivious design on "
            f"worst-case service+recovery for >=4 models "
            f"(got {len(improved)})")


# ---------------------------------------------------------------------------
# chaos: kill + restore with exactly-once token semantics
# ---------------------------------------------------------------------------

def _outputs_by_uid(engine) -> dict:
    out = {}
    for req in engine.finished:
        assert req.uid not in out, f"duplicated uid {req.uid}"
        out[int(req.uid)] = [int(t) for t in req.output]
    return out


def _classify(engine, steps_taken: int) -> str:
    if steps_taken == 0:
        return "post_admission"
    if engine._prefilling:
        return "mid_prefill"
    if any(r is not None for r in engine.slot_req):
        return "mid_decode"
    return "drained"


def run_chaos(models, *, temperature: float = 0.8, quant_model: str = "",
              max_steps: int = 24) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_config, reduce_config
    from repro.models import transformer as T
    from repro.serving.checkpoint import EngineCheckpointer
    from repro.serving.engine import EngineConfig, ServingEngine

    # one prompt longer than the chunk budget keeps a slot mid-prefill
    # across iterations; the 5th prompt lands *after* the snapshot, so it
    # only survives the crash through the journal
    prompt_lens = (8, 5, 19, 11, 6)
    chunk = 8

    def build_case(name, kv_bits):
        cfg = reduce_config(get_config(name))
        servable = not (cfg.n_encoder_layers or cfg.cross_attn_decoder)
        if not servable:
            return cfg, None, None, None
        params = T.init_params(cfg, jax.random.PRNGKey(0),
                               param_dtype=jnp.float32)
        ecfg = EngineConfig(max_batch=3, kv_len=48, max_new_tokens=6,
                            impl="ref", prefill_chunk=chunk,
                            temperature=temperature, seed=0,
                            kv_bits=kv_bits)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=n)
                   for n in prompt_lens]
        return cfg, params, ecfg, prompts

    def reference(cfg, params, ecfg, prompts, kill_at):
        eng = ServingEngine(cfg, params, ecfg)
        reqs = [eng.submit(p.copy()) for p in prompts[:4]]
        for _ in range(kill_at):
            eng.step()
        reqs.append(eng.submit(prompts[4].copy()))
        eng.run_until_drained()
        assert not eng.failed and not eng.rejected
        return _outputs_by_uid(eng)

    def chaos_once(cfg, params, ecfg, prompts, kill_at, root):
        ckpt_dir = os.path.join(root, f"kill{kill_at}")
        eng = ServingEngine(cfg, params, ecfg)
        ck = EngineCheckpointer(eng, ckpt_dir)
        for p in prompts[:4]:
            ck.submit(p.copy())
        for _ in range(kill_at):
            eng.step()
        kind = _classify(eng, kill_at)
        ck.save()
        ck.submit(prompts[4].copy())     # journal-only: post-snapshot
        for _ in range(2):               # work the crash throws away
            eng.step()
        del eng                          # the "crash"
        eng2 = ServingEngine.restore(cfg, params, ckpt_dir)
        eng2.run_until_drained()
        assert not eng2.failed and not eng2.rejected
        stats = eng2.stats()
        return _outputs_by_uid(eng2), kind, stats

    def kill_schedule(cfg, params, ecfg, prompts):
        """First iteration exhibiting each adversarial kind (scout run)."""
        eng = ServingEngine(cfg, params, ecfg)
        for p in prompts[:4]:
            eng.submit(p.copy())
        found = {"post_admission": 0}
        for i in range(1, max_steps):
            eng.step()
            kind = _classify(eng, i)
            if kind == "drained":
                break
            found.setdefault(kind, i)
        return found

    cells = []
    for name in models:
        kv_bits_list = [0] + ([8] if name == quant_model else [])
        for kv_bits in kv_bits_list:
            cfg, params, ecfg, prompts = build_case(name, kv_bits)
            if params is None:
                cells.append({
                    "model": name, "supported": False, "kv_bits": kv_bits,
                    "temperature": temperature, "kills": [],
                    "reason": "engine has no encoder-decoder prefill path "
                              "(covered by mttr_noi_search)"})
                break
            schedule = kill_schedule(cfg, params, ecfg, prompts)
            kills = []
            with tempfile.TemporaryDirectory() as root:
                for kind, kill_at in sorted(schedule.items(),
                                            key=lambda kv: kv[1]):
                    ref = reference(cfg, params, ecfg, prompts, kill_at)
                    got, seen, stats = chaos_once(cfg, params, ecfg,
                                                  prompts, kill_at, root)
                    lost = len(set(ref) - set(got))
                    dup = len(got) - len(set(got))
                    kills.append({
                        "kill_at": kill_at, "kind": seen,
                        "match": got == ref,
                        "lost": lost, "duplicated": dup,
                        "n_requests": len(ref),
                        "replayed_requests": stats["replayed_requests"],
                        "restores": stats["restores"],
                        "checkpoints_written": stats["checkpoints_written"],
                    })
            cells.append({"model": name, "supported": True,
                          "kv_bits": kv_bits, "temperature": temperature,
                          "kills": kills})
    return {"prompt_lens": list(prompt_lens), "prefill_chunk": chunk,
            "cells": cells}


# ---------------------------------------------------------------------------
# MTTR-aware NoI search vs fault-oblivious, exhaustive k=1 chiplet loss
# ---------------------------------------------------------------------------

def _score_chiplet_loss(design, name, mix, phases, ckpt_phases_t,
                        *, batch) -> dict:
    """Worst-case (service + recovery) of one placement over every single
    chiplet loss.  Disconnection of either the degraded service or the
    recovery traffic is a flag + count (JSON-safe), never an inf."""
    from repro.core.cosim import fabric_time, recovery_time
    from repro.core.faults import all_chiplet_scenarios

    nominal_t = fabric_time(design, phases)
    out = {"links": len(design.links), "nominal_t": nominal_t,
           "ckpt_overhead": ckpt_phases_t / max(nominal_t, 1e-30)}
    worst = (-1.0, 0.0, 0.0)            # (total, service, recovery)
    n_disc = 0
    for sc in all_chiplet_scenarios(design, k=1):
        svc = fabric_time(design, phases, sc)
        rec = recovery_time(design, name, mix, sc, batch=batch)
        total = svc + rec
        if total == float("inf"):
            n_disc += 1
            continue
        if total > worst[0]:
            worst = (total, svc, rec)
    disc = n_disc > 0
    out["worst_total_k1"] = None if disc else worst[0]
    out["worst_service_k1"] = None if disc else worst[1]
    out["worst_recovery_k1"] = None if disc else worst[2]
    out["n_disconnected_k1"] = n_disc
    return out


def run_mttr_search(models, chiplets: int, prompt_len: int, gen_len: int,
                    *, batch: int = 8, requests: int = 4,
                    iterations: int = 3, ls_steps: int = 12,
                    n_scenarios: int = 8, ckpt_every: int = 32,
                    mttr_weight: float = 1.0, seed: int = 0) -> dict:
    import numpy as np

    from repro.core.cosim import (Episode, EpisodeMix, fabric_time,
                                  generation_objective,
                                  mttr_resilience_objective,
                                  seeded_noi_search)

    chunk = max(prompt_len // 4, 1)
    cells = []
    for name in models:
        mix = EpisodeMix([Episode(prompt_len, gen_len, requests)],
                         prefill_chunk=chunk, max_batch=batch,
                         active_hist={batch: 1}, max_stall_tokens=chunk)
        # fault-oblivious designer: nominal service time only — never
        # prices what losing a chiplet (and re-sharding its KV) costs
        obl_obj, _, phases = generation_objective(name, mix, chiplets)
        obl = seeded_noi_search(obl_obj, chiplets, iterations=iterations,
                                ls_steps=ls_steps, seed=seed)
        obl_design = min(obl.archive.designs,
                         key=lambda d: fabric_time(d, phases))

        # MTTR-aware designer: steady state carries the checkpoint
        # write-back stream, worst case carries degraded service +
        # KV-migration/restore recovery; picks the best worst case
        aw_obj, _, aw_phases = mttr_resilience_objective(
            name, mix, chiplets, n_scenarios=n_scenarios,
            ckpt_every=ckpt_every, mttr_weight=mttr_weight)
        aw = seeded_noi_search(aw_obj, chiplets, iterations=iterations,
                               ls_steps=ls_steps, seed=seed)
        aobjs = np.asarray(aw.archive.objs)
        aw_design = aw.archive.designs[int(np.argmin(aobjs[:, 1]))]

        # both designs under the same yardstick: exhaustive k=1 chiplet
        # loss, worst-case service + recovery (ckpt stream reported as a
        # separate nominal-overhead ratio, not folded into the service
        # term — the comparison stays apples-to-apples)
        scores = {}
        for side, design in (("oblivious", obl_design),
                             ("aware", aw_design)):
            ckpt_t = fabric_time(design, aw_phases)
            scores[side] = _score_chiplet_loss(
                design, name, mix, phases, ckpt_t, batch=batch)
        # worst-case total ratio oblivious/aware: > 1 means the
        # MTTR-aware design recovers from its worst single chiplet loss
        # faster; None = the oblivious design cannot recover at all while
        # the aware one can (infinite gain)
        gain = None
        if scores["oblivious"]["worst_total_k1"] is not None \
                and scores["aware"]["worst_total_k1"] is not None:
            gain = (scores["oblivious"]["worst_total_k1"]
                    / scores["aware"]["worst_total_k1"])
        elif scores["aware"]["worst_total_k1"] is None:
            gain = 0.0                  # aware design itself disconnects
        cells.append({
            "model": name, "chiplets": chiplets,
            "oblivious": scores["oblivious"], "aware": scores["aware"],
            "gain_worst_k1": gain,
            "aware_survives_k1": scores["aware"]["n_disconnected_k1"] == 0,
            "same_design": obl_design == aw_design,
            "n_evals": obl.n_evals + aw.n_evals,
        })
    return {"chiplets": chiplets, "batch": batch, "requests": requests,
            "iterations": iterations, "ls_steps": ls_steps,
            "n_scenarios": n_scenarios, "ckpt_every": ckpt_every,
            "mttr_weight": mttr_weight, "seed": seed, "cells": cells}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, still writes JSON)")
    ap.add_argument("--chiplets", type=int, default=36,
                    choices=(36, 64, 100))
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--gen-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS, "BENCH_recovery_smoke.json" if args.smoke
            else "BENCH_recovery.json")

    chaos_models = ("qwen2.5-3b", "bart-large") if args.smoke else ZOO
    mttr_models = ("qwen2.5-3b", "bart-large") if args.smoke else ZOO
    if args.smoke:
        args.prompt_len, args.gen_len, args.batch = 64, 16, 4

    from benchmarks.common import emit

    rec = {
        "bench": "perf_recovery",
        "smoke": args.smoke,
        "chiplets": args.chiplets,
        "prompt_len": args.prompt_len,
        "gen_len": args.gen_len,
        "batch": args.batch,
        "chaos": run_chaos(
            chaos_models,
            quant_model="" if args.smoke else "qwen2.5-3b"),
        "mttr_noi_search": run_mttr_search(
            mttr_models, args.chiplets, args.prompt_len, args.gen_len,
            batch=args.batch,
            iterations=1 if args.smoke else 3,
            ls_steps=4 if args.smoke else 12,
            n_scenarios=4 if args.smoke else 8),
    }
    check_schema(rec)

    emit([{"model": c["model"],
           "kv_bits": c["kv_bits"] or "fp",
           "supported": c["supported"],
           "kills": len(c["kills"]),
           "kinds": "+".join(sorted({k["kind"] for k in c["kills"]})),
           "all_match": all(k["match"] for k in c["kills"]),
           "replayed": sum(k["replayed_requests"] for k in c["kills"])}
          for c in rec["chaos"]["cells"]],
         "recovery: chaos kill+restore exactly-once token semantics")
    emit([{"model": c["model"],
           "obl_worst_k1": c["oblivious"]["worst_total_k1"] or "disc",
           "obl_disc_k1": c["oblivious"]["n_disconnected_k1"],
           "aware_worst_k1": c["aware"]["worst_total_k1"] or "disc",
           "aware_disc_k1": c["aware"]["n_disconnected_k1"],
           "ckpt_overhead": c["aware"]["ckpt_overhead"],
           "gain_worst_k1": "inf" if c["gain_worst_k1"] is None
                            else c["gain_worst_k1"]}
          for c in rec["mttr_noi_search"]["cells"]],
         f"recovery: MTTR-aware vs fault-oblivious NoI designs "
         f"(k=1 chiplet loss, {args.chiplets} chiplets)")

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {os.path.normpath(args.out)}")


if __name__ == "__main__":
    main()
