"""§4.4: ReTransformer (ReRAM-only) write-endurance analysis — why the
dynamic kernels must NOT live on NVM crossbars."""
from repro.config import get_config
from repro.core.baselines import retransformer_endurance
from repro.core.chiplets import RERAM
from repro.core.traffic import Workload

from benchmarks.common import emit


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for arch, n in (("bert-base", 64), ("bert-base", 4096),
                    ("bert-large", 4096), ("llama2-7b", 4096)):
        w = Workload.from_config(get_config(arch), seq_len=n)
        rep = retransformer_endurance(w)
        rows.append({
            "arch": arch, "seq_len": n,
            "writes_per_cell_per_token": rep.writes_per_cell_per_token,
            "writes_per_encoder": rep.writes_per_encoder,
            "endurance_bound": RERAM.write_endurance,
            "feasible": rep.feasible,
            "days_to_failure_at_1khz": rep.days_to_failure_at_1khz,
        })
    if verbose:
        emit(rows, "sec4.4: ReRAM-only endurance")
    long_rows = [r for r in rows if r["seq_len"] == 4096]
    assert all(not r["feasible"] for r in long_rows)
    assert all(r["writes_per_encoder"] > 1e9 for r in long_rows)
    return rows


if __name__ == "__main__":
    run()
