"""Plane-B validation against the paper's own claims (§4, Figs 8-11,
Table 4).  Anything fitted is checked at its anchor; everything else is
checked as an *emergent* trend."""
import numpy as np
import pytest

from repro.config import get_config
from repro.core.baselines import (retransformer_endurance,
                                  simulate_haima_chiplet,
                                  simulate_transpim_chiplet)
from repro.core.simulator import ANCHORS, CALIB, simulate_2p5d_hi
from repro.core.traffic import Workload


def _w(arch, n):
    return Workload.from_config(get_config(arch), seq_len=n)


# ---------------------------------------------------------------------------
# Table 4 anchors (fitted — must be tight)
# ---------------------------------------------------------------------------

def test_table4_anchor_hi_bert():
    r = simulate_2p5d_hi(_w("bert-base", 64), 36)
    assert abs(np.log(r.latency_s * 1e3 / 50.0)) < 0.15   # ±15%


def test_table4_anchor_hi_gptj():
    r = simulate_2p5d_hi(_w("gpt-j", 64), 100)
    assert abs(np.log(r.latency_s * 1e3 / 143.0)) < 0.15


@pytest.mark.parametrize("fn,rows", [
    (simulate_haima_chiplet, ANCHORS["HAIMA_chiplet"]),
    (simulate_transpim_chiplet, ANCHORS["TransPIM_chiplet"]),
])
def test_table4_anchor_baselines(fn, rows):
    for arch, n, chips, target in rows:
        r = fn(_w(arch, n), chips)
        assert abs(np.log(r.latency_s * 1e3 / target)) < 0.02, (arch, chips)


# ---------------------------------------------------------------------------
# Fig 8: per-kernel latency, 36 chiplets — HI wins every kernel; FF largest
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 256])
def test_fig8_per_kernel_ordering(n):
    w = _w("bert-base", n)
    hi = simulate_2p5d_hi(w, 36)
    ha = simulate_haima_chiplet(w, 36)
    tp = simulate_transpim_chiplet(w, 36)
    gains = {}
    for k in ("embed", "kqv", "score", "ff"):
        assert hi.per_kernel_s[k] < ha.per_kernel_s[k], (k, "HAIMA")
        assert hi.per_kernel_s[k] < tp.per_kernel_s[k], (k, "TransPIM")
        gains[k] = min(ha.per_kernel_s[k], tp.per_kernel_s[k]) / hi.per_kernel_s[k]
    # "the performance gain is maximum for the FF layer" (§4.2)
    assert gains["ff"] >= max(gains["kqv"], gains["embed"]), gains


def test_fig8_haima_beats_transpim_on_score_only():
    """'HAIMA outperforms TransPIM in score computation' but loses overall
    at 36 chiplets (§4.2)."""
    w = _w("bert-base", 64)
    ha = simulate_haima_chiplet(w, 36)
    tp = simulate_transpim_chiplet(w, 36)
    assert ha.per_kernel_s["score"] < tp.per_kernel_s["score"]
    assert tp.latency_s < ha.latency_s


# ---------------------------------------------------------------------------
# Fig 9/10: scalability claims
# ---------------------------------------------------------------------------

def test_fig9_gain_grows_with_seq_len():
    """TransPIM-relative gain grows with N (the paper's 4.6→5.45 trend)."""
    gains = []
    for n in (64, 4096):
        w = _w("bart-large", n)
        hi = simulate_2p5d_hi(w, 64)
        tp = simulate_transpim_chiplet(w, 64)
        gains.append(tp.latency_s / hi.latency_s)
    assert gains[1] > gains[0], gains


def test_fig10_headline_gains():
    """'up to 11.8× latency and 2.36× lower energy' vs chiplet baselines —
    our max must land in the same regime (8–14× latency, ≥2× energy)."""
    best_lat, best_en = 0.0, 0.0
    for arch in ("gpt-j", "llama2-7b"):
        for n in (64, 256, 1024, 4096):
            w = _w(arch, n)
            hi = simulate_2p5d_hi(w, 100)
            for fn in (simulate_haima_chiplet, simulate_transpim_chiplet):
                b = fn(w, 100)
                best_lat = max(best_lat, b.latency_s / hi.latency_s)
                best_en = max(best_en, b.energy_j / hi.energy_j)
    assert 8.0 <= best_lat <= 14.0, best_lat
    assert best_en >= 2.0, best_en


def test_fig10_crossover_at_scale():
    """Table 4 @100 chiplets: HAIMA_chiplet (975) beats TransPIM_chiplet
    (1435) on GPT-J — the ordering flips vs the 36-chiplet BERT row."""
    w36, w100 = _w("bert-base", 64), _w("gpt-j", 64)
    assert (simulate_transpim_chiplet(w36, 36).latency_s
            < simulate_haima_chiplet(w36, 36).latency_s)
    assert (simulate_haima_chiplet(w100, 100).latency_s
            < simulate_transpim_chiplet(w100, 100).latency_s)


def test_fig10_originals_much_worse():
    """'up to 38× vs the original TransPIM and HAIMA' (§4.2)."""
    w = _w("gpt-j", 64)
    hi = simulate_2p5d_hi(w, 100)
    ho = simulate_haima_chiplet(w, 100, chiplet=False)
    to = simulate_transpim_chiplet(w, 100, chiplet=False)
    best = max(ho.latency_s, to.latency_s) / hi.latency_s
    assert 25.0 <= best <= 50.0, best
    # originals are strictly worse than their chiplet redesigns
    assert ho.latency_s > simulate_haima_chiplet(w, 100).latency_s
    assert to.latency_s > simulate_transpim_chiplet(w, 100).latency_s


def test_model_scalability_bigger_systems_faster():
    """2.5D-HI: the same workload runs faster on a bigger chiplet system."""
    w = _w("bert-large", 256)
    l36 = simulate_2p5d_hi(w, 36).latency_s
    l64 = simulate_2p5d_hi(w, 64).latency_s
    l100 = simulate_2p5d_hi(w, 100).latency_s
    assert l100 < l64 < l36


# ---------------------------------------------------------------------------
# §4.4 ReTransformer endurance
# ---------------------------------------------------------------------------

def test_endurance_matches_paper_orders():
    """'~1e7 writes per cell per token … 1e10 per encoder at N=4096' and
    infeasibility vs the ~1e8 endurance bound."""
    w = _w("bert-base", 4096)
    rep = retransformer_endurance(w)
    assert not rep.feasible
    assert rep.writes_per_encoder > 1e8
    w64 = _w("bert-base", 64)
    rep64 = retransformer_endurance(w64)
    assert rep64.writes_per_cell_per_token > 1e4  # grows to 1e7 at long N


# ---------------------------------------------------------------------------
# Fig 11: thermal
# ---------------------------------------------------------------------------

def test_fig11_baseline_stacks_exceed_dram_limit():
    """HAIMA/TransPIM 3-D stacks exceed the 95 °C DRAM ceiling (120–131 °C);
    3D-HI stays feasible."""
    from repro.core.thermal import baseline_stack_report, hi3d_stack_report
    for kind in ("haima", "transpim"):
        rep = baseline_stack_report(kind)
        assert rep.peak_c > 95.0, kind
        assert 110.0 < rep.peak_c < 140.0, (kind, rep.peak_c)
        assert not rep.dram_feasible
    rep = hi3d_stack_report(36)
    assert rep.dram_feasible, rep.peak_c


def test_fig11_edp_gain():
    """3D-HI EDP beats HAIMA by ~an order of magnitude at BERT-Large long-N
    (paper: 14.5× at n=2056)."""
    w = _w("bert-large", 2056)
    hi = simulate_2p5d_hi(w, 64)
    ha = simulate_haima_chiplet(w, 64)
    assert ha.edp / hi.edp > 5.0


# ---------------------------------------------------------------------------
# internal consistency
# ---------------------------------------------------------------------------

def test_latency_monotone_in_seq_len():
    lats = [simulate_2p5d_hi(_w("bert-base", n), 36).latency_s
            for n in (64, 128, 256, 512)]
    assert all(b > a for a, b in zip(lats, lats[1:]))


def test_energy_positive_and_scales():
    for arch, chips in (("bert-base", 36), ("gpt-j", 100)):
        r = simulate_2p5d_hi(_w(arch, 64), chips)
        assert r.energy_j > 0
        assert r.edp == pytest.approx(r.latency_s * r.energy_j)


def test_mqa_reduces_traffic_and_latency():
    """MQA (Llama2 per the paper) loads fewer K/V weights → lower kqv time
    than an MHA variant of the same dims."""
    mha = Workload(name="x", d_model=4096, n_layers=32, n_heads=32,
                   n_kv_heads=32, d_ff=11008, vocab=32000, seq_len=256)
    mqa = Workload(name="x", d_model=4096, n_layers=32, n_heads=32,
                   n_kv_heads=1, d_ff=11008, vocab=32000, seq_len=256)
    r_mha = simulate_2p5d_hi(mha, 100)
    r_mqa = simulate_2p5d_hi(mqa, 100)
    assert r_mqa.per_kernel_s["kqv"] < r_mha.per_kernel_s["kqv"]


def test_parallel_mha_ff_overlaps():
    """GPT-J's parallel formulation (eq. 9) is no slower than the serialized
    execution of identical phase times."""
    w_par = _w("gpt-j", 64)
    w_ser = Workload(**{**w_par.__dict__, "parallel_mha_ff": False})
    assert (simulate_2p5d_hi(w_par, 100).latency_s
            <= simulate_2p5d_hi(w_ser, 100).latency_s + 1e-9)
