"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 2:1.
[arXiv:2402.19427; unverified]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,          # MQA on the local-attention layers
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    pattern=("recurrent", "recurrent", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    rope_theta=10_000.0,
    act="gelu",
    glu=True,
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2402.19427",
    notes="38 = 12x(rec,rec,local) + 2 remainder recurrent layers",
))
