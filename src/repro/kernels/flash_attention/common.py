"""Shared constants and helpers for the flash-attention kernel family.

Single home for the masking sentinel and the block-alignment arithmetic
that `kernel.py`, `decode.py`, `ref.py` and `ops.py` previously each
copy-pasted.
"""
from __future__ import annotations

import jax.numpy as jnp

# Large-but-finite mask value: -inf would poison the online-softmax
# rescaling (exp(-inf - -inf) = NaN) on fully-masked rows; 0.7 * f32max
# keeps exp() underflowing to exactly 0.0 without overflow on negation.
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def block_size(cap: int, seq: int) -> int:
    """Kernel block edge: the requested block capped at the sequence."""
    return min(cap, seq)


def blocks_aligned(seq: int, cap: int) -> bool:
    """True when ``seq`` tiles exactly into ``block_size(cap, seq)`` blocks
    (the Pallas grids here require exact tiling; callers fall back to the
    reference path otherwise)."""
    return seq > 0 and seq % block_size(cap, seq) == 0


def vmem(shape, dtype=jnp.float32):
    """VMEM scratch allocation (works in interpret mode on CPU too)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
