"""Qwen3-30B-A3B — MoE, 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,            # assignment lists the per-expert intermediate size
    d_ff_expert=768,
    vocab_size=151_936,
    n_experts=128,
    top_k=8,
    n_shared_experts=0,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    glu=True,
    source="hf:Qwen/Qwen3-30B-A3B",
    notes="all layers MoE; per-head RMS q/k norm; GQA kv=4",
))
