"""Decode-aware co-simulation benchmark: serving-latency evaluation of the
chiplet architectures over the model zoo, under continuous batching.

For each model the full generation episode (prompt prefill + KV-cache
write-back + batched autoregressive decode) runs through
``simulate_generation`` on 2.5D-HI, HAIMA_chiplet and TransPIM_chiplet,
reporting TTFT, batched decode-step latency, decode tok/s over the batch,
energy per generated token and the prefill-vs-decode traffic split
(decode dominates: the KV cache is read at every step; batching amortises
the per-step weight streams, so each model also records its batched
decode-throughput uplift over a single stream).

Two further sections:

- **bridge** (full run only) — a real ``ServingEngine`` drain with a deep
  queue on a reduced config; its measured episode mix + active-slot
  histogram (``stats()`` → ``core.cosim.mix_from_stats``) is projected
  onto the full-size model and replayed through Plane B at the measured
  slot-pool occupancy, next to the single-stream replay;
- **noi_sweep** — decode-aware MOO-STAGE NoI design search
  (``core.cosim.generation_objective``: batched decode traffic +
  chunk-interleaved prefill) across system sizes × zoo models, emitting
  the Pareto front per cell and comparing it against the design the same
  search budget finds under *single-pass* traffic (the pre-generation
  objective), both evaluated under the generation traffic;
- **quant_sweep** — the precision plane: every zoo model's generation
  episode at fp16 / int8 / int4 weight+KV precision
  (``Workload(weight_bits=, kv_bits=)``), reporting the decode
  traffic/step-latency reduction quantisation buys, plus a
  quantised-vs-fp NoI comparison (design searched under the *quantised*
  generation traffic vs the same budget's fp-traffic design, both scored
  under the quantised traffic) for a subset of models.

    PYTHONPATH=src python -m benchmarks.perf_cosim [--smoke]

Results: ``experiments/BENCH_cosim.json`` (``BENCH_cosim_smoke.json`` with
``--smoke`` so CI never clobbers the recorded full run); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

ARCHS = ("2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet")

# model zoo sweep: paper workloads + assigned archs covering MHA, GQA/MQA,
# parallel-block and encoder-decoder stacks
ZOO = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b",
       "bart-large", "whisper-large-v3")

SWEEP_SIZES = (36, 64, 100)

_ARCH_KEYS = {"ttft_ms", "decode_step_ms", "decode_tok_s", "tokens_per_s",
              "energy_per_token_mj", "prefill_gb", "decode_gb",
              "decode_traffic_frac", "batch", "batch_uplift"}

_SWEEP_KEYS = {"model", "chiplets", "front", "best_mu_norm",
               "best_sigma_norm", "single_pass_mu_norm",
               "single_pass_sigma_norm", "gain_mu", "same_design", "n_evals"}

_QUANT_KEYS = {"model", "weight_bits", "kv_bits", "ttft_ms",
               "decode_step_ms", "decode_gb", "weight_stream_gb",
               "energy_per_token_mj", "decode_step_speedup_vs_fp",
               "decode_traffic_reduction_vs_fp"}

_QUANT_NOI_KEYS = {"front", "best_mu_norm", "best_sigma_norm",
                   "fp_design_mu_norm", "fp_design_sigma_norm", "gain_mu",
                   "same_design", "n_evals"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_cosim.json record shape (CI bit-rot gate)."""
    for key in ("bench", "smoke", "chiplets", "prompt_len", "gen_len",
                "batch", "models", "noi_sweep", "quant_sweep"):
        assert key in rec, f"missing top-level key {key!r}"
    assert len(rec["models"]) >= 4 or rec["smoke"], "zoo must cover ≥4 models"
    saw_gqa = saw_encdec = False
    for name, row in rec["models"].items():
        saw_gqa |= row["kv_frac"] < 1.0
        saw_encdec |= row["enc_dec"]
        for arch in ARCHS:
            missing = _ARCH_KEYS - set(row["archs"][arch])
            assert not missing, f"{name}/{arch} missing {missing}"
    if not rec["smoke"]:
        assert saw_gqa and saw_encdec, "zoo must include GQA and enc-dec"
    cells = rec["noi_sweep"]["cells"]
    for cell in cells:
        missing = _SWEEP_KEYS - set(cell)
        assert not missing, f"noi_sweep cell missing {missing}"
        assert cell["front"], f"empty Pareto front for {cell['model']}"
    if not rec["smoke"]:
        sizes = {c["chiplets"] for c in cells}
        models = {c["model"] for c in cells}
        assert len(sizes) >= 3, f"sweep must cover >=3 system sizes: {sizes}"
        assert len(models) >= 6, f"sweep must cover >=6 models: {models}"
    qcells = rec["quant_sweep"]["cells"]
    saw_noi = False
    for cell in qcells:
        missing = _QUANT_KEYS - set(cell)
        assert not missing, f"quant_sweep cell missing {missing}"
        if "noi" in cell:
            saw_noi = True
            missing = _QUANT_NOI_KEYS - set(cell["noi"])
            assert not missing, f"quant_sweep noi cell missing {missing}"
    assert saw_noi, "quant_sweep must include at least one NoI comparison"
    grid = {(c["weight_bits"], c["kv_bits"]) for c in qcells}
    assert (16, 16) in grid and (8, 8) in grid, f"quant grid too small: {grid}"
    if not rec["smoke"]:
        assert (4, 4) in grid, f"full quant grid must include int4: {grid}"


def _row(g, g1) -> dict:
    return {
        "ttft_ms": g.ttft_s * 1e3,
        "decode_step_ms": g.decode_step_s * 1e3,
        "decode_tok_s": g.decode_tok_s,
        "tokens_per_s": g.tokens_per_s,
        "energy_per_token_mj": g.energy_per_token_j * 1e3,
        "prefill_gb": g.prefill_bytes / 2**30,
        "decode_gb": g.decode_bytes / 2**30,
        "decode_traffic_frac": g.decode_bytes
                               / max(g.prefill_bytes + g.decode_bytes, 1e-30),
        "batch": g.batch,
        # batched decode throughput over the same episode single-streamed
        "batch_uplift": g.decode_tok_s / max(g1.decode_tok_s, 1e-30),
    }


def run_zoo(models, chiplets: int, prompt_len: int, gen_len: int,
            batch: int) -> dict:
    from repro.config import get_config
    from repro.core.simulator import simulate_generation
    from repro.core.traffic import Workload

    out = {}
    for name in models:
        cfg = get_config(name)
        w = Workload.from_config(cfg, seq_len=prompt_len)
        archs = {}
        for a in ARCHS:
            g = simulate_generation(w, chiplets, prompt_len, gen_len,
                                    arch=a, batch=batch)
            g1 = g if batch == 1 else simulate_generation(
                w, chiplets, prompt_len, gen_len, arch=a)
            archs[a] = _row(g, g1)
        hi = archs["2.5D-HI"]
        base_ttft = min(archs[a]["ttft_ms"] for a in ARCHS[1:])
        base_step = min(archs[a]["decode_step_ms"] for a in ARCHS[1:])
        base_epr = min(archs[a]["energy_per_token_mj"] for a in ARCHS[1:])
        out[name] = {
            "family": cfg.family,
            "kv_frac": w.kv_frac,
            "enc_dec": w.enc_dec,
            "archs": archs,
            "ttft_gain": base_ttft / hi["ttft_ms"],
            "decode_gain": base_step / hi["decode_step_ms"],
            "energy_gain": base_epr / hi["energy_per_token_mj"],
        }
    return out


def run_bridge(arch: str, chiplets: int) -> dict:
    """Measured-engine bridge: drain a deep queue (continuous batching
    keeps the slot pool busy) on the reduced config, project the measured
    episode mix + active-slot histogram onto the full model, and replay it
    both at the measured occupancy and single-streamed."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_config, reduce_config
    from repro.core.cosim import cosim_from_engine, cosim_mix, mix_from_stats
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0), param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=64, max_new_tokens=8, prefill_chunk=32))
    rng = np.random.default_rng(0)
    # deep queue: 3× the slot pool, so admission back-fills freed slots and
    # the active-slot histogram reflects real continuous batching
    for plen in (6, 10, 14, 10, 22, 6, 18, 10, 6, 14, 10, 22):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    full = get_config(arch)
    rec = cosim_from_engine(eng, cfg=full, n_chiplets=chiplets)
    rec["archs_batch1"] = cosim_mix(full, mix_from_stats(eng.stats()),
                                    chiplets, batch=1)
    rec["arch"] = arch
    rec["backend"] = jax.default_backend()
    return rec


def run_noi_sweep(models, sizes, prompt_len: int, gen_len: int, *,
                  requests: int = 4, batch: int = 8, iterations: int = 3,
                  ls_steps: int = 12, seed: int = 0) -> dict:
    """Decode-aware NoI Pareto sweep: for every system size × zoo model,
    MOO-STAGE under the *generation* traffic (batched decode +
    chunk-interleaved prefill) vs the design the same search budget finds
    under *single-pass* traffic — both scored under the generation
    objective, normalised to the placement-unaware mesh."""
    import numpy as np

    from repro.config import get_config
    from repro.core.cosim import (Episode, EpisodeMix, generation_objective,
                                  seeded_noi_search)
    from repro.core.noi import evaluate_noi, mesh_baseline_eval
    from repro.core.traffic import Workload, transformer_phases

    chunk = max(prompt_len // 4, 1)
    cells = []
    for chips in sizes:
        for name in models:
            mix = EpisodeMix([Episode(prompt_len, gen_len, requests)],
                             prefill_chunk=chunk, max_batch=batch,
                             active_hist={batch: 1},
                             max_stall_tokens=chunk)
            # one objective instance searches AND scores the control, so
            # both sides are guaranteed to see the same traffic model
            gen_obj, _, _ = generation_objective(name, mix, chips)
            res = seeded_noi_search(gen_obj, chips, iterations=iterations,
                                    ls_steps=ls_steps, seed=seed)
            objs = np.asarray(res.archive.objs)
            best_idx = int(np.argmin(objs[:, 0]))
            best = res.archive.objs[best_idx]
            best_design = res.archive.designs[best_idx]

            # single-pass-optimised design: same search budget, but the
            # objective only sees one fixed-length forward pass (the
            # pre-generation traffic model) — then score it under the
            # generation traffic
            w = Workload.from_config(get_config(name), seq_len=prompt_len)
            sp_phases = transformer_phases(w)
            sp_mesh = mesh_baseline_eval(chips, sp_phases)

            def sp_objective(p):
                ev = evaluate_noi(p, sp_phases)
                return (ev.mu / sp_mesh.mu, ev.sigma / sp_mesh.sigma)

            sp_res = seeded_noi_search(sp_objective, chips,
                                       iterations=iterations,
                                       ls_steps=ls_steps, seed=seed)
            sp_objs = np.asarray(sp_res.archive.objs)
            sp_design = sp_res.archive.designs[int(np.argmin(sp_objs[:, 0]))]
            sp_under_gen = gen_obj(sp_design)

            cells.append({
                "model": name, "chiplets": chips,
                "front": sorted([float(m), float(s)]
                                for m, s in res.archive.objs),
                "best_mu_norm": float(best[0]),
                "best_sigma_norm": float(best[1]),
                "single_pass_mu_norm": float(sp_under_gen[0]),
                "single_pass_sigma_norm": float(sp_under_gen[1]),
                "gain_mu": float(sp_under_gen[0] / max(best[0], 1e-30)),
                # both same-seed searches can converge to the very same
                # placement — flagged so a 1.0× gain is readable as "the
                # searches coincided", not "decode-awareness is free"
                "same_design": sp_design == best_design,
                "n_evals": res.n_evals + sp_res.n_evals,
            })
    return {"sizes": list(sizes), "models": list(models), "batch": batch,
            "requests": requests, "iterations": iterations,
            "ls_steps": ls_steps, "cells": cells}


def run_quant_sweep(models, chiplets: int, prompt_len: int, gen_len: int, *,
                    batch: int = 8, bits_grid=((16, 16), (8, 8), (4, 4)),
                    noi_models=None, requests: int = 4, iterations: int = 3,
                    ls_steps: int = 12, seed: int = 0) -> dict:
    """Precision sweep: each zoo model's generation episode re-simulated at
    every (weight_bits, kv_bits) point — decode traffic and step latency
    fall as the quantised bytes fall — plus, for ``noi_models``, a
    quantised-vs-fp NoI design comparison: MOO-STAGE under the *quantised*
    generation traffic vs the design the same budget finds under fp
    traffic, both scored under the quantised objective (normalised to its
    mesh baseline)."""
    import dataclasses

    import numpy as np

    from repro.config import get_config
    from repro.core.cosim import (Episode, EpisodeMix, generation_objective,
                                  seeded_noi_search)
    from repro.core.simulator import simulate_generation
    from repro.core.traffic import Workload, decode_weight_stream_bytes

    noi_models = set(noi_models if noi_models is not None else models[:2])
    chunk = max(prompt_len // 4, 1)
    steps = max(gen_len - 1, 1)
    cells = []
    for name in models:
        cfg = get_config(name)
        fp_cell = None
        fp_search = None              # fp-traffic control: one search/model
        for wb, kb in bits_grid:
            w = Workload.from_config(cfg, seq_len=prompt_len,
                                     weight_bits=wb, kv_bits=kb)
            g = simulate_generation(w, chiplets, prompt_len, gen_len,
                                    arch="2.5D-HI", batch=batch)
            wstream = decode_weight_stream_bytes(w) * steps / batch
            cell = {
                "model": name, "weight_bits": wb, "kv_bits": kb,
                "ttft_ms": g.ttft_s * 1e3,
                "decode_step_ms": g.decode_step_s * 1e3,
                "decode_gb": g.decode_bytes / 2**30,
                "weight_stream_gb": wstream / 2**30,
                "energy_per_token_mj": g.energy_per_token_j * 1e3,
            }
            if (wb, kb) == (16, 16):
                fp_cell = cell
            base = fp_cell or cell      # grid is fp-first by construction
            cell["decode_step_speedup_vs_fp"] = \
                base["decode_step_ms"] / max(cell["decode_step_ms"], 1e-30)
            cell["decode_traffic_reduction_vs_fp"] = \
                base["decode_gb"] / max(cell["decode_gb"], 1e-30)

            if name in noi_models and (wb, kb) != (16, 16):
                mix_q = EpisodeMix([Episode(prompt_len, gen_len, requests)],
                                   prefill_chunk=chunk, max_batch=batch,
                                   active_hist={batch: 1},
                                   max_stall_tokens=chunk,
                                   weight_bits=wb, kv_bits=kb)
                q_obj, _, _ = generation_objective(name, mix_q, chiplets)
                res = seeded_noi_search(q_obj, chiplets,
                                        iterations=iterations,
                                        ls_steps=ls_steps, seed=seed)
                objs = np.asarray(res.archive.objs)
                bi = int(np.argmin(objs[:, 0]))
                best = res.archive.objs[bi]
                best_design = res.archive.designs[bi]
                if fp_search is None:
                    # the fp-traffic control is identical for every bits
                    # point of this model — search once, reuse the design
                    mix_fp = dataclasses.replace(mix_q, weight_bits=16,
                                                 kv_bits=16)
                    fp_obj, _, _ = generation_objective(name, mix_fp,
                                                        chiplets)
                    fp_res = seeded_noi_search(fp_obj, chiplets,
                                               iterations=iterations,
                                               ls_steps=ls_steps, seed=seed)
                    fp_objs = np.asarray(fp_res.archive.objs)
                    fp_search = (
                        fp_res.archive.designs[int(np.argmin(fp_objs[:, 0]))],
                        fp_res.n_evals)
                fp_design, fp_evals = fp_search
                under_q = q_obj(fp_design)
                cell["noi"] = {
                    "front": sorted([float(m), float(s)]
                                    for m, s in res.archive.objs),
                    "best_mu_norm": float(best[0]),
                    "best_sigma_norm": float(best[1]),
                    "fp_design_mu_norm": float(under_q[0]),
                    "fp_design_sigma_norm": float(under_q[1]),
                    "gain_mu": float(under_q[0] / max(best[0], 1e-30)),
                    "same_design": fp_design == best_design,
                    "n_evals": res.n_evals + fp_evals,
                }
            cells.append(cell)
    return {"models": list(models), "chiplets": chiplets, "batch": batch,
            "prompt_len": prompt_len, "gen_len": gen_len,
            "bits_grid": [list(b) for b in bits_grid],
            "noi_models": sorted(noi_models), "requests": requests,
            "iterations": iterations, "ls_steps": ls_steps, "cells": cells}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, still writes JSON)")
    ap.add_argument("--chiplets", type=int, default=64, choices=(36, 64, 100))
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--gen-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8,
                    help="decode batch (slot-pool occupancy) for the zoo "
                         "sweep and the NoI search traffic")
    ap.add_argument("--bridge-arch", default="qwen2.5-3b")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS,
            "BENCH_cosim_smoke.json" if args.smoke else "BENCH_cosim.json")

    models = ("gemma2-9b", "bart-large") if args.smoke else ZOO
    sizes = (36,) if args.smoke else SWEEP_SIZES
    if args.smoke:
        args.prompt_len, args.gen_len, args.batch = 64, 16, 4

    from benchmarks.common import emit

    rec = {
        "bench": "perf_cosim",
        "smoke": args.smoke,
        "chiplets": args.chiplets,
        "prompt_len": args.prompt_len,
        "gen_len": args.gen_len,
        "batch": args.batch,
        "models": run_zoo(models, args.chiplets, args.prompt_len,
                          args.gen_len, args.batch),
        "noi_sweep": run_noi_sweep(
            models, sizes, args.prompt_len, args.gen_len, batch=args.batch,
            iterations=1 if args.smoke else 3,
            ls_steps=4 if args.smoke else 12),
        "quant_sweep": run_quant_sweep(
            models, args.chiplets, args.prompt_len, args.gen_len,
            batch=args.batch,
            bits_grid=((16, 16), (8, 8)) if args.smoke
            else ((16, 16), (8, 8), (4, 4)),
            noi_models=models[:1] if args.smoke else models[:2],
            iterations=1 if args.smoke else 3,
            ls_steps=4 if args.smoke else 12),
    }
    if not args.smoke:
        rec["bridge"] = run_bridge(args.bridge_arch, args.chiplets)
    check_schema(rec)

    rows = []
    for name, m in rec["models"].items():
        for arch in ARCHS:
            r = m["archs"][arch]
            rows.append({"model": name, "system": arch,
                         "ttft_ms": r["ttft_ms"],
                         "decode_ms_per_tok": r["decode_step_ms"],
                         "decode_tok_s": r["decode_tok_s"],
                         "batch_uplift": r["batch_uplift"],
                         "energy_mj_per_tok": r["energy_per_token_mj"],
                         "decode_traffic_frac": r["decode_traffic_frac"]})
    emit(rows, f"cosim: generation episodes ({args.chiplets} chiplets, "
               f"prompt={args.prompt_len}, gen={args.gen_len}, "
               f"batch={args.batch})")
    emit([{"model": c["model"], "chiplets": c["chiplets"],
           "pareto_pts": len(c["front"]),
           "best_mu_norm": c["best_mu_norm"],
           "single_pass_mu_norm": c["single_pass_mu_norm"],
           "gain_mu": c["gain_mu"]}
          for c in rec["noi_sweep"]["cells"]],
         "cosim: decode-aware NoI Pareto sweep vs single-pass designs")
    emit([{"model": c["model"], "bits": f"w{c['weight_bits']}kv{c['kv_bits']}",
           "decode_ms": c["decode_step_ms"],
           "decode_gb": c["decode_gb"],
           "traffic_reduction": c["decode_traffic_reduction_vs_fp"],
           "step_speedup": c["decode_step_speedup_vs_fp"],
           "noi_gain_mu": c.get("noi", {}).get("gain_mu", "")}
          for c in rec["quant_sweep"]["cells"]],
         "cosim: quantised-vs-fp precision sweep")

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {os.path.normpath(args.out)}")


if __name__ == "__main__":
    main()
