PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow verify bench-serving bench-capacity bench-cosim bench-quant bench-resilience bench-recovery bench-spec bench-calib bench-smoke report

test:               ## tier-1 test suite (everything, slow included)
	$(PY) -m pytest -x -q

test-slow:          ## only the slow-marked tests (CI runs these non-blocking)
	$(PY) -m pytest -q -m slow

bench-serving:      ## full serving decode+prefill benchmark -> experiments/BENCH_serving.json
	$(PY) -m benchmarks.perf_serving

bench-capacity:     ## tail latency vs offered load per scheduler -> experiments/BENCH_capacity.json
	$(PY) -m benchmarks.perf_capacity

bench-cosim:        ## generation co-simulation sweep (zoo x architectures) -> experiments/BENCH_cosim.json
	$(PY) -m benchmarks.perf_cosim

bench-quant:        ## quantised serving: parity/drift + Plane-B projection -> experiments/BENCH_quant.json
	$(PY) -m benchmarks.perf_quant

bench-resilience:   ## fault sweeps + fault-aware NoI search + overload shedding -> experiments/BENCH_resilience.json
	$(PY) -m benchmarks.perf_resilience

bench-recovery:     ## chaos kill+restore + MTTR-aware NoI search -> experiments/BENCH_recovery.json
	$(PY) -m benchmarks.perf_recovery

bench-spec:         ## speculative decoding: engine uplift + acceptance sweep + NoI comparison -> experiments/BENCH_spec.json
	$(PY) -m benchmarks.perf_spec

bench-calib:        ## measured-cost calibration: profile kernels, fit Plane-B rates, pin residuals -> experiments/BENCH_calib.json
	$(PY) -m benchmarks.perf_calib

bench-smoke:        ## tiny-config serving+capacity+cosim+quant+resilience+recovery+spec+calib benchmarks; assert the JSON report schemas
	$(PY) -m benchmarks.perf_serving --smoke
	$(PY) -m benchmarks.perf_capacity --smoke
	$(PY) -m benchmarks.perf_cosim --smoke
	$(PY) -m benchmarks.perf_quant --smoke
	$(PY) -m benchmarks.perf_resilience --smoke
	$(PY) -m benchmarks.perf_recovery --smoke
	$(PY) -m benchmarks.perf_spec --smoke
	$(PY) -m benchmarks.perf_calib --smoke

# slow-marked tests run in their own non-blocking CI job (test-slow)
verify:             ## CI gate: fast tests + bench smokes (schema-checked)
	$(PY) -m pytest -x -q -m "not slow"
	$(MAKE) bench-smoke

report:             ## render benchmark/dry-run tables
	$(PY) -m benchmarks.report
