"""NoI design variables λ = (λc, λl): chiplet placement + link graph (§3.3).

Constraints (paper): (1) the NoI connects all chiplets (no islands);
(2) link count ≤ the 2-D mesh budget.  Moves used by every MOO solver:
swap two chiplet positions, remove a link, add a (short-range) link.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import deque

import numpy as np

from repro.core.chiplets import SYSTEM_ALLOC
from repro.core.sfc import curve_positions


@dataclasses.dataclass
class Placement:
    """λc: grid of chiplet types + role id lists; λl: set of links."""
    grid_w: int
    grid_h: int
    types: list[str]                  # per cell: "SM"|"MC"|"DRAM"|"ReRAM"|...
    links: set                        # {(a, b)} a<b cell ids
    reram_order: list[int]            # SFC order of the ReRAM macro (dataflow)

    @property
    def n(self) -> int:
        return self.grid_w * self.grid_h

    def roles(self) -> dict[str, list[int]]:
        out: dict[str, list[int]] = {}
        for i, t in enumerate(self.types):
            out.setdefault(t, []).append(i)
        if self.reram_order:
            out["ReRAM"] = list(self.reram_order)
        return out

    def xy(self, i: int) -> tuple[int, int]:
        return i % self.grid_w, i // self.grid_w

    def copy(self) -> "Placement":
        return Placement(self.grid_w, self.grid_h, list(self.types),
                         set(self.links), list(self.reram_order))

    def connected(self) -> bool:
        if not self.links:
            return self.n == 1
        adj: dict[int, list[int]] = {}
        for a, b in self.links:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, []).append(a)
        seen = {0}
        q = deque([0])
        while q:
            u = q.popleft()
            for v in adj.get(u, ()):  # noqa: B905
                if v not in seen:
                    seen.add(v)
                    q.append(v)
        return len(seen) == self.n


def mesh_links(w: int, h: int) -> set:
    links = set()
    for y in range(h):
        for x in range(w):
            i = y * w + x
            if x + 1 < w:
                links.add((i, i + 1))
            if y + 1 < h:
                links.add((i, i + w))
    return links


def grid_for(n_chiplets: int) -> tuple[int, int]:
    w = int(math.isqrt(n_chiplets))
    while n_chiplets % w:
        w -= 1
    return max(w, n_chiplets // w), min(w, n_chiplets // w)


def initial_placement(n_chiplets: int, *, curve: str = "boustrophedon",
                      extra: dict | None = None,
                      seed: int = 0) -> Placement:
    """2.5D-HI seed design: ReRAM macro laid along an SFC, MC/DRAM pairs
    adjacent, SM clusters blocked around their MC (§3.2 placement logic)."""
    alloc = dict(SYSTEM_ALLOC.get(n_chiplets) or {})
    if not alloc:
        raise ValueError(f"no Table-2 allocation for {n_chiplets} chiplets")
    if extra:
        alloc.update(extra)
    w, h = grid_for(n_chiplets)
    pos_order = [int(y * w + x) for x, y in curve_positions(curve, w, h)]

    types = ["SM"] * (w * h)
    # walk the SFC: first the ReRAM macro (contiguous), then MC+DRAM pairs,
    # SMs fill the rest
    cursor = 0
    reram_cells = []
    for _ in range(alloc["ReRAM"]):
        reram_cells.append(pos_order[cursor])
        cursor += 1
    mc_cells, dram_cells = [], []
    for _ in range(alloc["MC"]):
        mc_cells.append(pos_order[cursor]); cursor += 1
        dram_cells.append(pos_order[cursor]); cursor += 1
    for c in reram_cells:
        types[c] = "ReRAM"
    for c in mc_cells:
        types[c] = "MC"
    for c in dram_cells:
        types[c] = "DRAM"
    return Placement(w, h, types, mesh_links(w, h), reram_cells)


def random_placement(n_chiplets: int, rng: random.Random,
                     extra: dict | None = None) -> Placement:
    p = initial_placement(n_chiplets, extra=extra)
    cells = list(range(p.n))
    rng.shuffle(cells)
    old_types = list(p.types)
    order = sorted(range(p.n))
    for new_cell, old_cell in zip(cells, order):
        p.types[new_cell] = old_types[old_cell]
    p.reram_order = [c for c in cells if p.types[c] == "ReRAM"]
    return p


# ---------------------------------------------------------------------------
# neighbourhood moves (shared by local search / AMOSA / NSGA-II mutation)
# ---------------------------------------------------------------------------

def neighbors(p: Placement, rng: random.Random, k: int = 8) -> list[Placement]:
    out = []
    mesh_budget = len(mesh_links(p.grid_w, p.grid_h))
    for _ in range(k):
        q = p.copy()
        move = rng.random()
        if move < 0.5:  # swap two chiplets
            a, b = rng.sample(range(q.n), 2)
            q.types[a], q.types[b] = q.types[b], q.types[a]
            remap = {a: b, b: a}
            q.reram_order = [remap.get(c, c) for c in q.reram_order]
        elif move < 0.75 and len(q.links) > q.n - 1:  # drop a link
            q.links.discard(rng.choice(sorted(q.links)))
            if not q.connected():
                continue
        else:  # add a short-range link under the mesh budget
            if len(q.links) >= mesh_budget:
                continue
            a = rng.randrange(q.n)
            ax, ay = q.xy(a)
            bx = min(max(ax + rng.randint(-2, 2), 0), q.grid_w - 1)
            by = min(max(ay + rng.randint(-2, 2), 0), q.grid_h - 1)
            b = by * q.grid_w + bx
            if a != b:
                q.links.add((min(a, b), max(a, b)))
        out.append(q)
    return out


def design_features(p: Placement) -> np.ndarray:
    """Summary features for the MOO-STAGE surrogate (core/rf.py)."""
    roles = p.roles()
    xy = np.array([p.xy(i) for i in range(p.n)], float)

    def centroid(ids):
        return xy[ids].mean(axis=0) if ids else np.zeros(2)

    def mean_dist(src, dst):
        if not src or not dst:
            return 0.0
        a, b = xy[src], xy[dst]
        return float(np.abs(a[:, None, :] - b[None, :, :]).sum(-1).mean())

    rer = roles.get("ReRAM", [])
    contig = 0.0
    if len(rer) > 1:
        pts = xy[rer]
        contig = float(np.abs(np.diff(pts, axis=0)).sum(1).mean())
    feats = [
        mean_dist(roles.get("SM", []), roles.get("MC", [])),
        mean_dist(roles.get("MC", []), roles.get("DRAM", [])),
        mean_dist(roles.get("MC", []), rer[:1]),
        contig,
        len(p.links) / max(len(mesh_links(p.grid_w, p.grid_h)), 1),
        float(np.linalg.norm(centroid(roles.get("SM", []))
                             - centroid(roles.get("MC", [])))),
        float(np.linalg.norm(centroid(rer) - centroid(roles.get("MC", []))))
        if rer else 0.0,
        float(len(rer)),
    ]
    return np.asarray(feats, dtype=np.float64)
