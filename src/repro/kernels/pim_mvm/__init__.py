from repro.kernels.pim_mvm.ops import pim_mvm, quantize_weights  # noqa: F401
from repro.kernels.pim_mvm.ref import pim_mvm_ref  # noqa: F401
