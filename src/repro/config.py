"""Model / shape / run configuration for the 2.5D-HI reproduction framework.

A :class:`ModelConfig` fully describes one of the supported transformer
architectures (the 10 assigned archs plus the paper's own six workloads).
The model library in :mod:`repro.models` consumes only this dataclass — no
architecture-specific code paths exist outside the fields declared here.

Layer heterogeneity (local vs. global attention, recurrent blocks, SSM
blocks, VLM cross-attention layers) is expressed as a *layer pattern*: a
short tuple of layer-kind strings that is cycled over ``n_layers``.  The
model stacks each maximal run of full pattern periods into a single
``jax.lax.scan`` group so HLO size (and dry-run compile time) stays O(1)
in depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Layer kinds understood by repro.models.transformer
LAYER_KINDS = ("global", "local", "recurrent", "ssm", "cross")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell: seq_len x global_batch x step kind."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity ---------------------------------------------------------
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | encoder | encdec
    # -- core dims --------------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # -- layer pattern ----------------------------------------------------
    pattern: tuple[str, ...] = ("global",)
    window: int = 0  # local-attention window (tokens)
    # -- attention flavour -------------------------------------------------
    attn_softcap: float = 0.0       # gemma2 attention-logit softcap
    final_softcap: float = 0.0      # gemma2 final-logit softcap
    qk_norm: bool = False           # qwen3 / gemma3 per-head RMS q,k norm
    qkv_bias: bool = False          # qwen2.5 bias on qkv projections
    mlp_bias: bool = False          # whisper/bert style biases
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0   # gemma3: distinct theta for local layers
    use_rope: bool = True           # whisper/bert use absolute positions
    max_abs_positions: int = 0      # learned/sinusoidal table size (no-rope)
    # -- MLP --------------------------------------------------------------
    act: str = "silu"               # silu | gelu | relu2
    glu: bool = True                # gated (w1,w3) MLP vs plain
    parallel_block: bool = False    # GPT-J: attn and MLP in parallel
    post_norm: bool = False         # gemma2/3: extra post-sublayer norms
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma: embeddings scaled by sqrt(d)
    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0          # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25
    # -- MLA (deepseek) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0          # decoupled rope dims per head
    v_head_dim: int = 0             # 0 -> head_dim
    # -- SSM (mamba2) -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    # -- recurrent (RG-LRU / griffin) --------------------------------------
    lru_width: int = 0
    # -- encoder/decoder ----------------------------------------------------
    n_encoder_layers: int = 0       # 0 -> decoder-only
    encoder_pattern: tuple[str, ...] = ("global",)
    cross_attn_decoder: bool = False  # enc-dec: each decoder block has cross
    frontend: str = "none"          # none | audio_stub | vision_stub
    n_frontend_tokens: int = 1024   # stub cross-attn source length (vlm)
    # -- provenance ---------------------------------------------------------
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        for k in self.pattern + self.encoder_pattern:
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")

    # -- derived ---------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind for the (decoder) stack, pattern cycled."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def encoder_layer_kinds(self) -> tuple[str, ...]:
        p = self.encoder_pattern
        return tuple(p[i % len(p)] for i in range(self.n_encoder_layers))

    @property
    def attn_free(self) -> bool:
        kinds = set(self.layer_kinds)
        return not (kinds & {"global", "local", "cross"})

    @property
    def subquadratic(self) -> bool:
        """True iff every layer's per-token cost is bounded in context length
        (SSM / recurrent / windowed-local states).  Archs with *any* global
        full-attention layer are still run for long_500k when the rest of the
        stack bounds memory (gemma2/3 hybrid-window) — see ``supports``."""
        return not any(k == "global" for k in self.layer_kinds)

    @property
    def has_bounded_state_layers(self) -> bool:
        kinds = set(self.layer_kinds)
        return bool(kinds & {"local", "recurrent", "ssm"})

    def moe_layer_mask(self) -> tuple[bool, ...]:
        if not self.is_moe:
            return tuple(False for _ in range(self.n_layers))
        return tuple(i >= self.first_k_dense for i in range(self.n_layers))

    # -- parameter counting (used by roofline + simulator) -----------------
    def param_count(self) -> int:
        """Exact parameter count implied by this config (matches init)."""
        from repro.models.transformer import count_params  # lazy, no jax at import
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)

    # -- shape applicability ------------------------------------------------
    def supports(self, shape: ShapeSpec) -> tuple[bool, str]:
        """(runnable, reason-if-not) for an assigned shape cell."""
        if shape.kind == "decode" and self.family == "encoder":
            return False, "encoder-only architecture has no decode step"
        if shape.name == "long_500k":
            if self.family == "audio":
                return False, ("whisper decoder max context is 448 tokens; "
                               "524k decode is architecturally undefined")
            if not (self.subquadratic or self.has_bounded_state_layers):
                return False, ("pure full-attention stack: long_500k requires "
                               "sub-quadratic attention (per assignment)")
        if shape.kind == "train" and shape.global_batch % 8:
            return False, "global batch must divide the data axes"
        return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}") from None


def list_archs(assigned_only: bool = False) -> list[str]:
    _ensure_loaded()
    names = sorted(_REGISTRY)
    if assigned_only:
        names = [n for n in names if n in ASSIGNED_ARCHS]
    return names


ASSIGNED_ARCHS = (
    "qwen3-moe-30b-a3b",
    "deepseek-v2-236b",
    "recurrentgemma-9b",
    "whisper-large-v3",
    "qwen2.5-3b",
    "gemma3-27b",
    "gemma2-9b",
    "minitron-8b",
    "mamba2-130m",
    "llama-3.2-vision-90b",
)

PAPER_ARCHS = (
    "bert-base", "bert-large", "bart-base", "bart-large", "gpt-j", "llama2-7b",
)

_loaded = False


def _ensure_loaded():
    global _loaded
    if not _loaded:
        import repro.configs  # noqa: F401  (registers everything)
        _loaded = True


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, *, seq_len: int = 32) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving its *family shape*:
    same pattern kinds, same attention flavour, same MoE/MLA/SSM structure,
    tiny dims.  One full pattern period (at least) of layers is kept."""
    n_layers = max(len(cfg.pattern), 2)
    # keep a remainder layer when the full model has one, to exercise the
    # remainder-group code path
    if cfg.n_layers % len(cfg.pattern):
        n_layers += 1
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads else 0
    if n_kv and n_heads % n_kv:
        n_kv = 2 if n_heads % 2 == 0 else 1
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        q_lora_rank=48 if cfg.q_lora_rank else 0,
        rope_head_dim=8 if cfg.rope_head_dim else 0,
        v_head_dim=16,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        max_abs_positions=max(seq_len * 2, 64) if cfg.max_abs_positions else 0,
        n_frontend_tokens=16,
        first_k_dense=min(cfg.first_k_dense, 1),
    )


def flops_per_token(cfg: ModelConfig) -> float:
    """~6*N_active for training, per token (used for MODEL_FLOPS)."""
    return 6.0 * cfg.active_param_count()
