"""Per-architecture smoke tests (deliverable f): every assigned arch (plus
the paper's own models) instantiates a REDUCED config of the same family
and runs one forward/train step and one prefill→decode step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ASSIGNED_ARCHS, PAPER_ARCHS, get_config,
                          list_archs, reduce_config)
from repro.models import transformer as T
from repro.launch.steps import make_train_step
from repro.training.optimizer import adamw_init

SEQ = 32
BATCH = 2


def _batch(cfg, key, batch=BATCH, seq=SEQ):
    b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(key, (batch, seq, cfg.d_model),
                                        jnp.bfloat16)
    if cfg.family == "encdec":
        b["encoder_tokens"] = b["tokens"]
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_arch_train_step(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    step = make_train_step(cfg)
    batch = _batch(cfg, key)
    new_p, new_o, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) > 0
    assert int(new_o["count"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_p)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).family != "encoder"])
def test_assigned_arch_prefill_decode(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key, param_dtype=jnp.bfloat16)
    batch = _batch(cfg, key)
    logits, cache = T.prefill(params, cfg, batch, kv_cap=SEQ + 4)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((BATCH,), SEQ, jnp.int32)
    logits2, cache2 = T.decode_step(params, cfg, cache, nxt, pos)
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", PAPER_ARCHS)
def test_paper_arch_forward(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    loss, metrics = T.loss_fn(params, cfg, _batch(cfg, key))
    assert np.isfinite(float(loss)), arch


def test_all_assigned_archs_registered():
    names = list_archs(assigned_only=True)
    assert sorted(names) == sorted(ASSIGNED_ARCHS)
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_configs_match_assignment(arch):
    """Spot-check the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab_size=151_936,
                                  n_experts=128, top_k=8),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab_size=102_400, n_experts=160, top_k=6,
                                 kv_lora_rank=512, n_shared_experts=2),
        "recurrentgemma-9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12_288,
                                  vocab_size=256_000),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 d_ff=5120, vocab_size=51_866),
        "qwen2.5-3b": dict(n_layers=36, d_model=2048, n_heads=16,
                           n_kv_heads=2, d_ff=11_008, vocab_size=151_936,
                           qkv_bias=True),
        "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21_504, vocab_size=262_144),
        "gemma2-9b": dict(n_layers=42, d_model=3584, n_heads=16,
                          n_kv_heads=8, d_ff=14_336, vocab_size=256_000),
        "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32,
                            n_kv_heads=8, d_ff=16_384, vocab_size=256_000),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab_size=50_280,
                            ssm_state=128),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28_672,
                                     vocab_size=128_256),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_shape_applicability_policy():
    from repro.config import SHAPES
    long = SHAPES["long_500k"]
    dec = SHAPES["decode_32k"]
    # pure full-attention archs skip long_500k
    for a in ("qwen2.5-3b", "minitron-8b", "deepseek-v2-236b",
              "qwen3-moe-30b-a3b", "llama-3.2-vision-90b"):
        ok, why = get_config(a).supports(long)
        assert not ok and "sub-quadratic" in why
    # ssm / hybrid / windowed run it
    for a in ("mamba2-130m", "recurrentgemma-9b", "gemma2-9b", "gemma3-27b"):
        ok, _ = get_config(a).supports(long)
        assert ok, a
    # whisper: decode beyond 448 undefined
    ok, why = get_config("whisper-large-v3").supports(long)
    assert not ok
    # encoder-only: no decode
    ok, why = get_config("bert-base").supports(dec)
    assert not ok
