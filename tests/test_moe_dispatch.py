"""MoE dispatch-path equivalence and invariants (property-based)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.config import get_config, reduce_config
from repro.models.moe import (_apply_dropless, _apply_gshard, _capacity,
                              apply_moe, init_moe)
from repro.parallel.api import Plan, activate_plan


@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, p


class _FakeMesh:
    def __init__(self, model):
        self.shape = {"model": model}


def test_gshard_equals_sort_at_g1(moe_setup):
    """With one group, GShard's cumsum ranks reproduce the stable-argsort
    capacity semantics exactly."""
    cfg, p = moe_setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_sort = apply_moe(p, x, cfg, mode="train")
    with activate_plan(Plan(mesh=_FakeMesh(1), roles={})):
        y_g = apply_moe(p, x, cfg, mode="train")
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_g), atol=1e-5)


@given(st.integers(0, 100), st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_gshard_finite_and_shaped(seed, groups):
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model))
    with activate_plan(Plan(mesh=_FakeMesh(groups), roles={})):
        y = apply_moe(p, x, cfg, mode="train")
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_dropless_is_exact_moe(moe_setup):
    """ragged_dot dropless == explicit dense top-k mixture."""
    cfg, p = moe_setup
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 4, cfg.d_model))
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = (gates / gates.sum(-1, keepdims=True)).astype(x.dtype)
    y = _apply_dropless(p, x, gates, idx, cfg)

    # dense oracle: evaluate every selected expert directly
    from repro.models.modules import activation
    act = activation(cfg.act)
    we = p["experts"]
    want = jnp.zeros_like(x)
    for b in range(1):
        for s in range(4):
            acc = jnp.zeros((cfg.d_model,), x.dtype)
            for j in range(cfg.top_k):
                e = int(idx[b, s, j])
                h = act(x[b, s] @ we["w_gate"][e]) * (x[b, s] @ we["w_up"][e])
                acc = acc + gates[b, s, j] * (h @ we["w_down"][e])
            want = want.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_formula():
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    c = _capacity(64, cfg)
    assert 1 <= c <= 64
    big = dataclasses.replace(cfg, capacity_factor=100.0)
    assert _capacity(64, big) == 64  # clamped at token count


def test_gshard_respects_capacity_drops():
    """Force every token to one expert: outputs beyond capacity are dropped
    (zero contribution), matching GShard semantics."""
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    cfg = dataclasses.replace(cfg, capacity_factor=0.01, top_k=1)
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    # identical tokens -> identical routing -> all to one expert
    x = jnp.ones((1, 8, cfg.d_model)) * 0.3
    with activate_plan(Plan(mesh=_FakeMesh(1), roles={})):
        y = apply_moe(p, x, cfg, mode="train")
    # capacity 1 -> exactly one token got an expert; shared experts may add
    # a dense term for everyone, so compare variance across tokens instead
    per_tok = np.asarray(jnp.abs(y[0]).sum(-1))
    assert per_tok.max() > 0
