"""Pallas TPU kernel: weight-stationary quantised MVM (ReRAM-crossbar analogue).

Paper mapping (DESIGN.md §3): the static FF layers run on ReRAM chiplets
built from 128×128 crossbars with 2-bit cells — a weight value lives
bit-sliced across 4 cells of a crossbar row, and activations stream
through the stationary array.  Analog MVM itself has no TPU analogue; the
*transferable* property is **weight-stationary low-precision execution
with per-crossbar-tile granularity**:

- weights are stored int8, quantised with one fp32 scale per 128×128 tile
  (= one crossbar): the same granularity the bit-sliced cells impose;
- the kernel streams activation tiles from HBM through VMEM, dequantises
  the weight tile *in VMEM* (fp weights never exist in HBM — the memory-
  roofline win: 2× fewer weight bytes than bf16, 4× vs fp32), and
  accumulates in fp32 on the MXU;
- block shapes are multiples of 128 on both matmul dims, matching the
  crossbar geometry AND the MXU systolic array.

Grid: (M/bm, N/bn, K/bk); the trailing K axis is sequential on TPU so the
fp32 accumulator lives in VMEM scratch across the K sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

XBAR = 128  # crossbar dimension == MXU tile


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _pim_mvm_kernel(x_ref, wq_ref, scale_ref, o_ref, acc_scr, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)              # (bm, bk)
    wq = wq_ref[...].astype(jnp.float32)            # (bk, bn) int8 -> f32
    scales = scale_ref[...].astype(jnp.float32)     # (bk/128, bn/128)
    # expand crossbar-tile scales to element granularity (in-VMEM dequant)
    w = wq * jnp.repeat(jnp.repeat(scales, XBAR, axis=0), XBAR, axis=1)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def pim_mvm_pallas(x, wq, scales, *, bm: int = 128, bn: int = 256,
                   bk: int = 512, interpret: bool = False):
    """x (M, K) · dequant(wq (K, N) int8, scales (K/128, N/128)) -> (M, N).

    Output dtype follows x.  Block defaults keep the working set
    (bm·bk + bk·bn + bm·bn fp32) well under one v5e core's VMEM while the
    (bk, bn) weight tile spans whole crossbars.
    """
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    if M % bm or K % bk or N % bn:
        raise ValueError(f"dims {(M, K, N)} must divide blocks {(bm, bk, bn)}")
    if bk % XBAR or bn % XBAR:
        raise ValueError("weight blocks must tile 128x128 crossbars")
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_pim_mvm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // XBAR, bn // XBAR), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_vmem((bm, bn))],
        interpret=interpret,
    )(x, wq, scales)
