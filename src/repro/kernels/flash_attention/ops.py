"""jit'd dispatch wrapper for attention.

``impl``:
  - ``ref``               pure-jnp chunked oracle (CPU, dry-run HLO)
  - ``pallas``            TPU Pallas kernel (compiled)
  - ``pallas_interpret``  Pallas kernel body executed in Python on CPU
  - ``auto``              pallas on TPU backends, ref elsewhere

The Pallas path covers self-attention (train/prefill) with implicit
positions; ring-buffer decode and cross-attention with explicit position
vectors route to the reference path (a 1-token decode step is DMA-bound,
not MXU-bound — a kernel buys nothing there).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention import kernel as _kernel


def _pallas_ok(q, k, causal, q_pos, kv_pos, kv_valid, window):
    if q_pos is not None or kv_pos is not None or kv_valid is not None:
        return False
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    if Sq < 8 or Skv < 8:
        return False
    bq = min(128, Sq)
    bk = min(128, Skv)
    return Sq % bq == 0 and Skv % bk == 0 and Hq % k.shape[2] == 0


def attention(
    q: jax.Array,            # (B, Sq, Hq, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)
    v: jax.Array,            # (B, Skv, Hkv, hdv)
    *,
    q_pos: Optional[jax.Array] = None,
    kv_pos: Optional[jax.Array] = None,
    kv_valid: Optional[jax.Array] = None,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"

    if impl in ("pallas", "pallas_interpret") and _pallas_ok(
            q, k, causal, q_pos, kv_pos, kv_valid, window):
        qt = q.transpose(0, 2, 1, 3)   # (B, H, S, hd)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        out = _kernel.flash_attention_fwd(
            qt, kt, vt, causal=causal, window=window, softcap=softcap,
            scale=scale, interpret=(impl == "pallas_interpret"))
        return out.transpose(0, 2, 1, 3)

    return attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=kv_valid,
        causal=causal, window=window, softcap=softcap, scale=scale)
