"""Per-kernel-class sharding plans — the paper's heterogeneous mapping on TPU.

The paper assigns each transformer kernel class to the substrate matching
its operand-update behaviour (§3.1): dynamic attention operands → SM/MC/DRAM
plane; static weight-stationary FFN/embedding → ReRAM macro.  On a
homogeneous TPU mesh the same classification decides *placement*:

  kernel class        paper substrate     TPU placement (this module)
  ------------------  ------------------  -----------------------------------
  QKV/score/PV        SM cluster + HBM    activations head-sharded over
                                          ``model`` ("SM cluster" axis group)
  FFN / experts       ReRAM macro (SFC)   weights stationary, f-dim sharded
                                          over ``model``; experts → EP
  embedding/LM head   ReRAM (one-time)    vocab-sharded over ``model``
  residual stream     NoI traffic         sequence-sharded over ``model``
                                          (SP) in train/prefill
  batch/grad sync     —                   ``data`` (+``pod``) axes: FSDP + DP

Plans are pure data (role → PartitionSpec + param-path rules), so the
dry-run, trainer and server all consume the same object.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeSpec
from repro.parallel.api import Plan

# serving: params go 2-D (model × data) above this per-device budget for
# pure-TP bf16 weights
_TP_ONLY_BYTES = 6 << 30


def _div(n: int, mesh: Mesh, axis) -> bool:
    """True if dim of size n is divisible by the mesh axis (or axis tuple)."""
    if axis is None:
        return True
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n > 0 and n % size == 0


def _maybe(n: int, mesh: Mesh, axis):
    return axis if _div(n, mesh, axis) else None


@dataclasses.dataclass
class PlanContext:
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Mesh
    fsdp: Optional[str]        # axis for weight sharding on the d_model dim
    dp: tuple[str, ...]        # batch axes
    seq_axis: Optional[str]    # SP axis for the residual stream (train/prefill)


def _plan_context(cfg, shape, mesh, *, mode) -> PlanContext:
    multi_pod = "pod" in mesh.shape
    if mode == "train":
        dp = ("pod", "data") if multi_pod else ("data",)
        # ZeRO/FSDP spans every batch axis — with pod-replicated params a
        # 512-chip job carries the same optimizer state per chip as a
        # 256-chip one (measured +5.5 GiB/chip on deepseek-v2 train multi)
        fsdp = ("pod", "data") if multi_pod else "data"
        seq_axis = "model" if shape.seq_len % mesh.shape["model"] == 0 else None
    elif mode == "prefill":
        dp = ("pod", "data") if multi_pod else ("data",)
        fsdp = _serving_fsdp(cfg, mesh)
        seq_axis = "model" if shape.seq_len % mesh.shape["model"] == 0 else None
    else:  # decode
        dp = ()
        gb = shape.global_batch
        if multi_pod and gb % (mesh.shape["pod"] * mesh.shape["data"]) == 0:
            dp = ("pod", "data")
        elif gb % mesh.shape["data"] == 0:
            dp = ("data",)
        fsdp = _serving_fsdp(cfg, mesh)
        seq_axis = None
    return PlanContext(cfg, shape, mesh, fsdp, dp, seq_axis)


def _serving_fsdp(cfg, mesh) -> Optional[str]:
    per_dev = 2 * cfg.param_count() / mesh.shape["model"]
    return "data" if per_dev > _TP_ONLY_BYTES else None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(path: str, shape: tuple[int, ...], ctx: PlanContext) -> P:
    """PartitionSpec for one parameter, by path + shape.

    Stack params carry a leading scan (repeats) dim — detected via path
    prefix ``stack/``/``encoder/`` and left unsharded.
    """
    cfg, mesh, fsdp = ctx.cfg, ctx.mesh, ctx.fsdp
    lead: tuple = ()
    dims = shape
    if path.startswith(("stack/", "encoder/")):
        lead = (None,)
        dims = shape[1:]

    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def fs(n):  # fsdp axis if divisible
        return _maybe(n, mesh, fsdp)

    def mp(n):  # model axis if divisible
        return _maybe(n, mesh, "model")

    # ---- embeddings -------------------------------------------------------
    if path == "embed/tok":
        # d-dim sharded (FSDP); vocab replicated: a vocab-sharded table turns
        # every token gather into a 2 GiB all-gather inside the scan (XLA
        # SPMD is conservative with sharded-operand gathers) — measured in
        # the deepseek train_4k dry-run.  See EXPERIMENTS.md §Perf.
        return P(None, fs(dims[1]))
    if path == "embed/pos":
        return P(None, fs(dims[1]))
    if path == "lm_head":
        return P(fs(dims[0]), mp(dims[1]))

    # ---- experts (EP: the ReRAM-macro analogue) ---------------------------
    if "experts" in path:
        if name in ("w_gate", "w_up"):        # (E, D, Fe)
            return P(*lead, mp(dims[0]), fs(dims[1]), None)
        if name == "w_down":                  # (E, Fe, D)
            return P(*lead, mp(dims[0]), None, fs(dims[2]))
    if name == "router":                      # (D, E)
        return P(*lead, fs(dims[0]), None)

    # ---- attention --------------------------------------------------------
    if parent in ("attn", "cross") or name in ("wq", "wk", "wv", "wo"):
        if name == "wq":
            if len(dims) == 3:                # MLA direct (D, H, dn+dr)
                return P(*lead, fs(dims[0]), mp(dims[1]), None)
            return P(*lead, fs(dims[0]), mp(dims[1]))
        if name in ("wk", "wv"):
            return P(*lead, fs(dims[0]), mp(dims[1]))
        if name == "wo":
            return P(*lead, mp(dims[0]), fs(dims[1]))
        if name == "wq_a":                    # (D, qr)
            return P(*lead, fs(dims[0]), None)
        if name == "wq_b":                    # (qr, H, dn+dr)
            return P(*lead, None, mp(dims[1]), None)
        if name == "wkv_a":                   # (D, kvr+dr)
            return P(*lead, fs(dims[0]), None)
        if name == "wkv_b":                   # (kvr, H, dn+dv)
            return P(*lead, None, mp(dims[1]), None)
        if name in ("bq", "bk", "bv"):
            return P(*lead, mp(dims[0]))

    # ---- dense MLP (weight-stationary plane) ------------------------------
    if name in ("w_gate", "w_up"):            # (D, F)
        return P(*lead, fs(dims[0]), mp(dims[1]))
    if name == "w_down":                      # (F, D)
        return P(*lead, mp(dims[0]), fs(dims[1]))
    if name == "b_up":
        return P(*lead, mp(dims[0]))

    # ---- mamba2 ------------------------------------------------------------
    if name == "in_proj":                     # (D, Z)
        return P(*lead, fs(dims[0]), mp(dims[1]))
    if name == "out_proj":                    # (di, D)
        return P(*lead, mp(dims[0]), fs(dims[1]))

    # ---- RG-LRU -------------------------------------------------------------
    if name in ("w_branch",):                 # (D, W)
        return P(*lead, fs(dims[0]), mp(dims[1]))
    if name in ("wa", "wi"):                  # (W, W)
        return P(*lead, None, mp(dims[1]))
    if name == "w_out":                       # (W, D)
        return P(*lead, mp(dims[0]), fs(dims[1]))

    # ---- everything else (norm scales, gates, conv, scalars) --------------
    return P(*lead, *(None for _ in dims))


def params_shardings(param_shapes, ctx: PlanContext):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(param_shapes)

    def pathstr(kp):
        parts = []
        for p in kp:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    out = [NamedSharding(ctx.mesh, param_spec(pathstr(kp), leaf.shape, ctx))
           for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(param_shapes), out)


# ---------------------------------------------------------------------------
# activation roles
# ---------------------------------------------------------------------------

def _roles(ctx: PlanContext, *, mode: str) -> dict[str, P]:
    cfg, mesh = ctx.cfg, ctx.mesh
    dp = ctx.dp if ctx.dp else None
    Hq = cfg.n_heads
    Hkv = cfg.n_kv_heads

    # Head-parallel attention ("SM cluster" = a model-axis group per head)
    # only when *both* q and kv head counts divide the axis — otherwise the
    # GQA head-group reshape forces SPMD full-rematerialisation copies.
    # Fallback: sequence-parallel q blocks (the FlashAttention partitioning
    # of the score matrix the paper runs across SM chiplets).
    heads_ok = _div(Hq, mesh, "model") and _div(Hkv, mesh, "model")

    vocab_ax = _maybe(cfg.vocab_size, mesh, "model")
    if mode == "decode":
        if heads_ok:
            return {
                "residual": P(dp, None, None),
                "act_heads": P(dp, None, "model", None),
                "kv_heads": P(dp, None, "model", None),
                "act_ff": P(dp, None, "model"),
                "expert_buf": P(dp, "model", None, None),
                "expert_hidden": P(dp, "model", None, None),
                "logits": P(dp, None, vocab_ax),
            }
        return {
            "residual": P(dp, None, None),
            "act_heads": P(dp, None, None, None),   # q replicated; KV cache
            "kv_heads": P(dp, None, None, None),    # stays sequence-sharded
            "act_ff": P(dp, None, "model"),
            "expert_buf": P(dp, "model", None, None),
            "expert_hidden": P(dp, "model", None, None),
            "logits": P(dp, None, vocab_ax),
        }

    seq = ctx.seq_axis
    if heads_ok:
        attn_roles = {
            "act_heads": P(dp, None, "model", None),
            "kv_heads": P(dp, None, "model", None),
        }
    elif mode == "prefill":
        # GQA with fewer KV heads than the model axis, forward-only:
        # REPLICATE K/V over the axis (one ~1e2-MB all-gather per layer)
        # and keep q sequence-sharded — attention computes shard-locally
        # with no per-chunk re-gathers (§Perf iteration C1).
        attn_roles = {
            "act_heads": P(dp, seq, None, None),
            "kv_heads": P(dp, None, None, None),
        }
    else:
        # training: K/V replication would be repaid with full dK/dV
        # all-reduces in backward (measured +252 GiB/dev on gemma2 —
        # §Perf C1 refuted for train); stay with the Megatron-SP pattern
        attn_roles = {
            "act_heads": P(dp, seq, None, None),
            "kv_heads": P(dp, seq, None, None),
        }
    # FFN hidden activations: train uses the Megatron-SP pattern (f-dim
    # TP-sharded; AG(x)/RS(out) around the block).  Prefill is forward-
    # only and token-heavy — keep activations sequence-sharded and let
    # XLA gather the (smaller) layer weights instead: kills the per-layer
    # full-sequence all-gather + partial-sum all-reduce (§Perf P2:
    # 3.15 GB → 0.87 GB per layer on gemma3-27b prefill_32k).
    ff_spec = P(dp, seq, None) if mode == "prefill" else P(dp, None, "model")
    roles_extra = {}
    if mode == "prefill":
        # force the weight-gathered strategy on attention projections too:
        # without this XLA gathers the (much larger) full-sequence
        # activations for q/k/v/o instead of the layer weights (§Perf P3)
        roles_extra["weight_full"] = P(None, None)
    return {
        "residual": P(dp, seq, None),
        **attn_roles,
        "act_ff": ff_spec,
        "expert_buf": P(dp, "model", None, None),
        "expert_hidden": P(dp, "model", None, None),
        **roles_extra,
        # unembed boundary: re-gather the (cheap) activations over seq and
        # shard the (huge) vocab dim instead — keeps the embedding / lm_head
        # table sharded through fwd AND bwd (no per-microbatch multi-GiB
        # table all-gathers/all-reduces; measured on gemma2 train_4k).
        # When the vocab doesn't divide the axis (mamba2 50280, whisper
        # 51866) stay sequence-sharded: full-seq unsharded logits are worse
        # than the table gather (measured 3×12.3 GiB on mamba2 train_4k).
        "pre_logits": P(dp, None, None) if vocab_ax else P(dp, seq, None),
        "logits": P(dp, None, vocab_ax) if vocab_ax else P(dp, seq, None),
    }


# ---------------------------------------------------------------------------
# KV-cache shardings
# ---------------------------------------------------------------------------

def cache_shardings(cache_shapes, ctx: PlanContext):
    """Shard stacked KV caches: batch → dp, then heads → model if divisible,
    else sequence → model (long-context single-batch decode shards the
    sequence across everything available)."""
    cfg, mesh = ctx.cfg, ctx.mesh
    dp = ctx.dp if ctx.dp else None
    B = ctx.shape.global_batch

    def spec(kp, leaf):
        name = str(getattr(kp[-1], "key", ""))
        dims = leaf.shape  # (R, B, ...)
        if name in ("k", "v", "k_q", "v_q", "k_s", "v_s"):
            # (R, B, S, Hkv, hd) — quantised pools: code planes (hd packed)
            # and per-(entry, head) scale planes (R, B, S, Hkv) shard the
            # same leading axes, so codes and scales stay co-located
            S, H = dims[2], dims[3]
            tail = (None,) * (len(dims) - 4)
            if dp is None:
                # batch unshardable: spread the sequence
                seq_ax = ("data", "model") if _div(S, mesh, ("data", "model")) \
                    else _maybe(S, mesh, "data")
                h_ax = _maybe(H, mesh, "model") if not (
                    isinstance(seq_ax, tuple)) else None
                return P(None, None, seq_ax, h_ax, *tail)
            h_ax = _maybe(H, mesh, "model")
            seq_ax = "model" if h_ax is None and _div(S, mesh, "model") else None
            return P(None, dp, seq_ax, h_ax, *tail)
        if name in ("ckv", "kr"):              # (R, B, S, r)
            S = dims[2]
            if dp is None:
                seq_ax = ("data", "model") if _div(S, mesh, ("data", "model")) \
                    else _maybe(S, mesh, "data")
                return P(None, None, seq_ax, None)
            return P(None, dp, _maybe(S, mesh, "model"), None)
        if name == "pos":                      # (R, B, S)
            S = dims[2]
            if dp is None:
                seq_ax = ("data", "model") if _div(S, mesh, ("data", "model")) \
                    else _maybe(S, mesh, "data")
                return P(None, None, seq_ax)
            return P(None, dp, None)
        if name == "state":                    # (R, B, H, P, N) ssd state
            H = dims[2]
            return P(None, dp, _maybe(H, mesh, "model"), None, None)
        if name == "conv":                     # (R, B, W-1, C)
            return P(None, dp, None, _maybe(dims[3], mesh, "model"))
        if name == "h":                        # (R, B, W) rg-lru state
            return P(None, dp, _maybe(dims[2], mesh, "model"))
        return P(*(None for _ in dims))

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = [NamedSharding(ctx.mesh, spec(kp, leaf)) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_shapes), out)


# ---------------------------------------------------------------------------
# batch shardings + plan assembly
# ---------------------------------------------------------------------------

def batch_shardings(batch_shapes, ctx: PlanContext):
    dp = ctx.dp if ctx.dp else None

    def spec(kp, leaf):
        nd = len(leaf.shape)
        return NamedSharding(ctx.mesh, P(dp, *(None,) * (nd - 1)))

    flat, tdef = jax.tree_util.tree_flatten_with_path(batch_shapes)
    out = [spec(kp, leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(batch_shapes), out)


def build_plan(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *, mode: str) -> tuple[Plan, PlanContext]:
    ctx = _plan_context(cfg, shape, mesh, mode=mode)
    plan = Plan(mesh=mesh, roles=_roles(ctx, mode=mode),
                name=f"{cfg.name}:{shape.name}:{mode}")
    return plan, ctx


def serving_decode_plan(cfg: ModelConfig, mesh: Mesh, *, max_batch: int,
                        kv_len: int) -> tuple[Plan, PlanContext]:
    """Decode-mode plan for the serving engine's slotted KV pool: the slot
    (batch) axis maps to the data axes when divisible, KV heads to the model
    axis — the same placement the paper gives dynamic attention operands
    (§3.1).  Feed the returned ctx to :func:`cache_shardings` for the pool."""
    shape = ShapeSpec("serving", "decode", kv_len, max_batch)
    return build_plan(cfg, shape, mesh, mode="decode")


def serving_prefill_plan(cfg: ModelConfig, mesh: Mesh, *,
                         prefill_chunk: int) -> tuple[Plan, PlanContext]:
    """Prefill-mode plan for the engine's packed ragged prefill call.

    The packed stream is a single ``(1, C)`` batch row, so the batch axes
    cannot be used — the stream is sequence-sharded over ``model`` instead
    (the FlashAttention partitioning of the score matrix the paper runs
    across SM chiplets), with the prefill weight-gathered projection
    strategy.  The chunked-continuation step runs over the whole slot pool
    and uses the decode plan."""
    shape = ShapeSpec("serving_packed", "prefill", prefill_chunk, 1)
    seq_ax = "model" if prefill_chunk % mesh.shape["model"] == 0 else None
    ctx = PlanContext(cfg, shape, mesh, fsdp=_serving_fsdp(cfg, mesh),
                      dp=(), seq_axis=seq_ax)
    plan = Plan(mesh=mesh, roles=_roles(ctx, mode="prefill"),
                name=f"{cfg.name}:serving_packed:prefill")
    return plan, ctx
