"""Layered serving stack: refactor-equivalence pins and the new seams.

The scheduler/executor/pool split must be behaviour-preserving by
construction: under the default config (FIFO, no SLOs) token streams,
``stats()`` and checkpoint round-trips are bit-identical to the
pre-layering monolithic engine.  The fixtures in ``tests/data/`` were
generated AT HEAD (before any refactoring):

- ``head_token_streams.json`` — golden token streams + deterministic
  stats pins for 8 engine configs (greedy, seeded sampling, int8/int4
  pools, chunked prefill, the sequential and host baselines);
- ``head_ckpt/`` + ``head_ckpt_expected.json`` — a snapshot directory
  written by the HEAD engine mid-decode (journal tail included), which
  must restore bit-exactly through the refactored layers
  (snapshot-format compatibility).

Plus coverage for the new surface: stats percentiles, queue-wait
separation (``t_admit``), scheduler injection, and the streaming
frontend + workload layers.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.serving.engine import (DONE, EngineConfig, REJECTED,
                                  ServingEngine)
from repro.serving.frontend import ServingFrontend
from repro.serving.scheduler import FifoScheduler, Scheduler, SloScheduler
from repro.serving.workload import make_workload

DATA = os.path.join(os.path.dirname(__file__), "data")

# engine kwargs per golden case, exactly as the fixture generator ran at
# HEAD (defaults: max_batch=2, kv_len=48, max_new_tokens=6, impl="ref")
GOLDEN_CASES = {
    "greedy": {},
    "sampled": {"temperature": 0.8, "seed": 3},
    "kv8": {"kv_bits": 8},
    "w8kv8": {"weight_bits": 8, "kv_bits": 8},
    "w4kv4": {"weight_bits": 4, "kv_bits": 4},
    "chunked": {"prefill_chunk": 8, "max_new_tokens": 4},
    "unpacked": {"packed": False},
    "hostpath": {"fused": False},
}


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def golden():
    with open(os.path.join(DATA, "head_token_streams.json")) as f:
        return json.load(f)


def _drain(cfg, params, *, scheduler=None, **kw):
    defaults = dict(max_batch=2, kv_len=48, max_new_tokens=6, impl="ref")
    defaults.update(kw)
    eng = ServingEngine(cfg, params, EngineConfig(**defaults),
                        scheduler=scheduler)
    rng = np.random.default_rng(7)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, size=3 + 2 * i))
    eng.run_until_drained()
    outs = {str(r.uid): list(map(int, r.output))
            for r in sorted(eng.finished, key=lambda r: r.uid)}
    return eng, outs


# ---------------------------------------------------------------------------
# tentpole pin: bit-identical token streams + stats vs the HEAD monolith
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(GOLDEN_CASES))
def test_token_streams_bit_identical_to_head(small_model, golden, case):
    cfg, params = small_model
    eng, outs = _drain(cfg, params, **GOLDEN_CASES[case])
    want = golden["cases"][case]
    assert outs == want["outputs"]
    s = eng.stats()
    for key, val in want["stats"].items():
        got = s[key]
        if key == "active_slots_hist":
            got = {str(k): v for k, v in got.items()}
        assert got == val, f"stats[{key!r}]: {got} != {val}"


def test_explicit_fifo_scheduler_is_the_default(small_model, golden):
    """Injecting FifoScheduler() by hand changes nothing (it IS the
    default policy)."""
    cfg, params = small_model
    _, outs = _drain(cfg, params, scheduler=FifoScheduler())
    assert outs == golden["cases"]["greedy"]["outputs"]


def test_slo_scheduler_without_targets_matches_fifo_outputs(small_model,
                                                            golden):
    """SloScheduler with no targets and uniform priority degrades to
    FIFO ordering (rank falls back to uid) — same tokens per uid."""
    cfg, params = small_model
    _, outs = _drain(cfg, params, scheduler=SloScheduler())
    assert outs == golden["cases"]["greedy"]["outputs"]


# ---------------------------------------------------------------------------
# satellite pin: a HEAD-written snapshot restores bit-exactly (format compat)
# ---------------------------------------------------------------------------

def test_head_checkpoint_restores_bit_exact(small_model, tmp_path):
    import shutil
    cfg, params = small_model
    with open(os.path.join(DATA, "head_ckpt_expected.json")) as f:
        expected = json.load(f)
    assert expected["model"] == cfg.name
    # restore from a copy: the fixture directory itself must stay pristine
    ckdir = str(tmp_path / "head_ckpt")
    shutil.copytree(os.path.join(DATA, "head_ckpt"), ckdir)
    eng = ServingEngine.restore(cfg, params, ckdir)
    assert eng.restores == 1
    assert eng.replayed_requests == 1        # journal tail (uid 3)
    eng.run_until_drained()
    outs = {str(r.uid): list(map(int, r.output)) for r in eng.finished}
    assert outs == expected["expected_outputs"]
    s = eng.stats()
    for key, val in expected["stats_pins"].items():
        assert s[key] == val, f"stats[{key!r}]: {s[key]} != {val}"


def test_restore_accepts_scheduler_passthrough(small_model, tmp_path):
    import shutil
    cfg, params = small_model
    ckdir = str(tmp_path / "head_ckpt")
    shutil.copytree(os.path.join(DATA, "head_ckpt"), ckdir)
    eng = ServingEngine.restore(cfg, params, ckdir,
                                scheduler=SloScheduler())
    assert isinstance(eng.scheduler, SloScheduler)
    assert isinstance(eng.scheduler, Scheduler)   # protocol conformance
    eng.run_until_drained()
    assert len(eng.finished) == 4


# ---------------------------------------------------------------------------
# satellite: stats percentiles + queue-wait separation
# ---------------------------------------------------------------------------

def test_stats_percentiles_and_queue_wait(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, kv_len=48,
                                     max_new_tokens=4, impl="ref"))
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, size=6))
    eng.run_until_drained()
    s = eng.stats()
    for base in ("latency", "ttft", "tpot", "queue_wait"):
        p50, p95, p99 = (s[f"{base}_p50_s"], s[f"{base}_p95_s"],
                         s[f"{base}_p99_s"])
        assert 0.0 <= p50 <= p95 <= p99
    # percentiles bracket the mean and the p50 is the median
    assert s["latency_p50_s"] <= s["latency_p99_s"]
    assert s["mean_tpot_s"] > 0.0
    # queue wait is separable from service: every request was admitted
    # at or after enqueue, and waiting <= total latency
    assert 0.0 <= s["mean_queue_wait_s"] <= s["mean_latency_s"]
    for r in eng.finished:
        assert r.t_enqueue <= r.t_admit <= r.t_done


def test_single_token_requests_report_null_tpot_not_zero(small_model):
    """max_new_tokens=1 makes every TPOT sample degenerate (gen_len <= 1
    has no inter-token gap).  The stats must say *no data* — None for the
    mean and every percentile — not a fake 0.0 that renders as a real
    0 ms latency in the benchmark tables; and the record must still be
    JSON-serialisable for the BENCH writers."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, kv_len=48,
                                     max_new_tokens=1, impl="ref"))
    rng = np.random.default_rng(5)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, size=5))
    eng.run_until_drained()
    s = eng.stats()
    assert s["finished"] == 3 and s["tokens"] == 3
    assert s["mean_tpot_s"] is None
    for p in ("tpot_p50_s", "tpot_p95_s", "tpot_p99_s"):
        assert s[p] is None
    # other latency families still carry real samples
    assert s["latency_p50_s"] > 0.0 and s["mean_latency_s"] > 0.0
    json.dumps(s)                       # None serialises; no NaN leaks


def test_t_admit_reflects_queueing_under_contention(small_model):
    """With one slot, the 2nd request's queue wait includes the 1st
    request's service time — t_admit separates scheduling delay."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=1, kv_len=48,
                                     max_new_tokens=4, impl="ref"))
    rng = np.random.default_rng(1)
    first = eng.submit(rng.integers(0, cfg.vocab_size, size=6))
    second = eng.submit(rng.integers(0, cfg.vocab_size, size=6))
    eng.run_until_drained()
    assert first.t_admit < second.t_admit
    assert second.t_admit >= first.t_done  # slot freed before re-admission


# ---------------------------------------------------------------------------
# frontend + workload layers
# ---------------------------------------------------------------------------

def test_frontend_streams_tokens_incrementally(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, kv_len=48,
                                     max_new_tokens=5, impl="ref"))
    fe = ServingFrontend(eng)
    rng = np.random.default_rng(2)
    seen: list[tuple[int, int]] = []
    streams = [fe.submit(rng.integers(0, cfg.vocab_size, size=5),
                         on_token=lambda st, tok: seen.append((st.uid, tok)))
               for _ in range(3)]
    fe.drain()
    for st in streams:
        assert st.done and st.status == DONE
        assert st.tokens == st.request.output
        assert len(st.tokens) == 5
    # callbacks saw exactly the union of all streams' tokens, in order
    for uid in (0, 1, 2):
        assert [t for u, t in seen if u == uid] == streams[uid].tokens


def test_stream_iterator_pumps_to_completion(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=1, kv_len=48,
                                     max_new_tokens=4, impl="ref"))
    fe = ServingFrontend(eng)
    rng = np.random.default_rng(3)
    a = fe.submit(rng.integers(0, cfg.vocab_size, size=4))
    b = fe.submit(rng.integers(0, cfg.vocab_size, size=4))
    got_a = list(a)                       # iterating drives the engine
    assert got_a == a.request.output and len(got_a) == 4
    got_b = list(b)
    assert got_b == b.request.output and len(got_b) == 4


def test_frontend_rejected_stream_ends_immediately(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=1, kv_len=48,
                                     max_new_tokens=2, impl="ref",
                                     max_queue=1))
    fe = ServingFrontend(eng)
    rng = np.random.default_rng(4)
    fe.submit(rng.integers(0, cfg.vocab_size, size=4))
    shed = fe.submit(rng.integers(0, cfg.vocab_size, size=4))
    assert shed.status == REJECTED and shed.done
    assert list(shed) == []
    fe.drain()


def test_frontend_play_replays_workload_on_fake_clock(small_model):
    """play() submits each arrival when the (injected) clock reaches its
    due time and drains everything — no real sleeping."""
    cfg, params = small_model

    class FakeClock:
        def __init__(self):
            self.t = 100.0

        def __call__(self):
            return self.t

        def sleep(self, dt):
            self.t += max(dt, 1e-3)      # sleeping advances virtual time

    clk = FakeClock()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=2, kv_len=48,
                                     max_new_tokens=3, impl="ref",
                                     clock=clk))
    fe = ServingFrontend(eng, sleep=clk.sleep)
    wl = make_workload(5, rate_rps=4.0, seed=11, hi_fraction=0.4,
                       min_len=4, max_len=8, vocab=cfg.vocab_size,
                       max_new_tokens=3)
    streams = fe.play(wl)
    assert len(streams) == 5
    assert all(st.done and st.status == DONE for st in streams)
    assert all(len(st.tokens) == 3 for st in streams)
    # priorities flowed through to the engine requests
    assert ([st.request.priority for st in streams] ==
            [a.priority for a in wl])


def test_play_overload_submits_late_arrivals_in_order(small_model):
    """Overload replay pins: when the engine falls behind the arrival
    process, every overdue arrival is still submitted in arrival order,
    the replay never asks for a negative sleep, and each request's
    ``t_enqueue`` is stamped from the engine clock at its *actual*
    submission (>= its due time — an overdue arrival cannot be
    back-dated)."""
    cfg, params = small_model

    class FakeClock:
        def __init__(self):
            self.t = 100.0
            self.sleeps: list[float] = []

        def __call__(self):
            return self.t

        def sleep(self, dt):
            self.sleeps.append(dt)
            self.t += max(dt, 1e-3)

    clk = FakeClock()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_batch=1, kv_len=48,
                                     max_new_tokens=4, impl="ref",
                                     clock=clk))
    fe = ServingFrontend(eng, sleep=clk.sleep)
    # a rate far beyond one slot's service capability: most arrivals are
    # overdue by the time their predecessors drain
    wl = make_workload(8, rate_rps=200.0, seed=13, hi_fraction=0.5,
                       min_len=4, max_len=6, vocab=cfg.vocab_size,
                       max_new_tokens=4)
    t0 = clk()
    streams = fe.play(wl)
    assert len(streams) == 8 and all(st.done for st in streams)
    assert all(dt >= 0.0 for dt in clk.sleeps)
    by_t = sorted(wl, key=lambda a: a.t)
    # submissions happened in arrival order: uid order == due-time order
    uids = [st.request.uid for st in streams]
    assert uids == sorted(uids)
    assert [len(st.request.prompt) for st in streams] == \
        [len(a.prompt) for a in by_t]
    # the engine clock stamped each submission at or after its due time
    for st, a in zip(streams, by_t):
        assert st.request.t_enqueue >= t0 + a.t - 1e-9
