"""NoI design walkthrough (the paper's §3.3 flow, end to end):

  workload → phase traffic → MOO-STAGE search over placements/links →
  Pareto front → pick min-EDP design → full-system simulation,
  plus the 3D-HI variant with thermal + ReRAM-noise objectives (eq. 20).

Run:  PYTHONPATH=src python examples/noi_design.py [--chiplets 36]
"""
import argparse
import random

import numpy as np

from repro.config import get_config
from repro.core.moo import moo_stage, local_search, Archive
from repro.core.noi import evaluate_noi, mesh_baseline_eval
from repro.core.placement import initial_placement
from repro.core.simulator import simulate_2p5d_hi
from repro.core.thermal import (hi3d_stack_report, moo_objectives_3d,
                                baseline_stack_report)
from repro.core.traffic import Workload, transformer_phases


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chiplets", type=int, default=36, choices=(36, 64, 100))
    ap.add_argument("--arch", default="bert-base")
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    w = Workload.from_config(get_config(args.arch), seq_len=args.seq_len)
    phases = transformer_phases(w)
    mesh_ev = mesh_baseline_eval(args.chiplets, phases)
    print(f"workload: {args.arch} n={args.seq_len}, {args.chiplets} chiplets")
    print(f"naive-mesh baseline: mu={mesh_ev.mu/1e6:.2f}MB sigma={mesh_ev.sigma/1e6:.2f}MB")

    # -- 2-objective MOO (eq. 10) ------------------------------------------
    def objective(p):
        ev = evaluate_noi(p, phases)
        return (ev.mu / mesh_ev.mu, ev.sigma / mesh_ev.sigma)

    ref = (2.0, 2.0)
    res = moo_stage(args.chiplets, objective, ref, iterations=4, ls_steps=25)
    local_search(initial_placement(args.chiplets), objective, res.archive,
                 random.Random(0), max_steps=25)
    front = sorted(res.archive.objs)
    print(f"\nMOO-STAGE: {res.n_evals} evaluations, "
          f"{len(front)} Pareto designs, PHV={res.archive.phv(ref):.3f}")
    for mu, sg in front[:6]:
        print(f"  mu_norm={mu:.3f}  sigma_norm={sg:.3f}")

    # -- pick min-EDP design via the full-system simulator ------------------
    best, best_edp = None, float("inf")
    for design, _ in zip(res.archive.designs, res.archive.objs):
        sim = simulate_2p5d_hi(w, args.chiplets, placement=design)
        if sim.edp < best_edp:
            best, best_edp = sim, sim.edp
    print(f"\nmin-EDP design: latency={best.latency_s*1e3:.1f}ms "
          f"energy={best.energy_j:.2f}J EDP={best.edp:.4f}")

    # -- 3D-HI: add thermal + noise objectives (eq. 20) ---------------------
    p0 = initial_placement(args.chiplets)
    ev0 = evaluate_noi(p0, phases)
    t4 = moo_objectives_3d(p0, ev0.mu, ev0.sigma)
    print(f"\n3D-HI 4-objective point (eq. 20): mu={t4[0]/1e6:.2f}MB "
          f"sigma={t4[1]/1e6:.2f}MB T_obj={t4[2]:.1f} noise_sigma={t4[3]:.2e}")
    hi = hi3d_stack_report(args.chiplets)
    print(f"3D-HI stack peak temp: {hi.peak_c:.1f}C "
          f"(DRAM-feasible: {hi.dram_feasible})")
    for kind in ("haima", "transpim"):
        r = baseline_stack_report(kind)
        print(f"original {kind} 3-D stack: {r.peak_c:.1f}C "
              f"(DRAM-feasible: {r.dram_feasible})   <- Fig. 11")


if __name__ == "__main__":
    main()
