"""Quickstart: the three layers of the framework in ~60 lines.

  1. Plane B — design a chiplet NoI for a transformer workload and compare
     2.5D-HI against the HAIMA/TransPIM baselines (the paper's headline).
  2. Plane A — instantiate one of the assigned architectures (reduced) and
     run a forward + a train step on CPU.
  3. Kernels — the Pallas flash-attention and PIM-MVM kernels vs their
     jnp oracles (interpret mode).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# ── 1. the paper's architecture plane ──────────────────────────────────────
from repro.config import get_config, reduce_config
from repro.core.simulator import simulate_2p5d_hi
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.traffic import Workload

w = Workload.from_config(get_config("bert-base"), seq_len=64)
hi = simulate_2p5d_hi(w, 36)
ha = simulate_haima_chiplet(w, 36)
tp = simulate_transpim_chiplet(w, 36)
print(f"[plane B] BERT-Base n=64 on 36 chiplets:")
print(f"  2.5D-HI         {hi.latency_s*1e3:7.1f} ms  {hi.energy_j:6.2f} J")
print(f"  HAIMA_chiplet   {ha.latency_s*1e3:7.1f} ms  ({ha.latency_s/hi.latency_s:.1f}x slower)")
print(f"  TransPIM_chiplet{tp.latency_s*1e3:7.1f} ms  ({tp.latency_s/hi.latency_s:.1f}x slower)")

# ── 2. the workload plane: a real (reduced) assigned architecture ──────────
from repro.models import transformer as T
from repro.launch.steps import make_train_step
from repro.training.optimizer import adamw_init

cfg = reduce_config(get_config("gemma2-9b"))
key = jax.random.PRNGKey(0)
params = T.init_params(cfg, key)
batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
loss, _ = T.loss_fn(params, cfg, batch)
print(f"\n[plane A] reduced gemma2-9b ({cfg.param_count()/1e6:.1f}M params) "
      f"forward loss = {float(loss):.3f}")

step = jax.jit(make_train_step(cfg))
params2, opt, metrics = step(params, adamw_init(params), batch)
print(f"[plane A] one train step: loss={metrics['loss']:.3f} "
      f"gnorm={metrics['gnorm']:.3f}")

# ── 3. the Pallas kernels (interpret mode on CPU) ──────────────────────────
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pim_mvm.ops import pim_mvm, quantize_weights

q = jax.random.normal(key, (1, 128, 4, 64))
k = jax.random.normal(key, (1, 128, 2, 64))
v = jax.random.normal(key, (1, 128, 2, 64))
err = float(jnp.abs(attention(q, k, v, impl="pallas_interpret")
                    - attention_ref(q, k, v)).max())
print(f"\n[kernels] flash attention (GQA, causal) max err vs oracle: {err:.2e}")

x = jax.random.normal(key, (128, 256))
wfp = jax.random.normal(key, (256, 128))
wq, scales = quantize_weights(wfp)
out = pim_mvm(x, wq, scales, impl="pallas_interpret")
rel = float(jnp.abs(out - x @ wfp).max() / jnp.abs(x @ wfp).max())
print(f"[kernels] PIM-MVM int8-crossbar quantised matmul rel err: {rel:.3%}")
