"""Griffin / RecurrentGemma recurrent block: conv + RG-LRU linear recurrence.

Dynamic-state kernel per the paper's own classification criterion (§3.1):
its state changes every token, so it belongs on the SM plane, never on PIM.
Train/prefill use a log-depth ``associative_scan``; decode is the O(1)
recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import dense_init
from repro.models.ssm import causal_conv
from repro.parallel import constrain
from repro.quant.ops import qdense

_C = 8.0  # RG-LRU temperature (Griffin paper)


def init_rglru(key, cfg, *, dtype=jnp.float32):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so a = exp(-c·softplus(Λ)·r) lands in ~(0.9, 0.999) at r≈0.5
    lam0 = jax.random.uniform(ks[4], (W,), jnp.float32, 0.2, 0.9)
    return {
        "w_gate": dense_init(ks[0], (D, W), dtype),           # GeLU branch
        "w_branch": dense_init(ks[1], (D, W), dtype),         # recurrent branch
        "conv_w": dense_init(ks[5], (cfg.conv_width, W), jnp.float32,
                             fan_in=cfg.conv_width),
        "conv_b": jnp.zeros((W,), jnp.float32),
        "wa": dense_init(ks[2], (W, W), jnp.float32),         # recurrence gate
        "ba": jnp.zeros((W,), jnp.float32),
        "wi": dense_init(ks[3], (W, W), jnp.float32),         # input gate
        "bi": jnp.zeros((W,), jnp.float32),
        "lam": lam0,
        "w_out": dense_init(jax.random.fold_in(key, 7), (W, D), dtype, fan_in=W),
    }


def init_rglru_cache(cfg, batch, dtype):
    W = cfg.lru_width
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), dtype),
        "h": jnp.zeros((batch, W), jnp.float32),
    }


def _rglru_core(u, p):
    """u (B, S, W) -> (a (B,S,W) f32, b (B,S,W) f32): h_t = a_t h_{t-1} + b_t."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wi"] + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * uf)
    return a, b


def apply_rglru(p, x, *, cfg, mode, cache=None, length=None):
    """x (B, S, D) -> (y, new_cache).

    ``length`` (prefill only, traced scalar): true prompt length of a
    right-padded stream — pads become identity recurrence steps (a=1, b=0)
    and are excluded from the conv state, so the prefill cache at
    ``length`` is exactly the unpadded one.
    """
    B, S, D = x.shape
    dt = x.dtype

    g = jax.nn.gelu(qdense(x, p["w_gate"], dt), approximate=True)
    u = x @ p["w_branch"].astype(dt)
    conv_state = cache["conv"] if cache is not None and mode == "decode" else None
    u, new_conv = causal_conv(u, p["conv_w"], p["conv_b"], conv_state,
                              length=length if mode == "prefill" else None)
    u = constrain(u, "act_ff")

    a, b = _rglru_core(u, p)
    if length is not None and mode == "prefill":
        real = (jnp.arange(S) < length)[None, :, None]
        a = jnp.where(real, a, 1.0)
        b = jnp.where(real, b, 0.0)

    if mode == "decode":
        h = a[:, 0] * cache["h"] + b[:, 0]                    # (B, W)
        hs = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = b_sc  # zero initial state: h_t = (scanned b)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "h": hs[:, -1]}

    y = (g * hs.astype(dt)) @ p["w_out"].astype(dt)
    return y, new_cache
