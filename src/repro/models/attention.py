"""Attention layers: MHA/GQA/MQA, local (sliding-window, ring-buffer cache),
cross-attention, and DeepSeek MLA (naive train path + absorbed decode path).

These are the paper's *dynamic* kernels — per-token-changing operands that
the paper routes to the SM/MC/DRAM plane (§3.1).  The sharding plan gives
their activations head-wise placement ("SM cluster"); the inner product
runs through :mod:`repro.kernels.flash_attention`.

Serving modes beyond train/decode:

- ``mode="prefill"`` with ``segments=`` — **packed ragged prefill**: several
  prompts in one token stream, per-token prompt ids, no cross-prompt
  attention.  Returns the *raw per-token* cache (no slot padding); the
  serving engine gathers each segment into its KV slot.
- ``mode="chunk"`` — **chunked prefill continuation**: a block of S tokens
  per batch row is written into the existing KV cache at explicit
  positions (``pos < 0`` = pad, dropped) and attends to the whole cache,
  so later chunks of a long prompt see the KV of earlier chunks.
- ``mode="prefill"`` with ``length=`` — right-padded single-prompt prefill
  whose cache state is *exact* at ``length`` (ring caches keep the last
  real tokens, not the pads).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.common import NEG_INF
from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.models.modules import apply_rope, dense_init, rmsnorm
from repro.parallel import constrain
from repro.quant.core import (dequantize_kv, kv_cache_bits, quantize_kv,
                              quantize_kv_cache)
from repro.quant.ops import qdense


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, cross: bool = False, dtype=jnp.float32):
    D = cfg.d_model
    Hq, Hkv, hd, hdv = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd), dtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (D, Hkv * hdv), dtype),
        "wo": dense_init(ks[3], (Hq * hdv, D), dtype, fan_in=Hq * hdv),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * hdv,), jnp.float32)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def init_mla(key, cfg, *, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    p = {
        "wkv_a": dense_init(ks[0], (D, kvr + dr), dtype),
        "kv_norm": jnp.zeros((kvr,), jnp.float32),
        "wkv_b": dense_init(ks[1], (kvr, H, dn + dv), dtype, fan_in=kvr),
        "wo": dense_init(ks[2], (H * dv, D), dtype, fan_in=H * dv),
    }
    if qr:
        p["wq_a"] = dense_init(ks[3], (D, qr), dtype)
        p["q_norm"] = jnp.zeros((qr,), jnp.float32)
        p["wq_b"] = dense_init(ks[4], (qr, H, dn + dr), dtype, fan_in=qr)
    else:
        p["wq"] = dense_init(ks[3], (D, H, dn + dr), dtype)
    return p


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, kind: str, batch: int, kv_len: int, dtype,
                  n_cross: int = 0, kv_bits: int = 0):
    """``kv_bits`` (0 | 8 | 4): 0 keeps the fp pool; 8/4 allocate the
    *quantised* slot pool — int8 code planes (packed two-per-byte along the
    head dim for int4) plus per-(entry, head) f32 scales, quantised on
    commit and dequantised on read.  Quantisation covers the self-attention
    k/v pools; MLA latent and cross caches stay fp (documented)."""
    Hkv, hd, hdv = cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    if cfg.is_mla and kind != "cross":
        return {
            "ckv": jnp.zeros((batch, kv_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, kv_len, cfg.rope_head_dim), dtype),
            "pos": jnp.full((batch, kv_len), -1, jnp.int32),
        }
    if kind == "cross":
        return {
            "k": jnp.zeros((batch, n_cross, Hkv, hd), dtype),
            "v": jnp.zeros((batch, n_cross, Hkv, hdv), dtype),
        }
    cap = kv_len if kind == "global" else min(cfg.window, kv_len)
    if kv_bits in (4, 8):
        pack = 2 if kv_bits == 4 else 1
        if hd % pack or hdv % pack:
            raise ValueError(f"int4 KV needs even head dims, got {hd}/{hdv}")
        return {
            "k_q": jnp.zeros((batch, cap, Hkv, hd // pack), jnp.int8),
            "k_s": jnp.zeros((batch, cap, Hkv), jnp.float32),
            "v_q": jnp.zeros((batch, cap, Hkv, hdv // pack), jnp.int8),
            "v_s": jnp.zeros((batch, cap, Hkv), jnp.float32),
            "pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    if kv_bits:
        raise ValueError(f"kv_bits must be 0, 4 or 8, got {kv_bits}")
    return {
        "k": jnp.zeros((batch, cap, Hkv, hd), dtype),
        "v": jnp.zeros((batch, cap, Hkv, hdv), dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def ring_positions(length, cap: int):
    """Position held by each slot of a ``cap``-entry ring cache after
    prefilling ``length`` tokens: slot ``s`` holds ``p ≡ s (mod cap)``,
    ``p ∈ [length-cap, length)``; ``p < 0`` = empty.  ``length`` broadcasts
    (scalar → (cap,), (B, 1) → (B, cap)).  For global caches (cap >= length)
    this degenerates to the identity layout.  The single source of truth for
    the layout shared by prefill ring fill and the serving engine's packed
    multi-slot insert."""
    s_idx = jnp.arange(cap, dtype=jnp.int32)
    return length - 1 - ((length - 1 - s_idx) % cap)


def _ring_fill(k, v, positions, cap, length=None):
    """Build a ring cache holding the last ``cap`` prefilled tokens.

    Without ``length`` the stream is exact and the last ``cap`` of S tokens
    are kept.  With ``length`` (traced scalar) the stream is right-padded
    (positions are ``arange(S)``) and the ring keeps the last
    ``min(length, cap)`` *real* tokens — pads never enter the cache and
    never evict real entries.
    """
    B, S = k.shape[0], k.shape[1]
    if length is None:
        keep = min(S, cap)
        pos_tail = positions[:, S - keep:]               # (B, keep)
        slots = pos_tail % cap
        bidx = jnp.arange(B)[:, None]
        kc = jnp.zeros((B, cap) + k.shape[2:], k.dtype).at[bidx, slots].set(k[:, S - keep:])
        vc = jnp.zeros((B, cap) + v.shape[2:], v.dtype).at[bidx, slots].set(v[:, S - keep:])
        pc = jnp.full((B, cap), -1, jnp.int32).at[bidx, slots].set(pos_tail)
        return kc, vc, pc
    p = ring_positions(length, cap)
    valid = p >= 0
    src = jnp.clip(p, 0, S - 1)
    kc = jnp.where(valid[None, :, None, None], k[:, src], 0)
    vc = jnp.where(valid[None, :, None, None], v[:, src], 0)
    pc = jnp.broadcast_to(jnp.where(valid, p, -1), (B, cap))
    return kc, vc, pc


def _pad_cache(x, cap):
    B, S = x.shape[0], x.shape[1]
    if cap <= S:
        return x
    pad = jnp.zeros((B, cap - S) + x.shape[2:], x.dtype)
    return jnp.concatenate([x, pad], axis=1)


def _pad_pos(pos, cap):
    B, S = pos.shape
    if cap <= S:
        return pos
    return jnp.concatenate([pos, jnp.full((B, cap - S), -1, jnp.int32)], axis=1)


def _ring_write(cache, new_leaves: dict, pos):
    """Write S tokens at per-(row, token) ``pos`` into the cache (ring for
    local, direct for global).  ``new_leaves`` maps cache leaf names to the
    (B, S, ...) values to commit — ``{"k", "v"}`` for fp pools, the
    code/scale planes for quantised ones — so one scatter covers both
    layouts.  ``pos < 0`` entries are dropped — dead pool slots and chunk
    pads never touch the cache.  Within one call only the last ``cap``
    positions of a row survive the ring, so those are the only ones written
    (keeps scatter indices unique per row)."""
    cap = cache["pos"].shape[1]
    B, S = pos.shape
    row_max = jnp.max(jnp.where(pos >= 0, pos, -1), axis=1, keepdims=True)
    valid = (pos >= 0) & (pos > row_max - cap)
    slot = jnp.where(valid, pos % cap, cap)          # cap = out of bounds
    bidx = jnp.arange(B)[:, None]
    new = {name: cache[name].at[bidx, slot].set(
        leaf.astype(cache[name].dtype), mode="drop")
        for name, leaf in new_leaves.items()}
    new["pos"] = cache["pos"].at[bidx, slot].set(pos, mode="drop")
    return new


def _commit_kv(cache, new_k, new_v, pos):
    """Commit fresh K/V rows into the slot pool: fp pools write the rows
    as-is; quantised pools quantise on commit (one symmetric scale per
    (token, head) row, int8 codes, packed for int4) so an fp copy of the
    cache never exists between steps."""
    if "k_q" in cache:
        bits = kv_cache_bits(cache, new_k.shape[-1])
        k_q, k_s = quantize_kv(new_k, bits)
        v_q, v_s = quantize_kv(new_v, bits)
        return _ring_write(cache, {"k_q": k_q, "k_s": k_s,
                                   "v_q": v_q, "v_s": v_s}, pos)
    return _ring_write(cache, {"k": new_k, "v": new_v}, pos)


# ---------------------------------------------------------------------------
# apply — standard path
# ---------------------------------------------------------------------------

def apply_attention(
    p,
    x,                       # (B, S, D)
    *,
    cfg,
    kind: str,               # global | local | cross
    mode: str,               # train | prefill | chunk | decode
    pos,                     # (B, S) int32 (decode: (B, 1); chunk: -1 = pad)
    cache=None,
    cross_src=None,          # (B, S_src, D) for cross in train/prefill
    impl: str = "auto",
    causal: bool = True,     # encoder stacks pass False
    kv_cap: int = 0,         # prefill: cache capacity to allocate (>= S)
    length=None,             # prefill: true prompt length of a padded stream
    segments=None,           # prefill: (B, S) packed prompt ids, -1 = pad
    kv_bits: int = 0,        # prefill: 8/4 returns a quantised cache
):
    B, S, D = x.shape
    Hq, Hkv, hd, hdv = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.v_head_dim
    dt = x.dtype
    causal = causal and kind != "cross"
    window = cfg.window if kind == "local" else 0
    theta = cfg.rope_theta_local if (kind == "local" and cfg.rope_theta_local) else cfg.rope_theta

    q = qdense(x, p["wq"], dt, "weight_full")
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, S, Hq, hd)

    if kind == "cross":
        if mode in ("decode", "chunk"):
            k, v = cache["k"], cache["v"]
            new_cache = cache
            # non-causal attention over a fully-valid cache expressed via
            # the masked explicit-position path: every q_pos >= every
            # kv_pos makes the causal predicate vacuous, so impl="flash"
            # runs the Pallas decode kernel instead of silently
            # downgrading to the reference path
            Skv = k.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32),
                                      (B, Skv))
            q = constrain(q, "act_heads")
            out = flash_attention(q, k, v, causal=True,
                                  softcap=cfg.attn_softcap, impl=impl,
                                  q_pos=jnp.full((B, S), Skv, jnp.int32),
                                  kv_pos=kv_pos, kv_valid=None)
        else:
            src = cross_src.astype(dt)
            k = qdense(src, p["wk"], dt)
            v = qdense(src, p["wv"], dt)
            if "bk" in p:
                k = k + p["bk"].astype(dt)
                v = v + p["bv"].astype(dt)
            k = k.reshape(B, -1, Hkv, hd)
            v = v.reshape(B, -1, Hkv, hdv)
            new_cache = {"k": k, "v": v} if mode == "prefill" else None
            q = constrain(q, "act_heads")
            out = flash_attention(q, k, v, causal=False,
                                  softcap=cfg.attn_softcap, impl=impl)
        out = qdense(out.reshape(B, S, Hq * hdv), p["wo"], dt)
        return out, new_cache

    k = qdense(x, p["wk"], dt, "weight_full")
    v = qdense(x, p["wv"], dt, "weight_full")
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hdv)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    q = constrain(q, "act_heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")

    if mode in ("train", "prefill"):
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_softcap, impl=impl,
                              segments=segments)
        new_cache = None
        if mode == "prefill":
            if segments is not None:
                # packed ragged prefill: raw per-token cache; the serving
                # engine gathers each segment into its KV slot
                new_cache = {"k": k, "v": v,
                             "pos": jnp.where(segments >= 0, pos, -1)}
            else:
                cap = max(kv_cap, S)
                if kind == "local":
                    kc, vc, pc = _ring_fill(k, v, pos, min(cfg.window, cap),
                                            length=length)
                    new_cache = {"k": kc, "v": vc, "pos": pc}
                else:
                    new_cache = {"k": _pad_cache(k, cap),
                                 "v": _pad_cache(v, cap),
                                 "pos": _pad_pos(pos, cap)}
            if kv_bits:
                # quantise the freshly-built cache so it matches the
                # engine's quantised slot pool (empty entries stay zeros)
                new_cache = quantize_kv_cache(new_cache, kv_bits)
    else:  # decode (S == 1 — Pallas decode kernel) / chunk (S-token write)
        quant = "k_q" in cache
        bits = kv_cache_bits(cache, hd) if quant else 0
        new_cache = _commit_kv(cache, k, v, pos)
        qkw = {}
        if mode == "chunk":
            # attend to the PRE-write cache plus the in-stream chunk: the
            # chunk write may evict ring entries that early chunk queries
            # still need (their window reaches back before the chunk), and
            # cache positions are all < the chunk's, so no duplicates.
            # A quantised cache is dequantised for the read (the committed
            # pool stays int8; the in-stream chunk attends at fp)
            if quant:
                ck = dequantize_kv(cache["k_q"], cache["k_s"], bits)
                cv = dequantize_kv(cache["v_q"], cache["v_s"], bits)
            else:
                ck, cv = cache["k"], cache["v"]
            kc = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
            vc = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
            kv_pos = jnp.concatenate([cache["pos"], pos], axis=1)
        elif quant:
            # dequantise-on-read decode: codes + scales go straight to the
            # kernel route (in-VMEM dequant); the fp cache never exists
            kc, vc, kv_pos = new_cache["k_q"], new_cache["v_q"], new_cache["pos"]
            qkw = dict(k_scale=new_cache["k_s"], v_scale=new_cache["v_s"],
                       kv_bits=bits)
        else:
            kc, vc, kv_pos = new_cache["k"], new_cache["v"], new_cache["pos"]
        out = flash_attention(
            q, kc, vc,
            q_pos=pos, kv_pos=kv_pos, kv_valid=kv_pos >= 0,
            causal=causal, window=window, softcap=cfg.attn_softcap,
            impl=impl, **qkw)

    out = qdense(out.reshape(B, S, Hq * hdv), p["wo"], dt, "weight_full")
    return out, new_cache


# ---------------------------------------------------------------------------
# apply — MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(p, x, pos, cfg):
    B, S, _ = x.shape
    dt = x.dtype
    H, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    if "wq_a" in p:
        cq = rmsnorm(x @ p["wq_a"].astype(dt), p["q_norm"])
        q = jnp.einsum("bsr,rhd->bshd", cq, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsD,Dhd->bshd", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, x, pos, cfg):
    dt = x.dtype
    kvr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv_full = x @ p["wkv_a"].astype(dt)
    ckv = rmsnorm(ckv_full[..., :kvr], p["kv_norm"])
    kr = ckv_full[..., kvr:]
    kr = apply_rope(kr[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def apply_mla(p, x, *, cfg, mode, pos, cache=None, impl="auto", kv_cap: int = 0,
              length=None, segments=None):
    """MLA self-attention.  train/prefill: naive expanded path (packed
    ragged prefill via ``segments=``); decode/chunk: absorbed latent-space
    path (the serving memory-traffic optimisation the paper's MQA
    discussion anticipates, §3.2)."""
    B, S, D = x.shape
    dt = x.dtype
    H, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _mla_q(p, x, pos, cfg)
    ckv, kr = _mla_kv_latent(p, x, pos, cfg)

    if mode in ("train", "prefill"):
        kv = jnp.einsum("bsr,rhd->bshd", ckv, p["wkv_b"].astype(dt))
        kv = constrain(kv, "kv_heads")
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))], -1)
        k = constrain(k, "kv_heads")
        v = constrain(v, "kv_heads")
        q = jnp.concatenate([q_nope, q_rope], -1)
        q = constrain(q, "act_heads")
        out = flash_attention(q, k, v, causal=True, scale=scale, impl=impl,
                              segments=segments)
        new_cache = None
        if mode == "prefill":
            if segments is not None:
                new_cache = {"ckv": ckv, "kr": kr,
                             "pos": jnp.where(segments >= 0, pos, -1)}
            else:
                cap = max(kv_cap, S)
                new_cache = {"ckv": _pad_cache(ckv, cap),
                             "kr": _pad_cache(kr, cap),
                             "pos": _pad_pos(pos, cap)}
    else:  # decode / chunk — absorbed; pos < 0 entries are dropped
        cap = cache["ckv"].shape[1]
        bidx = jnp.arange(B)[:, None]
        slot = jnp.where(pos >= 0, pos, cap)         # cap = out of bounds
        new_cache = {
            "ckv": cache["ckv"].at[bidx, slot].set(
                ckv.astype(cache["ckv"].dtype), mode="drop"),
            "kr": cache["kr"].at[bidx, slot].set(
                kr.astype(cache["kr"].dtype), mode="drop"),
            "pos": cache["pos"].at[bidx, slot].set(pos, mode="drop"),
        }
        ckv_all, kr_all, kv_pos = new_cache["ckv"], new_cache["kr"], new_cache["pos"]
        w_uk = p["wkv_b"][..., :dn].astype(dt)        # (kvr, H, dn)
        w_uv = p["wkv_b"][..., dn:].astype(dt)        # (kvr, H, dv)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                             ckv_all.astype(jnp.float32))
                  + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                               kr_all.astype(jnp.float32))) * scale
        mask = (kv_pos[:, None, None, :] <= pos[:, None, :, None]) & \
               (kv_pos >= 0)[:, None, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        # fully-masked rows (chunk pads) must produce zeros, not NaN
        w = jnp.where(mask.any(axis=-1)[..., None], w, 0.0).astype(dt)
        ctx = jnp.einsum("bhqk,bkr->bqhr", w, ckv_all)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv)

    out = qdense(out.reshape(B, S, H * dv), p["wo"], dt)
    return out, new_cache
