"""Deterministic kernel/phase micro-timer — the Plane-A side of the
measured-cost calibration plane (ROADMAP item 4).

Methodology
-----------
Every timed case is a zero-argument jitted callable.  It runs ``warmup``
calls first — XLA compilation and Pallas tracing happen there and the
first call's wall time is reported separately as ``compile_s`` — then
``repeat`` steady-state calls, each synchronised through
``jax.block_until_ready`` so asynchronous dispatch cannot leak device
work out of the timed region.  The statistic handed to the cost-model
fit is the steady-state *minimum*: timing noise on a shared machine is
strictly additive, so min-of-k is the stable estimator (the same
best-of-``repeat`` convention the ``benchmarks/perf_*`` drains use).

The clock is injectable (``clock=``), mirroring ``EngineConfig(clock=)``,
so tests drive the timer with a fake clock and assert the bookkeeping
deterministically.  On anything that is not a TPU the Pallas kernels run
through the interpreter (``interpret=True`` — ``interpret_default()``);
rates fitted there calibrate the interpreter as a backend, which is
exactly the backend the CPU CI lane replays.

Every :class:`Sample` carries the ``core.traffic`` byte/FLOP terms of
its invocation next to the measured seconds, so ``profile.costmodel``
can fit time as an affine model *in the analytical regressors* — the
whole point of the calibration plane is that Plane B and the fits share
one vocabulary of terms.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.config import get_config, reduce_config
from repro.core import traffic
from repro.core.traffic import Workload

__all__ = [
    "Timing", "Sample", "measure", "interpret_default",
    "kernel_samples", "executor_samples",
]


def interpret_default() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached."""
    return jax.default_backend() != "tpu"


def _sync(x):
    return jax.block_until_ready(x)


@dataclasses.dataclass(frozen=True)
class Timing:
    """One timed case: compile/trace cost split from steady state."""
    compile_s: float              # first call (includes jit + Pallas trace)
    times_s: tuple[float, ...]    # steady-state calls, in order

    @property
    def best_s(self) -> float:
        return min(self.times_s)

    @property
    def median_s(self) -> float:
        ts = sorted(self.times_s)
        return ts[len(ts) // 2]


def measure(fn: Callable[[], object], *, warmup: int = 1, repeat: int = 3,
            clock: Callable[[], float] = time.perf_counter,
            sync: Optional[Callable] = _sync) -> Timing:
    """Time ``fn`` with the warmup/steady-state split described above."""
    if warmup < 1 or repeat < 1:
        raise ValueError("measure needs warmup >= 1 and repeat >= 1")
    compile_s = 0.0
    for i in range(warmup):
        t0 = clock()
        out = fn()
        if sync is not None:
            sync(out)
        dt = clock() - t0
        if i == 0:
            compile_s = dt
    times = []
    for _ in range(repeat):
        t0 = clock()
        out = fn()
        if sync is not None:
            sync(out)
        times.append(clock() - t0)
    return Timing(compile_s=compile_s, times_s=tuple(times))


@dataclasses.dataclass(frozen=True)
class Sample:
    """One timed grid point with its analytical regressors.

    ``bytes_term``/``flops_term`` are computed from the *same*
    ``core.traffic`` formulas Plane B charges for the matching phase, so
    a fit against them yields directly comparable effective rates.
    """
    kind: str          # phase class ("decode_attn", "prefill_attn", ...)
    arch: str
    params: dict       # grid point (batch, kv len, seq, dims, ...)
    bytes_term: float
    flops_term: float
    seconds: float     # steady-state best
    compile_s: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Sample":
        return cls(**d)


# ---------------------------------------------------------------------------
# kernel grid: decode attention (fp / kv8 / kv4), segmented prefill,
# fused dequant-matmul — the real Pallas kernels, timed
# ---------------------------------------------------------------------------

def _decode_case(cfg, batch: int, skv: int, kv_bits: int, *,
                 interpret: bool, key) -> tuple[Callable, float, float]:
    """Build a jitted decode-attention invocation + its traffic terms."""
    from repro.kernels.flash_attention.decode import (flash_decode_fwd,
                                                      flash_decode_quant_fwd)
    from repro.quant.core import quantize_kv

    Hq, Hkv, hd = cfg.n_heads, max(cfg.n_kv_heads or cfg.n_heads, 1), cfg.head_dim
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (batch, 1, Hq, hd), jnp.bfloat16)
    k = jax.random.normal(kk, (batch, skv, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(kv, (batch, skv, Hkv, hd), jnp.bfloat16)
    q_pos = jnp.full((batch, 1), skv - 1, jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (batch, skv))
    # one KV block per (slot, head): the interpreter's per-grid-point
    # overhead (full-pool reslicing) is then constant per case and the
    # steady-state time tracks the streamed bytes linearly — the regime
    # the affine cost model assumes
    block_k = min(skv, 1024)

    if kv_bits:
        k_q, k_s = quantize_kv(k, kv_bits)
        v_q, v_s = quantize_kv(v, kv_bits)

        def call():
            return flash_decode_quant_fwd(
                q, k_q, k_s, v_q, v_s, kv_bits=kv_bits, q_pos=q_pos,
                kv_pos=kv_pos, block_k=block_k, interpret=interpret)
    else:
        def call():
            return flash_decode_fwd(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                    block_k=block_k, interpret=interpret)

    # regressors: the per-layer KV stream Plane B charges for score_dec —
    # traffic.kv_cache_bytes_per_layer at the pool depth, once per slot
    w = Workload.from_config(cfg, seq_len=skv, kv_bits=kv_bits or 16)
    bytes_term = batch * traffic.kv_cache_bytes_per_layer(w, skv)
    flops_term = 4.0 * batch * Hq * skv * hd       # QK^T + PV, one query row
    return jax.jit(call), bytes_term, flops_term


def _prefill_case(cfg, batch: int, seq: int, *, seg_len: int,
                  interpret: bool, key) -> tuple[Callable, float, float]:
    """Segmented (packed-prompt) prefill attention + traffic terms."""
    from repro.kernels.flash_attention.kernel import flash_attention_fwd

    Hq, hd = cfg.n_heads, cfg.head_dim
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (batch, Hq, seq, hd), jnp.bfloat16)
    k = jax.random.normal(kk, (batch, Hq, seq, hd), jnp.bfloat16)
    v = jax.random.normal(kv, (batch, Hq, seq, hd), jnp.bfloat16)
    seg = jnp.broadcast_to(
        jnp.arange(seq, dtype=jnp.int32) // seg_len, (batch, seq))
    # single-block sweep per (stream, head) — same rationale as the
    # decode case: constant grid overhead, work tracks the S^2 term
    blk = min(seq, 512)

    def call():
        return flash_attention_fwd(q, k, v, segments=seg, causal=True,
                                   block_q=blk, block_k=blk,
                                   interpret=interpret)

    # regressors: the full-sequence score phase transformer_phases
    # charges.  Segmentation only tightens the mask *inside* computed
    # blocks — the kernel still sweeps the causal S^2 block grid, so the
    # work term is quadratic in S regardless of how many prompts are
    # packed (causal halving is a constant; constants live in the rate)
    w = Workload.from_config(cfg, seq_len=seq)
    score = next(p for p in traffic.transformer_phases(w)
                 if p.name == "score")
    flops_term = batch * score.sm_flops
    bytes_term = batch * traffic.phase_bytes(score)
    return jax.jit(call), bytes_term, flops_term


def _qmm_case(cfg, m: int, k_dim: int, n_dim: int, bits: int, *,
              interpret: bool, key) -> tuple[Callable, float, float]:
    """Fused dequant-matmul (weight-streaming regime) + traffic terms."""
    from repro.quant.core import quantize
    from repro.quant.kernel import quant_matmul_pallas

    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k_dim), jnp.bfloat16)
    qt = quantize(jax.random.normal(kw, (k_dim, n_dim), jnp.float32), bits)
    # single-block invocation (same rationale as the attention cases):
    # constant grid overhead, steady-state time tracks the streamed
    # weight bytes linearly
    bm, bn, bk = min(8, m), min(512, n_dim), min(512, k_dim)

    def call():
        return quant_matmul_pallas(x, qt.q, qt.scale, bits=bits,
                                   bm=bm, bn=bn, bk=bk, interpret=interpret)

    # regressors: the streamed-weight bytes Plane B charges for a
    # quantised (K, N) projection (codes + f32 scale plane)
    w = Workload.from_config(cfg, seq_len=m, weight_bits=bits)
    bytes_term = w.weight_dram_bytes(k_dim, n_dim)
    flops_term = 2.0 * m * k_dim * n_dim
    return jax.jit(call), bytes_term, flops_term


def kernel_samples(archs: Sequence[str] = ("bert-base", "gpt-j"), *,
                   batches: Sequence[int] = (1, 2),
                   kv_lens: Sequence[int] = (128, 256, 384),
                   kv_bits: Sequence[int] = (0, 8, 4),
                   prefill_lens: Sequence[int] = (128, 256),
                   seg_len: int = 64,
                   qmm_shapes: Sequence[tuple[int, int]] = ((128, 256),
                                                           (256, 256),
                                                           (256, 512)),
                   qmm_m: int = 8,
                   qmm_bits: Sequence[int] = (8,),
                   warmup: int = 1, repeat: int = 3,
                   clock: Callable[[], float] = time.perf_counter,
                   interpret: Optional[bool] = None,
                   seed: int = 0) -> list[Sample]:
    """Time the real Pallas kernels across a zoo x batch x KV-position
    grid and return one :class:`Sample` per grid point.

    Kinds produced: ``decode_attn`` / ``decode_attn_kv8`` /
    ``decode_attn_kv4`` (pool depth = the KV-position axis),
    ``prefill_attn`` (segmented packed prompts), ``dequant_matmul``.
    """
    interp = interpret_default() if interpret is None else interpret
    key = jax.random.PRNGKey(seed)
    out: list[Sample] = []
    for arch in archs:
        cfg = reduce_config(get_config(arch))
        for bits in kv_bits:
            kind = "decode_attn" + (f"_kv{bits}" if bits else "")
            for batch in batches:
                for skv in kv_lens:
                    key, sub = jax.random.split(key)
                    fn, b, f = _decode_case(cfg, batch, skv, bits,
                                            interpret=interp, key=sub)
                    t = measure(fn, warmup=warmup, repeat=repeat, clock=clock)
                    out.append(Sample(kind, arch,
                                      {"batch": batch, "kv_len": skv,
                                       "kv_bits": bits or 16},
                                      b, f, t.best_s, t.compile_s))
        for batch in batches:
            for seq in prefill_lens:
                key, sub = jax.random.split(key)
                fn, b, f = _prefill_case(cfg, batch, seq, seg_len=seg_len,
                                         interpret=interp, key=sub)
                t = measure(fn, warmup=warmup, repeat=repeat, clock=clock)
                out.append(Sample("prefill_attn", arch,
                                  {"batch": batch, "seq": seq,
                                   "seg_len": seg_len},
                                  b, f, t.best_s, t.compile_s))
        for bits in qmm_bits:
            for (k_dim, n_dim) in qmm_shapes:
                key, sub = jax.random.split(key)
                fn, b, f = _qmm_case(cfg, qmm_m, k_dim, n_dim, bits,
                                     interpret=interp, key=sub)
                t = measure(fn, warmup=warmup, repeat=repeat, clock=clock)
                out.append(Sample("dequant_matmul", arch,
                                  {"m": qmm_m, "k": k_dim, "n": n_dim,
                                   "bits": bits},
                                  b, f, t.best_s, t.compile_s))
    return out


# ---------------------------------------------------------------------------
# executor grid: the jitted fused decode-step program, timed end to end
# ---------------------------------------------------------------------------

def executor_samples(archs: Sequence[str] = ("bert-base",), *,
                     batches: Sequence[int] = (1, 2, 4),
                     kv_len: int = 128, prompt_len: int = 16,
                     impl: str = "ref",
                     warmup: int = 1, repeat: int = 3,
                     steps_per_call: int = 8,
                     clock: Callable[[], float] = time.perf_counter,
                     seed: int = 0) -> list[Sample]:
    """Time the engine's jitted ``fused_step`` program (decode step over
    the slot pool — the thing a serving decode iteration actually runs).

    The buffers are donated by ``jit_step``, so each timed call chains
    the returned cache/state into the next; slot positions advance one
    token per step and the byte regressor is evaluated at the midpoint
    of the timed window.  Each timed call runs ``steps_per_call`` chained
    steps and reports the per-step time: a single step is sub-millisecond
    on CPU, so amortising scheduler jitter over the chain is what keeps
    the latency-floor fit's residuals inside the pinned tolerance.
    """
    import repro.models.transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    out: list[Sample] = []
    for arch in archs:
        cfg = reduce_config(get_config(arch))
        for batch in batches:
            params = T.init_params(cfg, jax.random.PRNGKey(seed),
                                   param_dtype=jnp.bfloat16)
            eng = ServingEngine(cfg, params, EngineConfig(
                max_batch=batch, kv_len=kv_len,
                max_new_tokens=kv_len - prompt_len - 2,
                impl=impl, fused=True, packed=True, seed=seed))
            for i in range(batch):
                eng.submit([(7 * i + j) % 97 + 1 for j in range(prompt_len)])
            eng.step()                      # admit + first decode step
            calls = {"n": 0}

            def call(calls=calls, eng=eng):
                calls["n"] += 1
                packed = None
                for _ in range(steps_per_call):
                    c, s, packed = eng.executor.fused_step(eng.pool.cache,
                                                           eng.pool.state)
                    eng.pool.cache, eng.pool.state = c, s
                return packed

            t = measure(call, warmup=warmup, repeat=repeat, clock=clock)
            t = Timing(compile_s=t.compile_s,
                       times_s=tuple(x / steps_per_call for x in t.times_s))
            # positions at the midpoint of the steady-state window
            mid = (prompt_len + 1
                   + steps_per_call * (warmup + repeat // 2))
            w = Workload.from_config(cfg, seq_len=kv_len)
            phases = traffic.decode_step_phases(w, [mid] * batch,
                                                batch=batch)
            bytes_term = traffic.total_traffic_bytes(phases)
            flops_term = sum(p.repeat * (p.sm_flops + p.reram_flops)
                             for p in phases)
            out.append(Sample("executor_step", arch,
                              {"batch": batch, "kv_len": kv_len,
                               "pos": mid, "impl": impl},
                              bytes_term, flops_term, t.best_s, t.compile_s))
    return out
