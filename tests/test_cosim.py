"""Decode-aware co-simulation: generation traffic invariants (single-stream
and batched), the simulate_generation execution model, the
energy-accounting fixes, the Table-4 regression pins, and the Plane-A →
Plane-B bridge (`core/cosim`)."""
import dataclasses

import numpy as np
import pytest

from repro.config import get_config
from repro.core import chiplets as C
from repro.core.cosim import (Episode, EpisodeMix, cosim_mix,
                              generation_objective, generation_phases,
                              mix_from_stats)
from repro.core.noi import evaluate_noi
from repro.core.placement import initial_placement
from repro.core.simulator import (_energy, simulate_2p5d_hi,
                                  simulate_generation)
from repro.core.traffic import (Phase, Workload, decode_step_phases,
                                decode_weight_stream_bytes,
                                kv_cache_bytes_per_layer, phase_bytes,
                                prefill_phases, total_traffic_bytes,
                                transformer_phases)

# the perf_cosim model zoo: MHA, GQA, MQA-ish, parallel-block and enc-dec
ZOO = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b",
       "bart-large", "whisper-large-v3")

ARCHS = ("2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet")


def _w(arch, n):
    return Workload.from_config(get_config(arch), seq_len=n)


# ---------------------------------------------------------------------------
# decode-phase traffic invariants
# ---------------------------------------------------------------------------

def test_kv_cache_read_grows_linearly_with_position():
    w = _w("llama2-7b", 64)
    by1 = {p.name: p for p in decode_step_phases(w, 256)}
    by2 = {p.name: p for p in decode_step_phases(w, 512)}
    fixed = w.d_model * w.d_model * 2          # weight stream, pos-independent
    kv1 = by1["score_dec"].dram_bytes - fixed
    kv2 = by2["score_dec"].dram_bytes - fixed
    assert kv2 == pytest.approx(2 * kv1)
    assert kv1 == pytest.approx(kv_cache_bytes_per_layer(w, 256))


def test_gqa_shrinks_kv_traffic_vs_mha():
    dims = dict(name="x", d_model=4096, n_layers=32, d_ff=11008,
                vocab=32000, seq_len=256)
    mha = Workload(n_heads=32, n_kv_heads=32, **dims)
    gqa = Workload(n_heads=32, n_kv_heads=8, **dims)
    mqa = Workload(n_heads=32, n_kv_heads=1, **dims)
    assert kv_cache_bytes_per_layer(gqa, 512) == pytest.approx(
        kv_cache_bytes_per_layer(mha, 512) / 4)
    assert kv_cache_bytes_per_layer(mqa, 512) == pytest.approx(
        kv_cache_bytes_per_layer(mha, 512) / 32)
    # ...and it reaches the score phase's streamed bytes
    s_mha = {p.name: p for p in decode_step_phases(mha, 512)}["score_dec"]
    s_gqa = {p.name: p for p in decode_step_phases(gqa, 512)}["score_dec"]
    assert s_gqa.dram_bytes < s_mha.dram_bytes


def test_decode_phases_cover_decoder_stack_only():
    w = _w("whisper-large-v3", 64)          # 32 enc + 32 dec layers
    assert w.n_enc_layers == 32 and w.n_dec_layers == 32
    by = {p.name: p for p in decode_step_phases(w, 128)}
    assert by["kqv_dec"].repeat == 32
    assert "cross_dec" in by                # enc-dec re-reads the cross-KV
    assert by["cross_dec"].repeat == 32


def test_enc_dec_cross_repeat_follows_decoder_stack():
    """The old ``n_layers // 2`` collapse was only right for symmetric
    stacks; an asymmetric workload must repeat cross per decoder layer."""
    sym = _w("bart-large", 64)              # 12 + 12
    by = {p.name: p for p in transformer_phases(sym)}
    assert by["cross"].repeat == 12
    asym = dataclasses.replace(sym, n_layers=30, n_enc_layers=24)
    by = {p.name: p for p in transformer_phases(asym)}
    assert by["cross"].repeat == 6          # = n_dec_layers, not 30//2


@pytest.mark.parametrize("n_chiplets", sorted(C.SYSTEM_ALLOC))
def test_decode_noi_routes_on_all_system_sizes(n_chiplets):
    w = _w("gemma2-9b", 128)
    p = initial_placement(n_chiplets)
    ev = evaluate_noi(p, decode_step_phases(w, 384))
    assert np.isfinite(ev.mu) and ev.mu > 0
    assert np.isfinite(ev.max_util)
    ev_pre = evaluate_noi(p, prefill_phases(w))
    assert np.isfinite(ev_pre.mu) and ev_pre.mu > 0


def test_prefill_phases_add_kv_writeback_only():
    w = _w("llama2-7b", 256)
    pre = prefill_phases(w)
    assert [p.name for p in pre[:-1]] == [p.name for p in transformer_phases(w)]
    kv = pre[-1]
    assert kv.name == "kv_write"
    assert kv.repeat == w.n_dec_layers
    assert kv.dram_bytes == pytest.approx(kv_cache_bytes_per_layer(w, 256))


# ---------------------------------------------------------------------------
# generation execution model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["2.5D-HI", "HAIMA_chiplet",
                                  "TransPIM_chiplet"])
def test_generation_slower_than_single_pass_same_tokens(arch):
    """Autoregressive generation of P+G tokens can never beat one
    single-pass forward over P+G tokens (decode loses batch amortisation)."""
    from repro.core.baselines import (simulate_haima_chiplet,
                                      simulate_transpim_chiplet)
    sims = {"2.5D-HI": simulate_2p5d_hi,
            "HAIMA_chiplet": simulate_haima_chiplet,
            "TransPIM_chiplet": simulate_transpim_chiplet}
    prompt, gen = 192, 64
    w = _w("llama2-7b", prompt + gen)
    single = sims[arch](w, 64)
    g = simulate_generation(w, 64, prompt, gen, arch=arch)
    assert g.latency_s >= single.latency_s
    assert g.ttft_s < g.latency_s
    assert g.energy_j > 0 and g.decode_step_s > 0


def test_generation_decode_latency_grows_with_position():
    w = _w("llama2-7b", 64)
    short = simulate_generation(w, 64, 64, 32)
    long = simulate_generation(w, 64, 2048, 32)
    assert long.decode_step_s > short.decode_step_s   # bigger KV to stream
    assert long.ttft_s > short.ttft_s


def test_generation_gqa_decodes_faster_than_mha():
    dims = dict(name="x", d_model=4096, n_layers=32, d_ff=11008,
                vocab=32000, seq_len=512)
    mha = Workload(n_heads=32, n_kv_heads=32, **dims)
    mqa = Workload(n_heads=32, n_kv_heads=1, **dims)
    g_mha = simulate_generation(mha, 64, 512, 64)
    g_mqa = simulate_generation(mqa, 64, 512, 64)
    assert g_mqa.decode_step_s < g_mha.decode_step_s
    assert g_mqa.decode_bytes < g_mha.decode_bytes


def test_generation_traffic_split_decode_heavy():
    """Weights re-stream per generated token: with a non-trivial gen length
    decode dominates the fabric traffic — the regime the NoI must serve."""
    w = _w("llama2-7b", 512)
    g = simulate_generation(w, 64, 512, 128)
    assert g.decode_bytes > g.prefill_bytes


# ---------------------------------------------------------------------------
# energy accounting (satellite bugfix)
# ---------------------------------------------------------------------------

def test_energy_background_weighted_by_repeat():
    """DRAM background energy integrates phase time × repeat; the busy /
    background composition is pinned against hand-computed values."""
    alloc = {"SM": 2, "DRAM": 3}
    phases = [Phase("a", repeat=10), Phase("b", repeat=1)]
    times = {"a": 0.5, "b": 2.0}
    busy = {"a": {"SM"}, "b": set()}
    e = _energy(phases, times, alloc, None, busy)
    busy_e = 2 * C.SM.power_w * 0.5 * 10          # SM busy during a × repeat
    background = 3 * C.DRAM.idle_power_w * (0.5 * 10 + 2.0)
    assert e == pytest.approx(busy_e + background)


def test_energy_background_scales_with_depth():
    """A 2× deeper model must carry ≥2× the background DRAM energy (the old
    sum-one-execution-per-phase under-counted this by ~n_layers×)."""
    w12 = _w("bert-base", 64)
    w24 = dataclasses.replace(w12, n_layers=24)
    e12 = simulate_2p5d_hi(w12, 36).energy_j
    e24 = simulate_2p5d_hi(w24, 36).energy_j
    assert e24 > 1.8 * e12


# ---------------------------------------------------------------------------
# Plane-A → Plane-B bridge
# ---------------------------------------------------------------------------

def _fake_stats():
    return {"finished": 4, "prompt_lens": [8, 8, 16, 24],
            "gen_lens": [4, 4, 8, 8], "prefill_chunk": 32, "max_batch": 4}


def test_mix_from_stats_groups_episodes():
    mix = mix_from_stats(_fake_stats())
    assert mix.requests == 4
    assert mix.prefill_chunk == 32 and mix.max_batch == 4
    assert Episode(8, 4, 2) in mix.episodes
    assert mix.prefill_tokens == 8 + 8 + 16 + 24
    assert mix.decode_tokens == 3 + 3 + 7 + 7
    with pytest.raises(ValueError):
        mix_from_stats({"finished": 0})


def test_cosim_mix_reports_all_archs():
    mix = mix_from_stats(_fake_stats())
    rec = cosim_mix("qwen2.5-3b", mix, 36)
    assert set(rec) == {"2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet"}
    for row in rec.values():
        assert row["ttft_s"] > 0 and row["tokens_per_s"] > 0
        assert 0.0 < row["decode_traffic_frac"] < 1.0


def test_generation_objective_is_finite_and_decode_weighted():
    mix = EpisodeMix([Episode(64, 32, 2)])
    objective, mesh_ev, phases = generation_objective("qwen2.5-3b", mix, 36)
    assert np.isfinite(mesh_ev.mu) and mesh_ev.mu > 0
    mu, sigma = objective(initial_placement(36))
    assert np.isfinite(mu) and np.isfinite(sigma)
    # decode phases must dominate the repeat-weighted traffic
    dec = sum(total_traffic_bytes([p]) for p in phases
              if p.name.endswith("_dec"))
    total = sum(total_traffic_bytes([p]) for p in phases)
    assert dec / total > 0.5


def test_generation_phases_scale_with_gen_len():
    one = generation_phases("qwen2.5-3b", EpisodeMix([Episode(64, 8, 1)]))
    two = generation_phases("qwen2.5-3b", EpisodeMix([Episode(64, 64, 1)]))
    assert total_traffic_bytes(two) > total_traffic_bytes(one)


@pytest.mark.parametrize("gen_len,samples", [(11, 4), (8, 4), (64, 3)])
def test_generation_phases_partition_decode_steps_exactly(gen_len, samples):
    """The sampled decode positions must represent exactly gen_len-1 steps
    (rounding must not over/under-weight decode in the MOO objective)."""
    w = _w("qwen2.5-3b", 64)
    mix = EpisodeMix([Episode(64, gen_len, 3)])
    phases = generation_phases("qwen2.5-3b", mix, samples=samples)
    per_layer = w.n_dec_layers * 3                  # repeat × episode count
    kqv_repeats = sum(p.repeat for p in phases if p.name == "kqv_dec")
    assert kqv_repeats == (gen_len - 1) * per_layer


# ---------------------------------------------------------------------------
# batched-decode traffic invariants (property suite over the zoo)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ZOO)
def test_batch1_phases_identical_to_unbatched(name):
    w = _w(name, 96)
    assert decode_step_phases(w, 192, batch=1) == decode_step_phases(w, 192)


@pytest.mark.parametrize("name", ZOO)
@pytest.mark.parametrize("B", [2, 4, 8])
def test_batched_decode_bytes_strictly_sublinear(name, B):
    """A batched step injects strictly less than B x the single-slot step
    (the weight streams are paid once), but more than one slot's worth."""
    w = _w(name, 96)
    t1 = total_traffic_bytes(decode_step_phases(w, 192))
    tB = total_traffic_bytes(decode_step_phases(w, 192, batch=B))
    assert t1 < tB < B * t1


@pytest.mark.parametrize("name", ZOO)
def test_weight_stream_bytes_independent_of_batch(name):
    """Total step bytes are affine in B with the weight stream as the
    B-independent intercept: bytes(B) = weights + B * per_slot."""
    w = _w(name, 96)
    wt = decode_weight_stream_bytes(w)
    t1 = total_traffic_bytes(decode_step_phases(w, 192))
    per_slot = t1 - wt
    assert 0 < wt < t1
    for B in (2, 3, 8, 16):
        tB = total_traffic_bytes(decode_step_phases(w, 192, batch=B))
        assert tB == pytest.approx(wt + B * per_slot, rel=1e-12)


@pytest.mark.parametrize("name", ZOO)
def test_kv_read_linear_in_sum_of_slot_positions(name):
    """Per-slot KV reads sum over the batch at each slot's own position:
    any position vector with the same sum injects the same score-phase
    bytes, and the KV component is kv_cache_bytes_per_layer of the sum."""
    w = _w(name, 96)
    het = {p.name: p for p in decode_step_phases(w, [64, 448, 128, 320])}
    hom = {p.name: p for p in decode_step_phases(w, 240, batch=4)}
    assert het["score_dec"].dram_bytes == pytest.approx(
        hom["score_dec"].dram_bytes, rel=1e-12)
    weights = w.d_model * w.d_model * 2            # output proj, B-free
    assert het["score_dec"].dram_bytes - weights == pytest.approx(
        kv_cache_bytes_per_layer(w, 64 + 448 + 128 + 320))


@pytest.mark.parametrize("B", [1, 4])
def test_head_sharing_traffic_order_preserved_under_batching(B):
    """MQA <= GQA <= MHA decode traffic, at any batch; and the batched KV
    read scales by exactly kv_frac."""
    dims = dict(name="x", d_model=4096, n_layers=32, d_ff=11008,
                vocab=32000, seq_len=256)
    mha = Workload(n_heads=32, n_kv_heads=32, **dims)
    gqa = Workload(n_heads=32, n_kv_heads=8, **dims)
    mqa = Workload(n_heads=32, n_kv_heads=1, **dims)
    t = {w.n_kv_heads: total_traffic_bytes(decode_step_phases(w, 512, B))
         for w in (mha, gqa, mqa)}
    assert t[1] < t[8] < t[32]
    kv = {w.n_kv_heads:
          {p.name: p for p in decode_step_phases(w, 512, B)}["score_dec"]
          .dram_bytes - 4096 * 4096 * 2
          for w in (mha, gqa, mqa)}
    assert kv[8] == pytest.approx(kv[32] / 4)
    assert kv[1] == pytest.approx(kv[32] / 32)


def test_decode_step_phases_rejects_bad_batch():
    w = _w("llama2-7b", 64)
    with pytest.raises(ValueError):
        decode_step_phases(w, 128, batch=0)
    with pytest.raises(ValueError):
        decode_step_phases(w, [128, 256], batch=3)   # len mismatch
    with pytest.raises(ValueError):
        decode_step_phases(w, [])


# ---------------------------------------------------------------------------
# batched generation execution model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_batched_generation_beats_single_stream(arch):
    """The batched step is slower than a single-slot step but far cheaper
    than B of them, so throughput rises and energy/token falls."""
    w = _w("llama2-7b", 128)
    g1 = simulate_generation(w, 64, 128, 32, arch=arch)
    g8 = simulate_generation(w, 64, 128, 32, arch=arch, batch=8)
    assert g1.decode_step_s <= g8.decode_step_s < 8 * g1.decode_step_s
    assert g8.decode_tok_s > g1.decode_tok_s
    assert g8.tokens_per_s > g1.tokens_per_s
    assert g8.energy_per_token_j < g1.energy_per_token_j
    assert g8.decode_bytes < g1.decode_bytes      # per-episode share


def test_batched_generation_monotone_in_batch():
    w = _w("gemma2-9b", 128)
    tok_s = [simulate_generation(w, 64, 128, 32, batch=b).decode_tok_s
             for b in (1, 2, 4, 8, 16)]
    assert tok_s == sorted(tok_s)


def test_simulate_generation_rejects_bad_batch():
    w = _w("llama2-7b", 64)
    for arch in ARCHS:
        with pytest.raises(ValueError):
            simulate_generation(w, 64, 64, 8, arch=arch, batch=0)


# ---------------------------------------------------------------------------
# regression pins: the batched-decode refactor must not move the
# calibration surface (Table-4 anchors) nor the batch-1 generation model
# ---------------------------------------------------------------------------

# (latency_s, energy_j) captured at PR 3 (with the deterministic busy-unit
# sum order); exact equality — these are the anchor rows every calibrated
# scalar is fitted to
_ANCHOR_PINS = {
    ("2.5D-HI", "bert-base", 64, 36):
        (0.04384849428577529, 3.5133460569159753),
    ("2.5D-HI", "gpt-j", 64, 100):
        (0.16308405967143874, 57.51770497936522),
    ("HAIMA_chiplet", "bert-base", 64, 36):
        (0.3399949068886732, 19.171506072810153),
    ("HAIMA_chiplet", "gpt-j", 64, 100):
        (0.9749948794837421, 151.82551320463253),
    ("TransPIM_chiplet", "bert-base", 64, 36):
        (0.20998853484005758, 10.754335052455287),
    ("TransPIM_chiplet", "gpt-j", 64, 100):
        (1.4349875283636135, 204.0803803899788),
}

_HI_RESIDUAL_PIN = 0.0345066439710499

# (ttft_s, decode_step_s, latency_s, energy_j, prefill_bytes, decode_bytes)
# of a llama2-7b 128+32 episode on 64 chiplets at PR 3 — batch=1 must
# reproduce them bit-identically
_GEN_PINS = {
    "2.5D-HI": (0.6776960438702991, 0.025484357484632066, 1.467711125893893,
                245.3625569472538, 4791943168.0, 135590258176.0),
    "HAIMA_chiplet": (2.7716863308409136, 0.06900124827863019,
                      4.910725027478449, 493.76655441191826,
                      4657725440.0, 135590258176.0),
    "TransPIM_chiplet": (4.512266350673472, 0.05245665898265166,
                         6.138422779135674, 568.8961233489139,
                         4657725440.0, 135590258176.0),
}


def test_table4_anchors_bit_identical():
    from repro.core.baselines import (simulate_haima_chiplet,
                                      simulate_transpim_chiplet)
    fns = {"2.5D-HI": simulate_2p5d_hi,
           "HAIMA_chiplet": simulate_haima_chiplet,
           "TransPIM_chiplet": simulate_transpim_chiplet}
    for (sys, arch, n, chips), (lat, energy) in _ANCHOR_PINS.items():
        r = fns[sys](_w(arch, n), chips)
        assert r.latency_s == lat, (sys, arch, r.latency_s, lat)
        assert r.energy_j == energy, (sys, arch, r.energy_j, energy)


def test_calibration_residual_bit_identical():
    from repro.core.simulator import ANCHORS, CALIB, _hi_residual
    workloads = {(a, n): _w(a, n)
                 for rows in ANCHORS.values() for a, n, _, _ in rows}
    assert _hi_residual(CALIB, workloads) == _HI_RESIDUAL_PIN


def test_batch1_generation_reproduces_pr3_numbers():
    w = _w("llama2-7b", 128)
    for arch, pin in _GEN_PINS.items():
        g = simulate_generation(w, 64, 128, 32, arch=arch, batch=1)
        got = (g.ttft_s, g.decode_step_s, g.latency_s, g.energy_j,
               g.prefill_bytes, g.decode_bytes)
        assert got == pin, (arch, got, pin)


def test_energy_busy_sum_order_is_sorted():
    """The busy-unit sum iterates the set in sorted order — set iteration
    order is hash-randomised per process and used to leak into the last
    ulp of every energy figure, breaking bit-exact pins across runs."""
    alloc = {"SM": 3, "MC": 2, "DRAM": 1, "ReRAM": 5}
    phases = [Phase("a", repeat=7)]
    times = {"a": 0.37}
    e = _energy(phases, times, alloc, None, {"a": {"SM", "MC", "ReRAM"}})
    t = 0.37 * 7
    expected = 0.0
    for p in (2 * C.MC.power_w, 5 * C.RERAM.power_w, 3 * C.SM.power_w):
        expected += p * t                   # MC < ReRAM < SM (sorted)
    expected += 1 * C.DRAM.idle_power_w * t
    assert e == expected


def test_engine_stats_feed_the_bridge():
    """End-to-end: a real (tiny) engine drain produces stats the cosim can
    consume."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np_

    from repro.config import reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0), param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, kv_len=32, max_new_tokens=4))
    rng = np_.random.default_rng(0)
    for plen in (5, 9):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    st = eng.stats()
    assert st["prompt_lens"] == [5, 9] or sorted(st["prompt_lens"]) == [5, 9]
    rec = cosim_from_engine(eng, cfg=get_config("qwen2.5-3b"), n_chiplets=36)
    assert rec["mix"]["requests"] == 2
    assert rec["archs"]["2.5D-HI"]["ttft_s"] > 0


# ---------------------------------------------------------------------------
# measured slot-pool utilisation → batched replay
# ---------------------------------------------------------------------------

def test_mix_from_stats_rejects_degenerate_slot_pool():
    """max_batch=0 (or missing) stats used to build a degenerate mix; they
    must raise instead — no engine can serve requests from a 0-slot pool."""
    s = _fake_stats()
    s["max_batch"] = 0
    with pytest.raises(ValueError, match="max_batch"):
        mix_from_stats(s)
    s2 = _fake_stats()
    del s2["max_batch"]
    with pytest.raises(ValueError, match="max_batch"):
        mix_from_stats(s2)


def test_mix_effective_batch_from_histogram():
    s = _fake_stats()
    s["active_slots_hist"] = {4: 10, 2: 10}        # mean occupancy 3
    s["max_stall_tokens"] = 24
    mix = mix_from_stats(s)
    assert mix.mean_active_slots == pytest.approx(3.0)
    assert mix.effective_batch == 3
    assert mix.max_stall_tokens == 24
    # no histogram → slot-pool size as the upper bound
    assert mix_from_stats(_fake_stats()).effective_batch == 4
    # direct EpisodeMix construction without pool info → single stream
    assert EpisodeMix([Episode(8, 4)]).effective_batch == 1


def test_cosim_mix_batched_beats_single_stream_everywhere():
    s = _fake_stats()
    s["active_slots_hist"] = {4: 20}
    mix = mix_from_stats(s)
    batched = cosim_mix("qwen2.5-3b", mix, 36)       # measured batch = 4
    single = cosim_mix("qwen2.5-3b", mix, 36, batch=1)
    for arch in ARCHS:
        assert batched[arch]["batch"] == 4
        assert single[arch]["batch"] == 1
        assert batched[arch]["tokens_per_s"] > single[arch]["tokens_per_s"]
        assert (batched[arch]["energy_per_token_j"]
                < single[arch]["energy_per_token_j"])
        assert batched[arch]["ttft_s"] == single[arch]["ttft_s"]


# ---------------------------------------------------------------------------
# chunked-prefill interleave in the NoI objective
# ---------------------------------------------------------------------------

def test_interleave_preserves_total_traffic():
    plain = EpisodeMix([Episode(256, 16, 2)], max_batch=1)
    chunked = EpisodeMix([Episode(256, 16, 2)], prefill_chunk=64,
                         max_batch=1, max_stall_tokens=64)
    tp = total_traffic_bytes(generation_phases("qwen2.5-3b", plain))
    tc = total_traffic_bytes(generation_phases("qwen2.5-3b", chunked))
    assert tc == pytest.approx(tp, rel=1e-12)


def test_interleave_bounds_per_execution_prefill_bursts():
    """The measured stall bound splits prefill into ceil(P/bound) chunk
    executions: per-execution bytes shrink by the interleave factor and
    repeats scale up to compensate."""
    plain = EpisodeMix([Episode(256, 16, 1)], max_batch=1)
    chunked = EpisodeMix([Episode(256, 16, 1)], prefill_chunk=64,
                         max_batch=1, max_stall_tokens=64)
    pre_p = [p for p in generation_phases("qwen2.5-3b", plain)
             if not p.name.endswith("_dec")]
    pre_c = [p for p in generation_phases("qwen2.5-3b", chunked)
             if not p.name.endswith("_dec")]
    for a, b in zip(pre_p, pre_c):
        assert phase_bytes(b) == pytest.approx(phase_bytes(a) / 4)
        assert b.repeat == a.repeat * 4
    # the stall bound wins over the configured chunk when tighter
    stalled = EpisodeMix([Episode(256, 16, 1)], prefill_chunk=64,
                         max_batch=1, max_stall_tokens=128)
    pre_s = [p for p in generation_phases("qwen2.5-3b", stalled)
             if not p.name.endswith("_dec")]
    assert pre_s[0].repeat == pre_p[0].repeat * 2   # ceil(256/128)


def test_generation_phases_batch_amortises_weight_streams():
    """At batch B each decode timestamp is one token's 1/B share of a
    batched step, so total decode traffic shrinks vs single-stream (the
    weight streams amortise) while repeats stay token-exact."""
    one = EpisodeMix([Episode(64, 33, 2)], max_batch=1)
    bat = EpisodeMix([Episode(64, 33, 2)], max_batch=8,
                     active_hist={8: 1})
    w = _w("qwen2.5-3b", 64)
    ph1 = generation_phases("qwen2.5-3b", one)
    ph8 = generation_phases("qwen2.5-3b", bat)
    k1 = sum(p.repeat for p in ph1 if p.name == "kqv_dec")
    k8 = sum(p.repeat for p in ph8 if p.name == "kqv_dec")
    assert k1 == k8 == 32 * w.n_dec_layers * 2      # token-exact repeats
    dec1 = sum(total_traffic_bytes([p]) for p in ph1
               if p.name.endswith("_dec"))
    dec8 = sum(total_traffic_bytes([p]) for p in ph8
               if p.name.endswith("_dec"))
    assert dec8 < dec1


def test_generation_objective_finite_with_batch_and_interleave():
    mix = EpisodeMix([Episode(256, 32, 2)], prefill_chunk=64, max_batch=8,
                     active_hist={8: 4, 6: 4}, max_stall_tokens=64)
    objective, mesh_ev, phases = generation_objective("qwen2.5-3b", mix, 36)
    assert np.isfinite(mesh_ev.mu) and mesh_ev.mu > 0
    mu, sigma = objective(initial_placement(36))
    assert np.isfinite(mu) and np.isfinite(sigma) and mu > 0


# ---------------------------------------------------------------------------
# end-to-end: deep-queue engine drain → batched Plane-B replay
# ---------------------------------------------------------------------------

def test_engine_deep_queue_batched_bridge():
    """A drained deep queue (3x the slot pool) must yield an active-slot
    histogram with occupancy > 1, and its batched Plane-B replay must beat
    the single-stream replay on every architecture while preserving the
    architecture ranking."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np_

    from repro.config import reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=48, max_new_tokens=6, prefill_chunk=24))
    rng = np_.random.default_rng(0)
    for plen in (5, 9, 7, 5, 11, 9, 5, 7, 9, 5, 7, 9):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()

    st = eng.stats()
    hist = st["active_slots_hist"]
    assert hist and all(1 <= k <= 4 for k in hist)
    assert sum(hist.values()) == st["decode_steps"]

    full = get_config("qwen2.5-3b")
    rec = cosim_from_engine(eng, cfg=full, n_chiplets=36)
    assert rec["mix"]["effective_batch"] > 1     # the pool actually batched
    single = cosim_from_engine(eng, cfg=full, n_chiplets=36, batch=1)
    b_tps, s_tps = {}, {}
    for arch in ARCHS:
        b_tps[arch] = rec["archs"][arch]["tokens_per_s"]
        s_tps[arch] = single["archs"][arch]["tokens_per_s"]
        assert b_tps[arch] >= s_tps[arch]
    assert (sorted(ARCHS, key=b_tps.__getitem__)
            == sorted(ARCHS, key=s_tps.__getitem__))


def test_active_slot_hist_counts_dead_chunk_iterations():
    """decode_chunk>1: scan iterations that outlive every slot (requests
    finished mid-chunk) are real device work — they must be recorded at
    occupancy 0 so Σhist == decode_steps and the occupancy mean discounts
    the dead tail instead of inflating the effective batch."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np_

    from repro.config import reduce_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, kv_len=32, max_new_tokens=6, decode_chunk=4))
    rng = np_.random.default_rng(0)
    for plen in (5, 7):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    st = eng.stats()
    hist = st["active_slots_hist"]
    assert sum(hist.values()) == st["decode_steps"]
    assert hist.get(0, 0) > 0            # the dead tail of the last chunk
    mix = mix_from_stats(st)
    # 5 productive iterations × 2 slots over 8 paid iterations
    assert mix.mean_active_slots == pytest.approx(10 / 8)


@pytest.mark.slow
def test_noi_sweep_emits_fronts_for_all_cells():
    """The benchmark's decode-aware Pareto sweep: every (size, model) cell
    carries a non-empty front and the single-pass design never beats the
    decode-aware one under generation traffic."""
    from benchmarks.perf_cosim import run_noi_sweep

    sweep = run_noi_sweep(("qwen2.5-3b", "bart-large"), (36, 64),
                          prompt_len=128, gen_len=32, batch=4,
                          iterations=1, ls_steps=6)
    assert len(sweep["cells"]) == 4
    for cell in sweep["cells"]:
        assert cell["front"]
        assert cell["gain_mu"] >= 1.0 - 1e-9
        assert np.isfinite(cell["best_mu_norm"])
