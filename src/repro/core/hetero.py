"""TPU adaptation of the paper's mapping methodology (DESIGN.md §3).

Two transfers of the paper's ideas to the homogeneous TPU mesh:

1. **SFC device ordering** (paper §3.2 → torus ICI): quantify the hop cost
   of ring collectives for different logical→physical device orderings of
   the 16×16 pod, exactly as the paper scores chiplet placements by NoI
   hop counts.  ``ring_hop_cost`` is used by launch/mesh.py's
   ``sfc_order`` option and reported in EXPERIMENTS.md.

2. **MappingSearch** (paper §3.3 → sharding space): the paper MOOs chiplet
   placement under fixed workload traffic; with fixed hardware we search
   *workload placements* (sharding-plan knobs) scoring candidates by the
   three-term roofline from the compiled HLO — same MOO-STAGE machinery,
   congestion-style objectives (collective seconds ≈ μ·link-utilisation).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.sfc import CURVES, curve_positions


# ---------------------------------------------------------------------------
# 1. SFC ordering of the TPU torus
# ---------------------------------------------------------------------------

def _torus_hops(a: tuple, b: tuple, w: int, h: int) -> int:
    dx = abs(a[0] - b[0])
    dy = abs(a[1] - b[1])
    return min(dx, w - dx) + min(dy, h - dy)


def ring_hop_cost(order_name: str, w: int = 16, h: int = 16,
                  axis: str = "model") -> dict:
    """Physical ICI hops used by a ring collective over one mesh axis when
    logical devices are enumerated along the given curve.

    Returns per-step hop stats — a ring all-gather/reduce-scatter moves
    data along consecutive logical devices, so consecutive-pair distance on
    the physical torus is the congestion metric (cf. paper eq. 11-13)."""
    pos = curve_positions(order_name, w, h)          # logical id -> (x, y)
    # the "model" axis = contiguous runs of 16 logical ids (row-major mesh)
    hops = []
    if axis == "model":
        for row in range(h):
            ids = range(row * w, (row + 1) * w)
            ring = list(ids) + [row * w]
            for a, b in zip(ring[:-1], ring[1:]):
                hops.append(_torus_hops(tuple(pos[a]), tuple(pos[b]), w, h))
    else:  # data axis: stride-w rings
        for col in range(w):
            ids = [r * w + col for r in range(h)]
            ring = ids + [ids[0]]
            for a, b in zip(ring[:-1], ring[1:]):
                hops.append(_torus_hops(tuple(pos[a]), tuple(pos[b]), w, h))
    hops = np.asarray(hops)
    return {"curve": order_name, "axis": axis, "mean_hops": float(hops.mean()),
            "max_hops": int(hops.max()), "total_hops": int(hops.sum())}


def compare_device_orders(w: int = 16, h: int = 16) -> list[dict]:
    out = []
    for name in CURVES:
        for axis in ("model", "data"):
            out.append(ring_hop_cost(name, w, h, axis))
    return out


# ---------------------------------------------------------------------------
# 2. MappingSearch over sharding-plan knobs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MappingKnobs:
    """The discrete sharding/layout design space (λ for the TPU plane)."""
    seq_shard: bool = True          # SP residual stream over `model`
    heads_policy: str = "auto"      # auto | heads | seq
    accum: int = 1                  # grad-accumulation microbatches
    remat_policy: str = "none"      # none | dots
    moe_dispatch: str = "gather"    # gather | a2a  (hillclimb lever)

    def neighbors(self) -> list["MappingKnobs"]:
        out = []
        for f, vals in [("seq_shard", (True, False)),
                        ("heads_policy", ("auto", "heads", "seq")),
                        ("accum", (1, 2, 4)),
                        ("remat_policy", ("none", "dots")),
                        ("moe_dispatch", ("gather", "a2a"))]:
            for v in vals:
                if getattr(self, f) != v:
                    out.append(dataclasses.replace(self, **{f: v}))
        return out


@dataclasses.dataclass
class MappingResult:
    knobs: MappingKnobs
    objectives: tuple           # (step_s, collective_s, live_bytes)
    report: Optional[object] = None


def mapping_search(evaluate: Callable[[MappingKnobs], tuple], *,
                   start: MappingKnobs = MappingKnobs(),
                   budget: int = 12) -> list[MappingResult]:
    """Greedy Pareto local search over the knob space (the base search of
    MOO-STAGE; the space is small enough that the surrogate meta-search is
    unnecessary — noted difference from Plane B)."""
    from repro.core.moo import dominates

    seen = {start: evaluate(start)}
    frontier = [start]
    evals = 1
    while frontier and evals < budget:
        cur = frontier.pop(0)
        for cand in cur.neighbors():
            if cand in seen or evals >= budget:
                continue
            seen[cand] = evaluate(cand)
            evals += 1
            if dominates(seen[cand], seen[cur]):
                frontier.append(cand)
    results = [MappingResult(k, o) for k, o in seen.items()]
    pareto = [r for r in results
              if not any(dominates(o.objectives, r.objectives)
                         for o in results if o is not r)]
    return sorted(pareto, key=lambda r: r.objectives[0])
