"""Plane-A ↔ Plane-B co-simulation bridge.

The serving engine (`repro.serving.engine`) runs real prefill+decode
schedules on JAX; the analytical simulator (`core/simulator`) evaluates
chiplet architectures.  This module closes the loop:

1. **measure** — ``mix_from_stats`` turns ``ServingEngine.stats()`` into a
   :class:`EpisodeMix`: the batch mix of (prompt_len, gen_len) episodes the
   engine actually served, plus its chunked-prefill schedule;
2. **replay** — ``cosim_mix`` replays that mix through
   ``simulate_generation`` for every architecture, on the *full* model
   config (the engine typically serves a ``reduce_config`` shrink of it),
   reporting TTFT, decode tok/s and energy/token per architecture;
3. **design** — ``generation_phases`` expands the mix into a decode-heavy
   phase list whose repeats weight prefill vs decode by their measured
   token counts, and ``generation_objective`` feeds it to the existing
   MOO solvers (`core/moo`) — so NoI placement/link search optimises for
   the traffic a *generation* workload actually produces (KV-cache reads
   dominating), not a single fixed-length forward pass.

The single-pass calibration contract is untouched: everything here is
built from ``prefill_phases`` / ``decode_step_phases`` on top of the
anchored single-pass models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.config import ModelConfig, get_config
from repro.core.noi import NoIEval, evaluate_noi, mesh_baseline_eval
from repro.core.simulator import (CALIB, Calib, _decode_positions,
                                  simulate_generation)
from repro.core.traffic import (Phase, Workload, decode_step_phases,
                                prefill_phases)

ARCHS = ("2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet")


@dataclasses.dataclass(frozen=True)
class Episode:
    """One served request class: prompt_len tokens in, gen_len tokens out."""
    prompt_len: int
    gen_len: int
    count: int = 1


@dataclasses.dataclass
class EpisodeMix:
    """The measured workload of a serving run (the Plane-A ground truth)."""
    episodes: list[Episode]
    prefill_chunk: int = 0        # engine chunked-prefill budget (tokens)
    max_batch: int = 0            # engine slot-pool size

    @property
    def requests(self) -> int:
        return sum(e.count for e in self.episodes)

    @property
    def prefill_tokens(self) -> int:
        return sum(e.prompt_len * e.count for e in self.episodes)

    @property
    def decode_tokens(self) -> int:
        return sum(max(e.gen_len - 1, 0) * e.count for e in self.episodes)


def mix_from_stats(stats: dict) -> EpisodeMix:
    """Build the episode mix from ``ServingEngine.stats()``.

    Requires the per-request ``prompt_lens``/``gen_lens`` lists the engine
    records for finished requests; identical (prompt, gen) pairs collapse
    into one weighted episode."""
    if not stats.get("finished"):
        raise ValueError("engine stats carry no finished requests")
    plens = stats.get("prompt_lens")
    glens = stats.get("gen_lens")
    if not plens or not glens or len(plens) != len(glens):
        raise ValueError("stats missing per-request prompt_lens/gen_lens")
    counts: dict[tuple[int, int], int] = {}
    for p, g in zip(plens, glens):
        counts[(int(p), int(g))] = counts.get((int(p), int(g)), 0) + 1
    episodes = [Episode(p, g, c) for (p, g), c in sorted(counts.items())]
    return EpisodeMix(episodes,
                      prefill_chunk=int(stats.get("prefill_chunk", 0)),
                      max_batch=int(stats.get("max_batch", 0)))


def _resolve(cfg) -> ModelConfig:
    return get_config(cfg) if isinstance(cfg, str) else cfg


def workload_for(cfg, episode: Episode) -> Workload:
    """Plane-B workload for one episode of a (full-size) model config."""
    return Workload.from_config(_resolve(cfg), seq_len=episode.prompt_len)


# ---------------------------------------------------------------------------
# replay: measured mix → per-architecture generation metrics
# ---------------------------------------------------------------------------

def cosim_mix(cfg, mix: EpisodeMix, n_chiplets: int,
              archs: Sequence[str] = ARCHS, *,
              calib: Calib = CALIB) -> dict:
    """Replay a measured episode mix through every architecture.

    Returns ``{arch: {ttft_s, decode_step_s, tokens_per_s,
    energy_per_token_j, prefill_bytes, decode_bytes, decode_traffic_frac}}``
    with request-count-weighted means (throughput weighted by tokens)."""
    cfg = _resolve(cfg)
    out: dict[str, dict] = {}
    for arch in archs:
        ttft = step = energy = toks = lat = pre_b = dec_b = 0.0
        n = 0
        for ep in mix.episodes:
            w = workload_for(cfg, ep)
            g = simulate_generation(w, n_chiplets, ep.prompt_len, ep.gen_len,
                                    arch=arch, calib=calib)
            n += ep.count
            ttft += g.ttft_s * ep.count
            step += g.decode_step_s * ep.count
            energy += g.energy_j * ep.count
            toks += g.gen_len * ep.count
            lat += g.latency_s * ep.count
            pre_b += g.prefill_bytes * ep.count
            dec_b += g.decode_bytes * ep.count
        out[arch] = {
            "ttft_s": ttft / n,
            "decode_step_s": step / n,
            "tokens_per_s": toks / max(lat, 1e-30),
            "energy_per_token_j": energy / max(toks, 1),
            "prefill_bytes": pre_b,
            "decode_bytes": dec_b,
            "decode_traffic_frac": dec_b / max(pre_b + dec_b, 1e-30),
        }
    return out


def cosim_from_engine(engine, cfg=None, n_chiplets: int = 64,
                      archs: Sequence[str] = ARCHS, *,
                      calib: Calib = CALIB) -> dict:
    """End-to-end bridge: measured engine run → Plane-B evaluation.

    ``cfg`` defaults to the engine's own (usually reduced) config; pass the
    full-size config to project the measured schedule onto the real model
    dims."""
    mix = mix_from_stats(engine.stats())
    cfg = _resolve(cfg) if cfg is not None else engine.cfg
    return {"mix": {"requests": mix.requests,
                    "prefill_tokens": mix.prefill_tokens,
                    "decode_tokens": mix.decode_tokens,
                    "prefill_chunk": mix.prefill_chunk,
                    "max_batch": mix.max_batch,
                    "episodes": [dataclasses.asdict(e) for e in mix.episodes]},
            "archs": cosim_mix(cfg, mix, n_chiplets, archs, calib=calib)}


# ---------------------------------------------------------------------------
# design: generation traffic → MOO/placement objective
# ---------------------------------------------------------------------------

def generation_phases(cfg, mix: EpisodeMix, *, samples: int = 1) -> list[Phase]:
    """Phase list of a whole generation episode mix, for NoI evaluation.

    Prefill phases keep their per-layer repeats; decode phases (evaluated
    at ``samples`` KV positions per episode) get their repeats scaled by
    the number of decode steps they represent, so ``evaluate_noi``'s
    repeat-weighted time-average (eqs 14-15) sees prefill and decode in
    their measured proportions — decode-heavy mixes dominate the objective
    exactly as they dominate the real fabric."""
    cfg = _resolve(cfg)
    phases: list[Phase] = []
    for ep in mix.episodes:
        w = workload_for(cfg, ep)
        for p in prefill_phases(w):
            q = dataclasses.replace(p, repeat=p.repeat * ep.count)
            phases.append(q)
        steps = max(ep.gen_len - 1, 0)
        if not steps:
            continue
        positions = _decode_positions(ep.prompt_len, ep.gen_len, samples)
        # partition the decode steps across the sampled positions exactly,
        # so the repeat-weighted decode/prefill ratio matches the mix
        base, rem = divmod(steps, len(positions))
        for i, pos in enumerate(positions):
            per_pos = base + (1 if i < rem else 0)
            for p in decode_step_phases(w, pos):
                q = dataclasses.replace(
                    p, repeat=p.repeat * per_pos * ep.count)
                phases.append(q)
    return phases


def generation_objective(cfg, mix: EpisodeMix, n_chiplets: int,
                         *, samples: int = 1,
                         mesh_ev: Optional[NoIEval] = None,
                         ) -> tuple[Callable, NoIEval, list[Phase]]:
    """(objective_fn, mesh_ev, phases): the paper's 2-objective NoI metric
    (μ, σ normalised to the placement-unaware 2-D mesh) over the measured
    generation traffic.  Drop-in for `core/moo` solvers."""
    phases = generation_phases(cfg, mix, samples=samples)
    mesh_ev = mesh_ev or mesh_baseline_eval(n_chiplets, phases)

    def objective(p):
        ev = evaluate_noi(p, phases)
        return (ev.mu / mesh_ev.mu, ev.sigma / mesh_ev.sigma)

    return objective, mesh_ev, phases


def optimize_generation_noi(cfg, mix: EpisodeMix, n_chiplets: int, *,
                            iterations: int = 3, ls_steps: int = 12,
                            seed: int = 0, samples: int = 1):
    """Decode-aware NoI design search: MOO-STAGE over the generation
    traffic, seeded (like `examples/noi_design.py`) with a local search
    from the dataflow-aware initial placement.  Returns
    (MooStageResult, mesh_ev)."""
    import random

    from repro.core.moo import local_search, moo_stage
    from repro.core.placement import initial_placement

    objective, mesh_ev, _ = generation_objective(cfg, mix, n_chiplets,
                                                 samples=samples)
    res = moo_stage(n_chiplets, objective, (2.0, 2.0),
                    iterations=iterations, ls_steps=ls_steps, seed=seed)
    local_search(initial_placement(n_chiplets), objective, res.archive,
                 random.Random(seed), max_steps=ls_steps)
    return res, mesh_ev
