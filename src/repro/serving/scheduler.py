"""Admission + slot policy layer: who is admitted next, and whether
prefill may preempt decode this iteration.

This module is deliberately JAX-free: a scheduler sees only host-side
request bookkeeping (uids, priorities, timestamps, token counts) and
returns decisions, so policies are unit-testable against a fake executor
(`tests/test_scheduler.py`) and swappable without touching device code.

The engine consults its scheduler at exactly two seams:

1. **selection** — ``select(queue, now)`` returns the *index* into the
   admission queue of the next request to admit (``None`` = admit
   nothing this iteration).  The engine pops that entry and runs its
   admission mechanics (packing, padding, slot assignment) unchanged —
   policy decides *who*, the engine decides *how*.
2. **preemption gating** — ``allow_prefill(decoding, now)`` is asked
   before any prefill work (packed admission or a chunked-prefill
   continuation) when slots are actively decoding: prefill stalls every
   decoding slot for roughly one chunk, so an SLO-aware policy may defer
   it while decode slack is too thin.  The engine only asks when there
   is both decode work to preempt and prefill work to run; it never
   gates an idle pool (no deadlock by policy).

``FifoScheduler`` reproduces the pre-layering engine bit-for-bit:
selection is strict FIFO and prefill is always allowed.
``SloScheduler`` adds priority classes with per-class TTFT/TPOT targets,
least-slack-first ordering, aging (starvation-freeness), and slack-gated
chunked-prefill preemption of decode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol, Sequence, runtime_checkable


@runtime_checkable
class Scheduler(Protocol):
    """The policy contract the engine drives (see module docstring).

    ``queue`` entries and ``decoding`` entries are ``Request``-shaped:
    the policy may read ``uid``, ``priority``, ``t_enqueue``,
    ``t_first_token`` and ``output`` (emitted-token list) — nothing
    else, and it must mutate nothing."""

    def select(self, queue: Sequence, now: float) -> Optional[int]:
        """Index into ``queue`` of the next request to admit, or None."""
        ...

    def allow_prefill(self, decoding: Sequence, now: float) -> bool:
        """May prefill preempt the ``decoding`` slots this iteration?"""
        ...

    def observe_prefill(self, dt_s: float) -> None:
        """Measured wall time of one admission/chunk burst (the stall a
        preemption actually costs) — feeds the policy's cost estimate."""
        ...


class FifoScheduler:
    """Strict FIFO admission, prefill always allowed — bit-identical to
    the pre-layering monolithic engine under every workload."""

    def select(self, queue: Sequence, now: float) -> Optional[int]:
        return 0 if queue else None

    def allow_prefill(self, decoding: Sequence, now: float) -> bool:
        return True

    def observe_prefill(self, dt_s: float) -> None:
        pass

    # -- checkpoint plumbing (FIFO carries no adaptive state) --------------
    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


@dataclasses.dataclass(frozen=True)
class SloClass:
    """Service targets for one priority class (seconds are derived from
    the ms fields; ``inf`` = no target)."""
    ttft_ms: float = math.inf     # queue + first-token deadline
    tpot_ms: float = math.inf     # per-token cadence once decoding

    @property
    def ttft_s(self) -> float:
        return self.ttft_ms / 1e3

    @property
    def tpot_s(self) -> float:
        return self.tpot_ms / 1e3


class SloScheduler:
    """SLO-aware admission: priority classes, least-TTFT-slack-first
    ordering, aging, and slack-gated prefill preemption of decode.

    **Selection.**  Requests order by *effective priority* (the submitted
    ``priority`` plus one level per ``aging_s`` seconds waited — a
    starving low-priority request eventually outranks fresh high-priority
    arrivals, so no class is starved forever), then by TTFT slack
    (``t_enqueue + ttft_target - now``, most-overdue first), then by uid
    (FIFO within a class).

    **Preemption gating.**  A prefill burst stalls every decoding slot
    for about one chunk; ``allow_prefill`` permits it only when the
    tightest decoding slot can absorb the estimated stall without
    missing its TPOT cadence: slot ``i``'s next token is due at
    ``t_first_token + n_emitted x tpot_s`` and the stall estimate is an
    EWMA of measured admission bursts (``observe_prefill``).  Decode
    slack can stay negative under sustained overload, so after
    ``max_defer`` consecutive deferrals prefill runs anyway — admission
    is throttled, never starved.
    """

    def __init__(self, classes: Optional[dict[int, SloClass]] = None,
                 *, default: SloClass = SloClass(), aging_s: float = 0.0,
                 max_defer: int = 8, ewma: float = 0.5):
        if max_defer < 1:
            raise ValueError(f"max_defer must be >= 1, got {max_defer}")
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        self.classes = dict(classes or {})
        self.default = default
        self.aging_s = aging_s
        self.max_defer = max_defer
        self.ewma = ewma
        self._stall_est_s = 0.0       # EWMA of measured admission bursts
        self._defers = 0              # consecutive gated iterations

    def class_of(self, priority: int) -> SloClass:
        return self.classes.get(priority, self.default)

    # -- selection ---------------------------------------------------------
    def _rank(self, req, now: float):
        wait = now - req.t_enqueue
        eff = req.priority
        if self.aging_s > 0 and wait > 0:
            eff += int(wait / self.aging_s)
        slack = req.t_enqueue + self.class_of(req.priority).ttft_s - now
        return (-eff, slack, req.uid)

    def select(self, queue: Sequence, now: float) -> Optional[int]:
        if not queue:
            return None
        return min(range(len(queue)), key=lambda i: self._rank(queue[i], now))

    # -- preemption gating -------------------------------------------------
    def _decode_slack_s(self, decoding: Sequence, now: float) -> float:
        """Seconds until the tightest decoding slot misses its TPOT
        cadence (inf when no decoding slot carries a TPOT target)."""
        slack = math.inf
        for req in decoding:
            tpot = self.class_of(req.priority).tpot_s
            if math.isinf(tpot):
                continue
            due = req.t_first_token + len(req.output) * tpot
            slack = min(slack, due - now)
        return slack

    def allow_prefill(self, decoding: Sequence, now: float) -> bool:
        if self._decode_slack_s(decoding, now) >= self._stall_est_s:
            self._defers = 0
            return True
        self._defers += 1
        if self._defers >= self.max_defer:   # bounded deferral: admission
            self._defers = 0                 # is throttled, never starved
            return True
        return False

    def observe_prefill(self, dt_s: float) -> None:
        if self._stall_est_s <= 0.0:
            self._stall_est_s = dt_s
        else:
            self._stall_est_s += self.ewma * (dt_s - self._stall_est_s)

    # -- checkpoint plumbing ------------------------------------------------
    def state_dict(self) -> dict:
        """Adaptive policy state a crash would otherwise lose.  The EWMA
        stall estimate gates preemption and the deferral counter is
        mid-burst state — dropping either changes which iteration admits
        next after a restore, so SLO admission order would diverge from
        the uninterrupted run.  (Aging needs no extra state here: it is
        derived from each request's ``t_enqueue``, which restores with
        the request.)"""
        return {"stall_est_s": self._stall_est_s, "defers": self._defers}

    def load_state_dict(self, state: dict) -> None:
        self._stall_est_s = float(state.get("stall_est_s", 0.0))
        self._defers = int(state.get("defers", 0))
